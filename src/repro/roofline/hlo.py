"""Trip-count-aware cost extraction from optimized (SPMD-partitioned) HLO.

``compiled.cost_analysis()`` counts ``while`` bodies ONCE, which silently
drops a factor of num_layers from every scanned-layer model.  XLA's
optimized HLO carries ``backend_config={"known_trip_count":{"n":...}}`` on
each while, so we parse the module into computations, propagate loop
multipliers through while-body/fusion/call edges, and accumulate:

* **flops** — every ``dot`` (2 * prod(result) * prod(contracting dims)) and
  ``convolution`` (2 * prod(result) * kernel work per output element);
* **bytes** — result + operand bytes of ops in *non-fusion* computations
  (fusion internals live in registers/VMEM, so only fusion boundaries touch
  HBM — this matches the XLA execution model);
* **collectives** — wire bytes per op kind, ring-scaled, x loop multiplier.

Validated against an unrolled single-device lowering in
tests/test_roofline.py (scan vs unroll agree).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COMP_HDR = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((?:[^()]|\([^()]*\))*\)\s*->")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY = re.compile(r"body=%?([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_RG = re.compile(r"replica_groups=\{\{([^}]*)\}")
_RG2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "call",
    "conditional", "custom-call", "infeed", "outfeed", "rng-get-and-update-state",
}


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nbytes(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    shapes: List[Tuple[str, List[int]]]
    line: str


@dataclasses.dataclass
class ModuleCosts:
    flops: float
    hbm_bytes: float
    collective_wire_bytes: float
    collective_counts: Dict[str, int]
    loop_multipliers: Dict[str, float]


def parse_computations(text: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("{" in line) and ("->" in line):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if m:
            name, type_str, opcode = m.groups()
            comps[cur].append(Op(name, opcode, _shape_list(type_str), line))
    return comps


def _entry_name(text: str) -> Optional[str]:
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line[len("ENTRY"):].strip() if False else
                                line.replace("ENTRY", "", 1).strip())
            if m:
                return m.group(1)
    return None


def loop_multipliers(text: str, comps: Dict[str, List[Op]]) -> Dict[str, float]:
    entry = _entry_name(text)
    mult: Dict[str, float] = {}
    if entry is None:
        return {c: 1.0 for c in comps}
    mult[entry] = 1.0
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(32):
        changed = False
        for cname, ops in comps.items():
            m0 = mult.get(cname)
            if m0 is None:
                continue
            for op in ops:
                targets: List[Tuple[str, float]] = []
                if op.opcode == "while":
                    trip = 1.0
                    tm = _TRIP.search(op.line)
                    if tm:
                        trip = float(tm.group(1))
                    bm = _BODY.search(op.line)
                    cm = _COND.search(op.line)
                    if bm:
                        targets.append((bm.group(1), m0 * trip))
                    if cm:
                        targets.append((cm.group(1), m0 * (trip + 1)))
                else:
                    for rex in (_CALLS, _TO_APPLY):
                        mm = rex.search(op.line)
                        if mm:
                            targets.append((mm.group(1), m0))
                for tgt, val in targets:
                    if tgt in comps and mult.get(tgt, 0.0) < val:
                        mult[tgt] = val
                        changed = True
        if not changed:
            break
    for c in comps:
        mult.setdefault(c, 1.0)
    return mult


def _symbol_table(comps: Dict[str, List[Op]]) -> Dict[str, List[Tuple[str, List[int]]]]:
    table: Dict[str, List[Tuple[str, List[int]]]] = {}
    for ops in comps.values():
        for op in ops:
            table[op.name] = op.shapes
    return table


def _operands(line: str) -> List[str]:
    m = re.search(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)", line.split("=", 1)[1])
    if not m:
        return []
    names = re.findall(r"%([\w.\-]+)", m.group(1))
    return names


def _dot_flops(op: Op, table) -> float:
    res = 1
    for _, dims in op.shapes:
        for d in dims:
            res *= d
    lhs_c = _LHS_C.search(op.line)
    contracted = 1
    if lhs_c:
        operands = _operands(op.line)
        if operands:
            lhs_shapes = table.get(operands[0])
            if lhs_shapes:
                dims = lhs_shapes[0][1]
                for idx in (int(i) for i in lhs_c.group(1).split(",") if i):
                    if idx < len(dims):
                        contracted *= dims[idx]
    return 2.0 * res * contracted


def _conv_flops(op: Op, table) -> float:
    res = 1
    for _, dims in op.shapes:
        for d in dims:
            res *= d
    operands = _operands(op.line)
    kernel_work = 1
    if len(operands) >= 2:
        ker = table.get(operands[1])
        if ker:
            dims = ker[0][1]
            total = 1
            for d in dims:
                total *= d
            # per-output-element work = prod(kernel)/out_features; the
            # out-features dim is the one matching the result feature count —
            # approximate with the largest trailing dim
            out_feat = dims[-1] if dims else 1
            kernel_work = max(1, total // max(1, out_feat))
    return 2.0 * res * kernel_work


def _param_read_bytes(comps: Dict[str, List[Op]]) -> Dict[str, List[Optional[int]]]:
    """Per fusion computation: effective read bytes per parameter position.

    A parameter consumed ONLY via dynamic-slice reads just the slice (the
    scan residual-stash pattern); anything else reads the full buffer
    (None = full).  This is what keeps the HBM-traffic proxy honest for
    scanned-layer models.
    """
    out: Dict[str, List[Optional[int]]] = {}
    for cname, ops in comps.items():
        params: Dict[str, int] = {}
        for op in ops:
            if op.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.line)
                if m:
                    params[op.name] = int(m.group(1))
        if not params:
            continue
        # consumer map: param -> (all_dynamic_slice, slice_bytes); layout ops
        # (bitcast/reshape/transpose/copy) alias transitively to the param
        layout_ops = {"bitcast", "reshape", "transpose", "copy"}
        alias: Dict[str, str] = {p: p for p in params}
        info: Dict[str, Tuple[bool, int]] = {p: (True, 0) for p in params}
        for op in ops:
            if op.opcode == "parameter":
                continue
            operands = _operands(op.line)
            if (op.opcode in layout_ops and len(operands) == 1
                    and operands[0] in alias):
                alias[op.name] = alias[operands[0]]
                continue
            for i, o in enumerate(operands):
                root = alias.get(o)
                if root is None:
                    continue
                ok, nb = info[root]
                if op.opcode == "dynamic-slice" and i == 0:
                    info[root] = (ok, nb + _nbytes(op.shapes))
                elif op.opcode == "dynamic-update-slice" and i == 0:
                    # in-place update target: written slice counted via the
                    # update operand; the buffer itself is not fully read
                    continue
                else:
                    info[root] = (False, nb)
        n = max(params.values()) + 1
        eff: List[Optional[int]] = [None] * n
        for p, idx in params.items():
            ok, nb = info[p]
            if ok and nb >= 0:
                eff[idx] = nb
        out[cname] = eff
    return out


def _root_dus_write_bytes(comps, table) -> Dict[str, int]:
    """Fusions whose ROOT is a dynamic-update-slice write only the update
    slice in place, not the full (possibly stacked) buffer."""
    out: Dict[str, int] = {}
    for cname, ops in comps.items():
        for op in ops:
            if "ROOT" not in op.line or op.opcode != "dynamic-update-slice":
                continue
            operands = _operands(op.line)
            if len(operands) >= 2:
                upd = table.get(operands[1])
                if upd:
                    out[cname] = _nbytes(upd)
    return out


def _group_size(line: str, default: int) -> int:
    m = _RG.search(line)
    if m:
        return max(2, len(m.group(1).split(",")))
    m2 = _RG2.search(line)
    if m2:
        return max(2, int(m2.group(2)))
    return max(2, default)


def module_costs(text: str, num_devices: int) -> ModuleCosts:
    comps = parse_computations(text)
    mult = loop_multipliers(text, comps)
    table = _symbol_table(comps)
    param_reads = _param_read_bytes(comps)
    dus_roots = _root_dus_write_bytes(comps, table)
    flops = 0.0
    hbm = 0.0
    coll_bytes = 0.0
    coll_counts: Dict[str, int] = {}
    fusion_like = {c for c in comps
                   if c.startswith(("fused_", "wrapped_", "region_", "wide."))
                   or ".fused" in c or "_computation" in c
                   or ".clone" in c or "region_" in c}
    # computations reachable only as while bodies are NOT fusion-internal
    body_comps = set()
    for ops in comps.values():
        for op in ops:
            if op.opcode == "while":
                bm = _BODY.search(op.line)
                if bm:
                    body_comps.add(bm.group(1))
    for cname, ops in comps.items():
        m = mult.get(cname, 1.0)
        count_bytes_here = (cname in body_comps) or (cname not in fusion_like)
        for op in ops:
            if op.opcode == "dot":
                flops += m * _dot_flops(op, table)
            elif op.opcode == "convolution":
                flops += m * _conv_flops(op, table)
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                g = _group_size(op.line, num_devices)
                ring = (g - 1) / g
                factor = {"all-gather": ring, "reduce-scatter": ring,
                          "all-reduce": 2 * ring, "all-to-all": ring,
                          "collective-permute": 1.0}[base]
                coll_bytes += m * _nbytes(op.shapes) * factor
                coll_counts[base] = coll_counts.get(base, 0) + int(m)
            if not count_bytes_here or op.opcode in _SKIP_BYTES_OPS:
                continue
            # HBM traffic: results written + operands read at fusion
            # boundaries (fusion internals stay on-chip; dynamic-slice-only
            # fusion params read just their slices)
            operands = _operands(op.line)
            if op.opcode == "dynamic-update-slice" and len(operands) >= 2:
                upd = table.get(operands[1])
                hbm += m * 2 * (_nbytes(upd) if upd else 0)
                continue
            if op.opcode == "dynamic-slice":
                hbm += m * 2 * _nbytes(op.shapes)
                continue
            nb = _nbytes(op.shapes)
            callee = None
            if op.opcode == "fusion":
                cm = _CALLS.search(op.line)
                if cm:
                    callee = param_reads.get(cm.group(1))
                    if cm.group(1) in dus_roots:
                        nb = dus_roots[cm.group(1)]  # in-place slice write
            for i, o in enumerate(operands):
                sh = table.get(o)
                if sh is None:
                    continue
                full = _nbytes(sh)
                if callee is not None and i < len(callee) and callee[i] is not None:
                    nb += min(full, callee[i])
                else:
                    nb += full
            hbm += m * nb
    return ModuleCosts(flops=flops, hbm_bytes=hbm,
                       collective_wire_bytes=coll_bytes,
                       collective_counts=coll_counts,
                       loop_multipliers={k: v for k, v in mult.items()
                                         if v > 1.0})
