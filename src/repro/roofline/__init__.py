"""Roofline analysis over compiled dry-run artifacts."""
from repro.roofline import analysis

__all__ = ["analysis"]
