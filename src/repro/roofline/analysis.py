"""Three-term roofline from a compiled SPMD executable.

  compute    = HLO_FLOPs   / (chips * peak FLOP/s)
  memory     = HLO_bytes   / (chips * HBM bandwidth)
  collective = coll_bytes  / (chips * ICI link bandwidth)

``cost_analysis()`` supplies FLOPs/bytes; collective bytes are parsed from
the partitioned HLO text (``compiled.as_text()``): we sum the *result*
buffer sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, scaled to wire bytes per the op's
algorithm (ring AG/RS move (n-1)/n of the result; AR moves 2x that;
A2A/CP move the buffer once).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

# e.g.  %ag = bf16[4,128,1024]{2,1,0} all-gather(%x), ...
_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _replica_groups_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if not m:
        m2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        if m2:
            return int(m2.group(2))
        return default
    return len(m.group(1).split(","))


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    wire_bytes: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str, num_devices: int) -> CollectiveStats:
    counts: Dict[str, int] = {}
    wire: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(4)
        if m.group(1) is not None:   # tuple result: sum element shapes
            nbytes = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(m.group(1)))
        else:
            nbytes = _shape_bytes(m.group(2), m.group(3))
        g = max(2, _replica_groups_size(line, num_devices))
        ring = (g - 1) / g
        factor = {"all-gather": ring, "reduce-scatter": ring,
                  "all-reduce": 2 * ring, "all-to-all": ring,
                  "collective-permute": 1.0}[op]
        counts[op] = counts.get(op, 0) + 1
        wire[op] = wire.get(op, 0.0) + nbytes * factor
    return CollectiveStats(counts, wire)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # per-device
    hlo_bytes: float          # per-device
    coll_bytes: float         # per-device wire bytes
    model_flops: float        # 6*N*D useful flops (global)
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    peak_mem_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> str:
        return (f"{self.arch:26s} {self.shape:12s} {self.mesh:9s} "
                f"compute={self.t_compute * 1e3:9.2f}ms "
                f"memory={self.t_memory * 1e3:9.2f}ms "
                f"coll={self.t_collective * 1e3:9.2f}ms "
                f"-> {self.bottleneck:10s} useful={self.useful_ratio:6.3f}")


def cost_terms(compiled, num_devices: int) -> Tuple[float, float]:
    """(flops, bytes) per device from cost_analysis (already partitioned)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    return flops, nbytes


def model_flops(cfg, kind: str, batch: int, seq_len: int) -> float:
    """6*N*D for training, 2*N_active*D for inference (per step)."""
    import jax
    from repro.models import transformer

    params_shape = jax.eval_shape(
        lambda k: transformer.init(cfg, k), jax.random.PRNGKey(0))
    n_total = sum(int(x.size) for x in jax.tree_util.tree_leaves(params_shape))
    # active params for MoE: experts scaled to experts_per_token/num_experts
    n_active = n_total
    if cfg.num_experts:
        flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
        expert_n = sum(
            int(l.size) for p, l in flat
            if any(getattr(k, "key", None) == "moe" for k in p)
            and getattr(p[-1], "key", "") in ("w_gate", "w_up", "w_down"))
        n_active = n_total - expert_n * (1 - cfg.experts_per_token / cfg.num_experts)
    tokens = batch * (seq_len if kind != "decode" else 1)
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_active * tokens
