"""Checkpointing: pytree <-> .npz with path-encoded keys (no deps)."""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "||"


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(jax.tree_util.keystr((p,)) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(path: str, tree: PyTree) -> None:
    flat, _ = _flatten(tree)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # np.savez rejects some key chars when zipping; index keys positionally
    keys = sorted(flat)
    np.savez(tmp, __keys__=np.array(keys, dtype=object),
             **{f"a{i}": flat[k] for i, k in enumerate(keys)})
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with np.load(path, allow_pickle=True) as data:
        keys = list(data["__keys__"])
        arrays = {k: data[f"a{i}"] for i, k in enumerate(keys)}
    flat_like, treedef = _flatten(like)
    assert set(arrays) == set(flat_like), (
        f"checkpoint keys mismatch: {set(arrays) ^ set(flat_like)}")
    leaves_like, tdef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_like:
        key = _SEP.join(jax.tree_util.keystr((p,)) for p in path_k)
        arr = arrays[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return tdef.unflatten(out)
