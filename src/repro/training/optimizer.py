"""AdamW with decoupled weight decay + warmup-cosine schedule (pure JAX)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree          # f32 first moments
    nu: PyTree          # f32 second moments


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: PyTree) -> AdamWState:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def update(cfg: AdamWConfig, grads: PyTree, state: AdamWState, params: PyTree
           ) -> Tuple[PyTree, AdamWState]:
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
