"""Training loop: loss, train_step, and a simple driver.

``train_step`` is the function the multi-pod dry-run lowers for the
``train_4k`` input shape: forward + backward + AdamW update, with the MoE
load-balance auxiliary loss folded in.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterator, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.common import ModelConfig
from repro.training import optimizer as opt_lib

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: opt_lib.AdamWState


def init_state(cfg: ModelConfig, key: jax.Array) -> TrainState:
    params = transformer.init(cfg, key)
    return TrainState(params=params, opt=opt_lib.init(params))


def loss_fn(cfg: ModelConfig, params: PyTree, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (+ MoE aux).  batch: tokens/labels (B, L)
    [or (B, K, L) audio; VLM batches add ``patch_embeds``]."""
    logits, aux = transformer.forward(cfg, params, batch["tokens"],
                                      prefix_embeds=batch.get("patch_embeds"))
    labels = batch["labels"]
    if cfg.modality == "audio_codec":
        # logits (B, T, K, V); labels (B, K, T)
        labels = jnp.moveaxis(labels, 1, 2)
    else:
        # VLM: score text positions only (logits cover [vision; text])
        if logits.shape[1] != labels.shape[1]:
            logits = logits[:, logits.shape[1] - labels.shape[1]:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll) + aux
    return loss, {"loss": loss, "nll": jnp.mean(nll), "aux": aux}


def make_train_step(cfg: ModelConfig, ocfg: Optional[opt_lib.AdamWConfig] = None):
    ocfg = ocfg or opt_lib.AdamWConfig()

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(state.params)
        new_params, new_opt = opt_lib.update(ocfg, grads, state.opt, state.params)
        metrics = dict(metrics, grad_norm=opt_lib.global_norm(grads),
                       lr=opt_lib.schedule(ocfg, new_opt.step))
        return TrainState(new_params, new_opt), metrics

    return train_step


def train(cfg: ModelConfig, data: Iterator[Dict[str, jax.Array]],
          num_steps: int, seed: int = 0,
          ocfg: Optional[opt_lib.AdamWConfig] = None,
          log_every: int = 10) -> Tuple[TrainState, list]:
    """Single-host driver used by the examples and integration tests."""
    state = init_state(cfg, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    history = []
    t0 = time.perf_counter()
    for i in range(num_steps):
        batch = next(data)
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall"] = time.perf_counter() - t0
            history.append(m)
    return state, history
