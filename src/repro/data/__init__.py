"""Data pipeline: synthetic, deterministic, shardable token streams."""
from repro.data import pipeline

__all__ = ["pipeline"]
