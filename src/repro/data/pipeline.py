"""Synthetic data pipeline.

Deterministic per-step token batches (a Zipfian unigram stream with local
n-gram structure so losses actually decrease), plus the modality extras the
zoo needs (vision patch embeddings, audio codebook tokens).  Batches are
host-local numpy; the launcher shards them onto the mesh with
``jax.device_put`` + NamedSharding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0


def _zipf_tokens(rng: np.random.Generator, vocab: int, shape) -> np.ndarray:
    """Zipf-ish unigram distribution (bounded to vocab)."""
    ranks = rng.zipf(1.3, size=shape)
    return (ranks % vocab).astype(np.int32)


def synthetic_batch(cfg: ModelConfig, dcfg: DataConfig, step: int
                    ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(dcfg.seed * 100_003 + step)
    if cfg.modality == "audio_codec":
        toks = _zipf_tokens(rng, cfg.vocab_size,
                            (dcfg.batch, cfg.num_codebooks, dcfg.seq_len + 1))
        batch = {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}
    else:
        toks = _zipf_tokens(rng, cfg.vocab_size, (dcfg.batch, dcfg.seq_len + 1))
        # inject learnable bigram structure: token[t+1] == token[t] sometimes
        rep = rng.random((dcfg.batch, dcfg.seq_len + 1)) < 0.3
        for b in range(dcfg.batch):
            idx = np.nonzero(rep[b][1:])[0] + 1
            toks[b][idx] = toks[b][idx - 1]
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.modality == "vision":
        batch["patch_embeds"] = rng.standard_normal(
            (dcfg.batch, cfg.vision_tokens, cfg.vision_embed_dim),
            dtype=np.float32) * 0.02
    return batch


def iterator(cfg: ModelConfig, dcfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    step = 0
    while True:
        yield synthetic_batch(cfg, dcfg, step)
        step += 1


def batch_spec(cfg: ModelConfig, dcfg: DataConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the dry-run (mirrors synthetic_batch)."""
    if cfg.modality == "audio_codec":
        shape = (dcfg.batch, cfg.num_codebooks, dcfg.seq_len)
    else:
        shape = (dcfg.batch, dcfg.seq_len)
    out = {"tokens": jax.ShapeDtypeStruct(shape, jnp.int32),
           "labels": jax.ShapeDtypeStruct(shape, jnp.int32)}
    if cfg.modality == "vision":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (dcfg.batch, cfg.vision_tokens, cfg.vision_embed_dim), jnp.float32)
    return out
