"""Gemma 2 9B [arXiv:2408.00118].

42 layers, d_model 3584, 16 heads (GQA kv=8, head_dim 256), d_ff 14336,
vocab 256000; alternating local (window 4096) / global attention; attention
softcap 50, final-logit softcap 30; tied embeddings; RoPE theta 10000.
"""
from repro.configs._smoke import make_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    layer_pattern=("attn_local:dense", "attn:dense"),
    window_size=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    rope_theta=10000.0,
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2408.00118",
)

SMOKE = make_smoke(CONFIG)
