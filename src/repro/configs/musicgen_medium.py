"""MusicGen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48 layers, d_model 1536, 24 heads (MHA, kv=24), d_ff 6144; 4 codebooks of
2048 entries with the delay interleave pattern.  The EnCodec codec is a
STUB per the brief: inputs are precomputed frame tokens (B, K=4, T).
"""
from repro.configs._smoke import make_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    layer_pattern=("attn:dense",),
    modality="audio_codec",
    num_codebooks=4,
    source="arXiv:2306.05284",
)

SMOKE = make_smoke(CONFIG, num_codebooks=4)
