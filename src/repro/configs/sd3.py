"""Stable Diffusion 3 Medium pipeline [arXiv:2403.03206 / Table 2].

Encode: T5-XXL-style bidirectional encoder (~4.8B); Diffuse: Sd3-DiT ~2B;
Decode: AE-KL ~0.1B.  Denoising steps 20 (Table 5).  Full config is
dry-run-only; SMOKE is the CPU-runnable reduced pipeline.
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.diffusion import DecoderConfig, DiTConfig
from repro.models.pipeline import PipelineConfig

_ENCODER = ModelConfig(
    name="t5-xxl-enc", family="dense", num_layers=24, d_model=4096,
    num_heads=64, num_kv_heads=64, d_ff=10240, vocab_size=32128,
    layer_pattern=("attn_bidir:dense",), source="T5-XXL [arXiv:1910.10683]")

_DIT = DiTConfig(name="sd3-dit", num_layers=24, d_model=1536, num_heads=24,
                 d_ff=6144, latent_dim=64, cond_dim=4096,
                 source="arXiv:2403.03206")

_DEC = DecoderConfig(name="ae-kl", latent_channels=16, base_channels=512,
                     source="AutoencoderKL")

CONFIG = PipelineConfig(name="sd3", encoder=_ENCODER, dit=_DIT, decoder=_DEC,
                        num_steps=20, source="stabilityai/stable-diffusion-3-medium")

SMOKE = PipelineConfig(
    name="sd3-smoke",
    encoder=dataclasses.replace(_ENCODER, num_layers=2, d_model=128,
                                num_heads=4, num_kv_heads=4, head_dim=32,
                                d_ff=256, vocab_size=256, dtype=jnp.float32,
                                name="t5-smoke"),
    dit=dataclasses.replace(_DIT, num_layers=2, d_model=128, num_heads=4,
                            d_ff=256, latent_dim=16, cond_dim=128,
                            dtype=jnp.float32, name="sd3-dit-smoke"),
    decoder=dataclasses.replace(_DEC, latent_channels=4, base_channels=32,
                                dtype=jnp.float32, name="ae-smoke"),
    num_steps=3)
