"""Llama 4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family].

48 layers, d_model 5120, 40 heads (GQA kv=8, head_dim 128), vocab 202048.
MoE: 128 routed experts, top-1, per-expert hidden 8192, plus one shared
expert; MoE interleaved every other layer.  Attention is iRoPE-style:
chunked-local (chunk 8192) with every 4th layer global — which is what makes
long_500k serving feasible.
"""
from repro.configs._smoke import make_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    layer_pattern=(
        "attn_chunked:moe",
        "attn_chunked:dense",
        "attn_chunked:moe",
        "attn:dense",
    ),
    chunk_size=8192,
    num_experts=128,
    num_shared_experts=1,
    experts_per_token=1,
    moe_d_ff=8192,
    rope_theta=5e5,
    qk_norm=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = make_smoke(CONFIG)
