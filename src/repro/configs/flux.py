"""Flux.1 pipeline [arXiv:2506.15742 / black-forest-labs/flux, Table 2].

Encode: T5-XXL (~4.8B); Diffuse: Flux-DiT ~12B (the released model is
19 double + 38 single MMDiT blocks at d=3072; we use 56 uniform joint
blocks at d=3072 — same d_model/heads/FLOP scale, single-stream); Decode:
AE-KL ~0.1B.  Denoising steps 4 (schnell schedule, Table 5).
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.diffusion import DecoderConfig, DiTConfig
from repro.models.pipeline import PipelineConfig

_ENCODER = ModelConfig(
    name="t5-xxl-enc", family="dense", num_layers=24, d_model=4096,
    num_heads=64, num_kv_heads=64, d_ff=10240, vocab_size=32128,
    layer_pattern=("attn_bidir:dense",), source="T5-XXL [arXiv:1910.10683]")

_DIT = DiTConfig(name="flux-dit", num_layers=56, d_model=3072, num_heads=24,
                 d_ff=12288, latent_dim=64, cond_dim=4096,
                 source="black-forest-labs/FLUX.1-schnell")

_DEC = DecoderConfig(name="ae-kl", latent_channels=16, base_channels=512,
                     source="AutoencoderKL")

CONFIG = PipelineConfig(name="flux", encoder=_ENCODER, dit=_DIT, decoder=_DEC,
                        num_steps=4, source="black-forest-labs/flux")

SMOKE = PipelineConfig(
    name="flux-smoke",
    encoder=dataclasses.replace(_ENCODER, num_layers=2, d_model=128,
                                num_heads=4, num_kv_heads=4, head_dim=32,
                                d_ff=256, vocab_size=256, dtype=jnp.float32,
                                name="t5-smoke"),
    dit=dataclasses.replace(_DIT, num_layers=2, d_model=128, num_heads=4,
                            d_ff=256, latent_dim=16, cond_dim=128,
                            dtype=jnp.float32, name="flux-dit-smoke"),
    decoder=dataclasses.replace(_DEC, latent_channels=4, base_channels=32,
                                dtype=jnp.float32, name="ae-smoke"),
    num_steps=2)
