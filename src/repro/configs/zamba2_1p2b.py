"""Zamba2 1.2B [arXiv:2411.15242].

38 layers, d_model 2048, Mamba2 backbone (state 64) with interleaved
attention blocks (32 heads, kv=32, d_ff 8192), vocab 32000.

Simplification vs the released model: Zamba2 re-uses *one shared* attention
block with per-use LoRA specialization; here each interleaved attention
block has its own parameters (the compute/communication shape — what the
serving system and dry-run reason about — is identical).  Pattern: five
Mamba2 layers then one attention+MLP block, cycled.
"""
from repro.configs._smoke import make_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    layer_pattern=("mamba2:none",) * 5 + ("attn:dense",),
    ssm_state_dim=64,
    ssm_heads=64,          # d_inner 4096 / head_dim 64
    ssm_expand=2,
    ssm_conv=4,
    source="arXiv:2411.15242",
)

SMOKE = make_smoke(CONFIG, layer_pattern=("mamba2:none", "attn:dense"))
