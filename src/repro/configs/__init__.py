"""Config registry: assigned architectures + the paper's diffusion pipelines.

``get(arch_id)`` returns the full published config; ``get_smoke(arch_id)``
returns a reduced same-family variant (2 layers, d_model<=512, <=4 experts)
used by the CPU smoke tests.  The full configs are only ever exercised via
``.lower().compile()`` dry-runs (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

ARCH_IDS = (
    "gemma2-9b",
    "zamba2-1.2b",
    "yi-34b",
    "starcoder2-15b",
    "rwkv6-3b",
    "internvl2-2b",
    "deepseek-moe-16b",
    "yi-9b",
    "llama4-maverick-400b-a17b",
    "musicgen-medium",
)

PIPELINE_IDS = ("sd3", "flux", "cogvideox", "hunyuanvideo")

_MODULES = {
    "gemma2-9b": "gemma2_9b",
    "zamba2-1.2b": "zamba2_1p2b",
    "yi-34b": "yi_34b",
    "starcoder2-15b": "starcoder2_15b",
    "rwkv6-3b": "rwkv6_3b",
    "internvl2-2b": "internvl2_2b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "yi-9b": "yi_9b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "musicgen-medium": "musicgen_medium",
    "sd3": "sd3",
    "flux": "flux",
    "cogvideox": "cogvideox",
    "hunyuanvideo": "hunyuanvideo",
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str):
    return _module(arch_id).SMOKE


# --- Input shapes (assigned) -------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
