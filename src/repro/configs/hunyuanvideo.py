"""HunyuanVideo pipeline [arXiv:2412.03603 / Table 2].

Encode: Llama3-8B-style causal encoder (~8B); Diffuse: HYV-DiT ~13B
(released: 20 double + 40 single blocks at d=3072; we use 64 uniform joint
blocks); Decode: AE-KL-HYV ~0.5B.  Video latents.  Steps 6 (FastHunyuan).
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.diffusion import DecoderConfig, DiTConfig
from repro.models.pipeline import PipelineConfig

_ENCODER = ModelConfig(
    name="llama3-8b-enc", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=128256,
    layer_pattern=("attn:dense",), rope_theta=5e5,
    source="Llama 3 [arXiv:2407.21783]")

_DIT = DiTConfig(name="hyv-dit", num_layers=64, d_model=3072, num_heads=24,
                 d_ff=12288, latent_dim=64, cond_dim=4096,
                 source="tencent/HunyuanVideo")

_DEC = DecoderConfig(name="ae-kl-hyv", latent_channels=16, base_channels=512,
                     res_blocks=4,
                     source="AutoencoderKL-HunyuanVideo")

CONFIG = PipelineConfig(name="hunyuanvideo", encoder=_ENCODER, dit=_DIT,
                        decoder=_DEC, num_steps=6, is_video=True,
                        source="tencent/HunyuanVideo")

SMOKE = PipelineConfig(
    name="hunyuanvideo-smoke",
    encoder=dataclasses.replace(_ENCODER, num_layers=2, d_model=128,
                                num_heads=4, num_kv_heads=2, head_dim=32,
                                d_ff=256, vocab_size=256, dtype=jnp.float32,
                                name="llama-smoke"),
    dit=dataclasses.replace(_DIT, num_layers=2, d_model=128, num_heads=4,
                            d_ff=256, latent_dim=16, cond_dim=128,
                            dtype=jnp.float32, name="hyv-dit-smoke"),
    decoder=dataclasses.replace(_DEC, latent_channels=4, base_channels=32,
                                dtype=jnp.float32, name="ae-smoke"),
    num_steps=2, is_video=True)
