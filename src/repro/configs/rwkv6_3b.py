"""RWKV6 "Finch" 3B [arXiv:2404.05892] — attention-free, data-dependent decay.

32 layers, d_model 2560, vocab 65536; 40 heads of dim 64; channel-mix hidden
3.5x = 8960 (matches the published d_ff).  The paper's Ulysses-SP technique
is inapplicable (no attention heads to all-to-all); sequence parallelism for
this arch is chunked-scan parallelism — see DESIGN.md §Arch-applicability.
"""
from repro.configs._smoke import make_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    layer_pattern=("rwkv6:none",),
    ssm_heads=40,          # head_dim 64
    source="arXiv:2404.05892",
)

SMOKE = make_smoke(CONFIG)
