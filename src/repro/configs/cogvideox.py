"""CogVideoX1.5-5B pipeline [arXiv:2408.06072 / Table 2].

Encode: T5 (~0.35B per Table 2); Diffuse: Cog-DiT ~4.2B; Decode:
AE-KL-Cog ~0.45B.  Video latents (4x temporal compression).  Steps 6.
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.diffusion import DecoderConfig, DiTConfig
from repro.models.pipeline import PipelineConfig

_ENCODER = ModelConfig(
    name="t5-enc-small", family="dense", num_layers=12, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=32128,
    layer_pattern=("attn_bidir:dense",), source="T5 [arXiv:1910.10683]")

_DIT = DiTConfig(name="cog-dit", num_layers=25, d_model=3072, num_heads=48,
                 d_ff=12288, latent_dim=64, cond_dim=1024,
                 source="zai-org/CogVideoX1.5-5B")

_DEC = DecoderConfig(name="ae-kl-cog", latent_channels=16, base_channels=512,
                     res_blocks=4,
                     source="AutoencoderKL-CogVideoX")

CONFIG = PipelineConfig(name="cogvideox", encoder=_ENCODER, dit=_DIT,
                        decoder=_DEC, num_steps=6, is_video=True,
                        source="zai-org/CogVideoX1.5-5B")

SMOKE = PipelineConfig(
    name="cogvideox-smoke",
    encoder=dataclasses.replace(_ENCODER, num_layers=2, d_model=128,
                                num_heads=4, num_kv_heads=4, head_dim=32,
                                d_ff=256, vocab_size=256, dtype=jnp.float32,
                                name="t5-smoke"),
    dit=dataclasses.replace(_DIT, num_layers=2, d_model=128, num_heads=4,
                            d_ff=256, latent_dim=16, cond_dim=128,
                            dtype=jnp.float32, name="cog-dit-smoke"),
    decoder=dataclasses.replace(_DEC, latent_channels=4, base_channels=32,
                                dtype=jnp.float32, name="ae-smoke"),
    num_steps=2, is_video=True)
