"""Yi-34B [arXiv:2403.04652] — llama-architecture GQA dense model.

60 layers, d_model 7168, 56 heads (GQA kv=8, head_dim 128), d_ff 20480,
vocab 64000, RoPE theta 5e6.
"""
from repro.configs._smoke import make_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    layer_pattern=("attn:dense",),
    rope_theta=5e6,
    source="arXiv:2403.04652",
)

SMOKE = make_smoke(CONFIG)
