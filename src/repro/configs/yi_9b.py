"""Yi-9B [arXiv:2403.04652] — llama-architecture GQA dense model.

48 layers, d_model 4096, 32 heads (GQA kv=4, head_dim 128), d_ff 11008,
vocab 64000.
"""
from repro.configs._smoke import make_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    layer_pattern=("attn:dense",),
    rope_theta=5e6,
    source="arXiv:2403.04652",
)

SMOKE = make_smoke(CONFIG)
