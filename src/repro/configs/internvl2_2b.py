"""InternVL2-2B [arXiv:2404.16821] — InternViT-300M + InternLM2-1.8B.

The LM backbone: 24 layers, d_model 2048, 16 heads (GQA kv=8), d_ff 8192,
vocab 92553.  The vision frontend is a STUB per the brief: ``input_specs``
supplies 256 patch embeddings of 1024 dims (one 448px tile after
pixel-shuffle), projected into the LM by a learned projector.
"""
from repro.configs._smoke import make_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    layer_pattern=("attn:dense",),
    modality="vision",
    vision_tokens=256,
    vision_embed_dim=1024,
    rope_theta=1e6,
    source="arXiv:2404.16821",
)

SMOKE = make_smoke(CONFIG)
