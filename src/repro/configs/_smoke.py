"""Shared helper to derive reduced same-family smoke variants."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig


def make_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """2 layers, d_model<=512, <=4 experts; same layer family/pattern."""
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    upd = dict(
        num_layers=max(2, len(cfg.layer_pattern)) if len(cfg.layer_pattern) <= 2 else len(cfg.layer_pattern),
        d_model=128,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=32,
        d_ff=256,
        vocab_size=min(cfg.vocab_size, 512),
        window_size=min(cfg.window_size, 16),
        chunk_size=min(cfg.chunk_size, 16),
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=min(cfg.moe_d_ff, 64) if cfg.moe_d_ff else 0,
        ssm_state_dim=min(cfg.ssm_state_dim, 16),
        ssm_heads=4 if cfg.resolved_ssm_heads else 0,
        vision_tokens=min(cfg.vision_tokens, 8),
        vision_embed_dim=min(cfg.vision_embed_dim, 64) if cfg.vision_embed_dim else 0,
        dtype=jnp.float32,
        name=cfg.name + "-smoke",
    )
    upd.update(overrides)
    # keep num_layers == 2 when the pattern is length<=2; otherwise one cycle
    if len(cfg.layer_pattern) <= 2:
        upd["num_layers"] = 2
    else:
        upd["num_layers"] = len(cfg.layer_pattern) if len(cfg.layer_pattern) <= 8 else 2
        if upd["num_layers"] == 2:
            upd["layer_pattern"] = cfg.layer_pattern[:2]
    return dataclasses.replace(cfg, **upd)
