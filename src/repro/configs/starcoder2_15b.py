"""StarCoder2-15B [arXiv:2402.19173].

40 layers, d_model 6144, 48 heads (GQA kv=4, head_dim 128), d_ff 24576,
vocab 49152; RoPE; sliding-window attention (w=4096) per the StarCoder2
training recipe — which is what makes long_500k serving feasible for this
otherwise-dense architecture.
"""
from repro.configs._smoke import make_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    layer_pattern=("attn_local:dense",),
    window_size=4096,
    rope_theta=1e5,
    source="arXiv:2402.19173",
)

SMOKE = make_smoke(CONFIG)
