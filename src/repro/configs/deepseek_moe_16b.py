"""DeepSeekMoE 16B [arXiv:2401.06066] — fine-grained experts.

28 layers, d_model 2048, 16 heads (kv=16), vocab 102400.  Layer 0 uses a
dense FFN (d_ff 10944); layers 1..27 use MoE with 64 routed experts
(per-expert hidden 1408, top-6) + 2 shared experts.
"""
from repro.configs._smoke import make_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,
    vocab_size=102400,
    layer_pattern=("attn:dense",) + ("attn:moe",) * 27,
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    source="arXiv:2401.06066",
)

SMOKE = make_smoke(CONFIG, layer_pattern=("attn:dense", "attn:moe"))
