"""Ulysses sequence parallelism via shard_map (the paper's SP mechanism).

DeepSpeed-Ulysses [arXiv:2309.14509]: activations enter sharded on the
*sequence* dim; an all-to-all re-shards them on the *head* dim for the
attention core (each device holds H/k full-length heads), and a second
all-to-all restores sequence sharding.  On TPU both all-to-alls map 1:1
onto ``jax.lax.all_to_all`` over the model axis — this is the φ_s =
"ulysses" parallel config a dispatch plan requests.

For the attention-free SSM architectures (rwkv6, mamba2) Ulysses is
inapplicable; ``scan_chunk_parallel`` is the substitute: devices hold
sequence chunks and chain recurrent states with a ppermute ladder.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels import ops as kops

Array = jax.Array


def _pvary(x: Array, axes: Tuple[str, ...]) -> Array:
    """``jax.lax.pvary`` marks a replicated value as device-varying for
    shard_map's replication checker; on older jax (< 0.6) the primitive
    does not exist and the check accepts the raw value."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axes) if fn is not None else x


def ulysses_attention(q: Array, k: Array, v: Array, mesh: Mesh,
                      axis: str = "model", causal: bool = False,
                      softcap: float = 0.0) -> Array:
    """q/k/v: (B, L, H, D) sharded on L over ``axis``; H % axis_size == 0.

    Returns attention output sharded on L again.
    """
    n = mesh.shape[axis]
    assert q.shape[2] % n == 0, f"heads {q.shape[2]} % {n} != 0"

    def body(qs, ks, vs):
        # (B, L/n, H, D) -> all-to-all -> (B, L, H/n, D)
        a2a = lambda x: jax.lax.all_to_all(x, axis, split_axis=2, concat_axis=1,
                                           tiled=True)
        qh, kh, vh = a2a(qs), a2a(ks), a2a(vs)
        out = kops.flash_attention(qh, kh, vh, causal=causal, softcap=softcap,
                                   use_kernel=False)
        # (B, L, H/n, D) -> back to sequence sharding (B, L/n, H, D)
        return jax.lax.all_to_all(out, axis, split_axis=1, concat_axis=2,
                                  tiled=True)

    spec = P(None, axis, None, None)
    return shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec)(q, k, v)


def scan_chunk_parallel(q: Array, k: Array, v: Array, decay: Array,
                        mesh: Mesh, axis: str = "model",
                        bonus: Optional[Array] = None) -> Tuple[Array, Array]:
    """Sequence-chunk parallel gated linear scan (SSM SP substitute).

    Inputs (B, H, L, K) sharded on L.  Each device runs the chunked scan on
    its local chunk from a zero state, then states are corrected with a
    sequential ppermute ladder: device i receives the accumulated state of
    devices < i, decayed by its chunk's total decay product.
    """
    n = mesh.shape[axis]

    def body(qs, ks, vs, ws):
        bb, hh, _, kk = qs.shape
        vv = vs.shape[-1]
        zero = _pvary(jnp.zeros((bb, hh, kk, vv), jnp.float32), (axis,))
        _, s_local = kops.linear_scan(qs, ks, vs, ws, bonus=bonus,
                                      initial_state=zero)
        # total decay of the local chunk per (B, H, K)
        dtot = jnp.exp(jnp.sum(jnp.log(jnp.clip(ws.astype(jnp.float32),
                                                1e-30)), axis=2))
        # prefix ladder: prefix_i = dtot_{i-1} * prefix_{i-1} + S_{i-1};
        # telescoped with n-1 right-shifts (device 0 receives zeros)
        carry = jnp.zeros_like(s_local)
        perm = [(i, i + 1) for i in range(n - 1)]
        for _ in range(max(0, n - 1)):
            msg = dtot[..., None] * carry + s_local
            carry = jax.lax.ppermute(msg, axis, perm=perm)
        # redo the local scan seeded with the exact prefix state
        out, s_final = kops.linear_scan(qs, ks, vs, ws, bonus=bonus,
                                        initial_state=carry)
        return out, s_final[None]

    spec_l = P(None, None, axis, None)
    out, s = shard_map(body, mesh=mesh,
                       in_specs=(spec_l, spec_l, spec_l, spec_l),
                       out_specs=(spec_l, P(axis, None, None, None, None)))(
        q, k, v, decay)
    return out, s[-1]
