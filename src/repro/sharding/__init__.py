"""Distribution: partition specs per architecture + sequence parallelism."""
from repro.sharding import partition, sequence_parallel

__all__ = ["partition", "sequence_parallel"]
