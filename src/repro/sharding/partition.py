"""Partition rules: parameter/optimizer/batch/cache PartitionSpecs.

Conventions (single-pod mesh ("data", "model"); multi-pod prepends "pod"):

* tensor parallelism on ``model``: attention/ffn projections shard their
  hidden dimension; embeddings shard the vocab; MoE experts shard the
  expert dimension (expert parallelism);
* ``data`` (x ``pod``) carries the batch; decode caches shard sequence
  across whatever axes the batch does not use (flash-decoding style — the
  softmax max/sum over the sharded axis lowers to small all-reduces);
* per-head scalars, norms, and small LoRA/conv params replicate.

Rules are name-based over the param tree paths, so one function covers all
ten architectures.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

PyTree = Any

# leaf-name -> (spec for the *unstacked* layer param)
_COL = "col"     # shard last (output) dim on model
_ROW = "row"     # shard first (input/contraction) dim on model
_EXP = "expert"  # shard leading expert dim on model
_REP = "rep"

_RULES: Dict[str, str] = {
    # embeddings / heads
    "embed": "vocab_in",
    "lm_head": "vocab_out",
    "codebook_embed": "cb_embed",
    "codebook_head": "cb_head",
    "vision_proj": _REP,
    # attention
    "wq": _COL, "wk": _COL, "wv": _COL, "wo": _ROW,
    # dense ffn
    "w_gate": _COL, "w_up": _COL, "w_down": _ROW,
    # moe
    "router": _REP,
    "shared_gate": _COL, "shared_up": _COL, "shared_down": _ROW,
    # mamba2
    "in_proj": _COL, "out_proj": _ROW,
    "conv_w": "conv", "conv_b": "conv_b",
    "A_log": _REP, "D": _REP, "dt_bias": _REP, "norm_w": "vec_model",
    # rwkv6
    "w_r": _COL, "w_k": _COL, "w_v": _COL, "w_g": _COL, "w_o": _ROW,
    "decay_w0": _REP, "decay_A": _REP, "decay_B": _COL,
    "bonus_u": _REP, "mu": _REP, "cm_mu": _REP,
    "ln_w": _REP, "ln_b": _REP,
    "cm_rk": _COL, "cm_kv": _COL, "cm_vo": _ROW,
    # norms / misc
    "ln1": _REP, "ln2": _REP, "q_norm": _REP, "k_norm": _REP,
    "final_norm": _REP,
}

# moe expert tensors are distinguished by path ("moe" ancestor)
_MOE_EXPERT_LEAVES = {"w_gate", "w_up", "w_down"}


def _leaf_rule(path) -> Tuple[str, bool, bool]:
    """(rule, is_stacked_layer_param, is_moe_expert)."""
    names = [p.key for p in path if hasattr(p, "key")]
    stacked = "blocks" in names
    leaf = names[-1]
    moe = "moe" in names and leaf in _MOE_EXPERT_LEAVES
    return _RULES.get(leaf, _REP), stacked, moe


def _spec_for(rule: str, ndim: int, stacked: bool, moe: bool, model: str
              ) -> P:
    lead = (None,) if stacked else ()
    if moe:
        # (E, D, F) / (E, F, D): expert parallelism on the expert dim
        return P(*lead, model, None, None)
    base = {
        _COL: (None, model),
        _ROW: (model, None),
        "vocab_in": (model, None),
        "vocab_out": (None, model),
        "cb_embed": (None, model, None),
        "cb_head": (None, None, model),
        "conv": (None, model),
        "conv_b": (model,),
        "vec_model": (model,),
        _REP: tuple([None] * (ndim - len(lead))),
    }[rule]
    spec = lead + base
    assert len(spec) == ndim, (rule, ndim, spec)
    return P(*spec)


def param_specs(cfg: ModelConfig, params_shape: PyTree, *,
                model_axis: str = "model") -> PyTree:
    """PartitionSpec tree matching ``params_shape`` (from jax.eval_shape)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        rule, stacked, moe = _leaf_rule(path)
        spec = _spec_for(rule, leaf.ndim, stacked, moe, model_axis)
        # divisibility guard: replicate any axis that does not divide
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def validate_divisibility(specs: PyTree, shapes: PyTree, mesh: Mesh) -> PyTree:
    """Replace specs whose sharded dims don't divide the mesh axis size
    (e.g. 56 heads on a 16-way model axis shards the fused H*Dh dim
    instead — if even that fails, replicate)."""
    def fix(spec: P, leaf):
        out = []
        for dim, ax in enumerate(tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            if ax is None:
                out.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            out.append(ax if leaf.shape[dim] % size == 0 else None)
        return P(*out)

    return jax.tree_util.tree_map(
        fix, specs, shapes,
        is_leaf=lambda x: isinstance(x, P))


def zero_shard(specs: PyTree, shapes: PyTree, mesh: Mesh,
               data_axis: str = "data") -> PyTree:
    """ZeRO-style sharding: additionally shard each tensor's largest
    still-replicated dim over the data axis (when divisible).  Applied to
    the AdamW moments (and optionally params = FSDP) it removes the
    dominant optimizer-state term from peak memory at the cost of
    per-step (reduce-)scatter/gather collectives."""
    dsize = mesh.shape[data_axis]

    def fix(spec: P, leaf):
        dims = tuple(spec) + (None,) * (leaf.ndim - len(spec))
        cands = [i for i, ax in enumerate(dims)
                 if ax is None and leaf.shape[i] % dsize == 0
                 and leaf.shape[i] >= dsize]
        if not cands:
            return P(*dims)
        best = max(cands, key=lambda i: leaf.shape[i])
        out = list(dims)
        out[best] = data_axis
        return P(*out)

    return jax.tree_util.tree_map(fix, specs, shapes,
                                  is_leaf=lambda x: isinstance(x, P))


def state_specs(cfg: ModelConfig, state_shape, *, model_axis: str = "model",
                zero_mesh: Optional[Mesh] = None, fsdp: bool = False):
    """TrainState specs: params + AdamW moments share layouts; step scalar
    replicates.  ``zero_mesh`` enables ZeRO sharding of the f32 moments
    over the data axis; ``fsdp`` extends it to the params."""
    from repro.training.loop import TrainState
    from repro.training.optimizer import AdamWState
    pspec = param_specs(cfg, state_shape.params, model_axis=model_axis)
    mspec = param_specs(cfg, state_shape.opt.mu, model_axis=model_axis)
    nspec = param_specs(cfg, state_shape.opt.nu, model_axis=model_axis)
    if zero_mesh is not None:
        mspec = zero_shard(mspec, state_shape.opt.mu, zero_mesh)
        nspec = zero_shard(nspec, state_shape.opt.nu, zero_mesh)
        if fsdp:
            pspec = zero_shard(pspec, state_shape.params, zero_mesh)
    return TrainState(params=pspec,
                      opt=AdamWState(step=P(), mu=mspec, nu=nspec))


def batch_specs(batch_shape: Dict[str, Any], data_axes) -> Dict[str, P]:
    """Shard the batch dimension across the data(+pod) axes."""
    return {k: P(data_axes, *([None] * (v.ndim - 1)))
            for k, v in batch_shape.items()}


def cache_specs(cfg: ModelConfig, cache_shape: PyTree, batch: int,
                mesh: Mesh, data_axes, model_axis: str = "model") -> PyTree:
    """Decode-cache specs.

    KV tensors ("k"/"v": (count, B, S, Hkv, Dh), "pos": (count, B, S)):
    batch shards on the data axes when divisible; the sequence dim shards
    on ``model`` — and on data+model when B=1 (long_500k flash-decoding
    layout).  SSM/conv/shift states shard batch only.
    """
    daxes = data_axes if isinstance(data_axes, tuple) else (data_axes,)
    data_size = 1
    for a in daxes:
        data_size *= mesh.shape[a]
    batch_ok = batch % data_size == 0 and batch >= data_size
    b_ax = (data_axes if batch_ok else None)
    s_ax = (model_axis if batch_ok else (*daxes, model_axis))

    def spec(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        leaf_name = names[-1] if names else ""
        if leaf_name in ("k", "v"):          # (count, B, S, Hkv, Dh)
            sa = s_ax if leaf.shape[2] % (data_size * mesh.shape[model_axis]
                                          if not batch_ok else
                                          mesh.shape[model_axis]) == 0 else None
            return P(None, b_ax, sa, None, None)
        if leaf_name == "pos":               # (count, B, S)
            sa = s_ax if leaf.shape[2] % (data_size * mesh.shape[model_axis]
                                          if not batch_ok else
                                          mesh.shape[model_axis]) == 0 else None
            return P(None, b_ax, sa)
        return P(None, b_ax, *([None] * (leaf.ndim - 2)))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def named(tree_specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
