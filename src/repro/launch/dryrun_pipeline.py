import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Paper-side dry-run: lower the diffusion pipeline *stages* (the models
TridentServe actually serves) on the production mesh.

For each pipeline and a representative request class, lowers one Diffuse
denoise step (the unit the dispatcher's t_{r,i,k} measures) and one Decode
pass, with DiT params TP-sharded and latents sharded over data x model
(Ulysses-style sequence split on the joint stream).

  PYTHONPATH=src python -m repro.launch.dryrun_pipeline --out results/dryrun_pipelines.jsonl
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.launch import mesh as mesh_lib
from repro.models import diffusion
from repro.roofline import analysis as ra
from repro.roofline import hlo as hlo_mod

CASES = {
    "sd3": (1024, 0.0, 16),
    "flux": (2048, 0.0, 16),
    "cogvideox": (720, 4.0, 16),
    "hunyuanvideo": (720, 4.0, 16),
}


def _div_axis(size: int, axis: str, mesh) -> object:
    return axis if size % mesh.shape[axis] == 0 else None


def _dit_param_specs(shapes):
    def spec(path, leaf):
        names = [getattr(p, "key", "") for p in path]
        leaf_name = names[-1]
        lead = (None,) if "layers" in names else ()
        col = {"wq", "wk", "wv", "w_up", "mod"}
        row = {"wo", "w_down"}
        if leaf_name in col:
            return P(*lead, None, "model")
        if leaf_name in row:
            return P(*lead, "model", None)
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(spec, shapes)


def run_case(pid: str, out_path):
    res, sec, batch = CASES[pid]
    cfg = configs.get(pid)
    mesh = mesh_lib.make_production_mesh(multi_pod=False)
    chips = mesh.size
    lt = cfg.latent_tokens(res, sec)
    key = jax.random.PRNGKey(0)

    for stage, build in (("D", "dit"), ("C", "decoder")):
        rec = {"arch": f"{pid}-{'dit' if stage == 'D' else 'ae'}",
               "shape": f"{res}x{sec}", "mesh": "16x16", "kind": "serve"}
        t0 = time.perf_counter()
        try:
            if stage == "D":
                shapes = jax.eval_shape(lambda k: diffusion.init(cfg.dit, k), key)
                pspec = _dit_param_specs(shapes)
                lat = jax.ShapeDtypeStruct((batch, lt, cfg.dit.latent_dim),
                                           jnp.float32)
                t = jax.ShapeDtypeStruct((batch,), jnp.float32)
                cond = jax.ShapeDtypeStruct((batch, 77, cfg.dit.cond_dim),
                                            jnp.float32)
                fn = lambda p, x, tt, c: diffusion.forward(cfg.dit, p, x, tt, c)
                in_sh = (jax.tree_util.tree_map(
                            lambda s: NamedSharding(mesh, s), pspec,
                            is_leaf=lambda x: isinstance(x, P)),
                         NamedSharding(mesh, P(
                             _div_axis(batch, "data", mesh),
                             _div_axis(lt, "model", mesh), None)),
                         NamedSharding(mesh, P(_div_axis(batch, "data", mesh))),
                         NamedSharding(mesh, P(
                             _div_axis(batch, "data", mesh), None, None)))
                args = (shapes, lat, t, cond)
                n_params = sum(int(x.size) for x in
                               jax.tree_util.tree_leaves(shapes))
                mf = 2.0 * n_params * batch * (lt + 77)
            else:
                shapes = jax.eval_shape(
                    lambda k: diffusion.init_decoder(cfg.decoder, k), key)
                f, h, w = cfg.latent_grid(res, sec)
                z = jax.ShapeDtypeStruct(
                    (batch * f, 2 * h, 2 * w, cfg.decoder.latent_channels),
                    jnp.float32)
                fn = lambda p, zz: diffusion.decode_latent(cfg.decoder, p, zz)
                bf = batch * f
                in_sh = (None, NamedSharding(mesh, P(
                    ("data", "model") if bf % chips == 0 else
                    _div_axis(bf, "data", mesh),
                    _div_axis(2 * h, "model", mesh)
                    if bf % chips != 0 else None, None, None)))
                args = (shapes, z)
                n_params = sum(int(x.size) for x in
                               jax.tree_util.tree_leaves(shapes))
                mf = 2.0 * n_params * batch * f * 4 * h * w
            with mesh:
                compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
            mc = hlo_mod.module_costs(compiled.as_text(), chips)
            try:
                ma = compiled.memory_analysis()
                peak = int(getattr(ma, "temp_size_in_bytes", 0)
                           + getattr(ma, "argument_size_in_bytes", 0))
            except Exception:
                peak = 0
            roof = ra.Roofline(arch=rec["arch"], shape=rec["shape"],
                               mesh="16x16", chips=chips, hlo_flops=mc.flops,
                               hlo_bytes=mc.hbm_bytes,
                               coll_bytes=mc.collective_wire_bytes / chips,
                               model_flops=mf,
                               coll_counts=mc.collective_counts,
                               peak_mem_bytes=peak)
            rec.update(status="ok",
                       t_compile_s=round(time.perf_counter() - t0, 1),
                       t_compute_s=roof.t_compute, t_memory_s=roof.t_memory,
                       t_collective_s=roof.t_collective,
                       bottleneck=roof.bottleneck,
                       useful_ratio=roof.useful_ratio,
                       peak_mem_per_device=peak, model_flops=mf,
                       hlo_flops_per_device=mc.flops,
                       hlo_bytes_per_device=mc.hbm_bytes,
                       coll_counts=mc.collective_counts)
            print(roof.row(), flush=True)
        except Exception as e:
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-1500:])
            print(rec["arch"], "ERROR", rec["error"][:160], flush=True)
        with open(out_path, "a") as fo:
            fo.write(json.dumps(rec) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun_pipelines.jsonl")
    ap.add_argument("--pipeline", default=None, choices=list(CASES))
    args = ap.parse_args()
    for pid in ([args.pipeline] if args.pipeline else CASES):
        run_case(pid, args.out)


if __name__ == "__main__":
    main()
