"""input_specs(): weak-type-correct ShapeDtypeStruct stand-ins per
(architecture x input shape), plus the step function each shape lowers.

Shapes (assigned):
  train_4k     seq 4096,   batch 256  -> train_step (fwd+bwd+AdamW)
  prefill_32k  seq 32768,  batch 32   -> prefill_step (logits + KV cache)
  decode_32k   cache 32768, batch 128 -> serve_step (ONE token vs cache)
  long_500k    cache 524288, batch 1  -> serve_step (sub-quadratic archs)

The modality carve-out: VLM prompts are (text_tokens, patch_embeds) with
text = seq - vision_tokens so the total processed length matches; audio
tokens carry the codebook dim (B, K, L).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.configs import INPUT_SHAPES, InputShape
from repro.models import transformer
from repro.models.common import ModelConfig
from repro.training import loop as train_loop

PyTree = Any


@dataclasses.dataclass
class LoweringSpec:
    """Everything needed to lower one (arch, shape) combination."""
    kind: str                       # train | prefill | decode
    fn: Callable                    # the step function to jit
    args: Tuple                     # ShapeDtypeStruct pytree args
    arg_names: Tuple[str, ...]      # for sharding assignment
    batch: int
    seq_len: int
    skipped: Optional[str] = None   # reason, when the combo is skipped


def _token_struct(cfg: ModelConfig, batch: int, seq: int):
    if cfg.modality == "audio_codec":
        return jax.ShapeDtypeStruct((batch, cfg.num_codebooks, seq), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def supports_shape(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """None when supported; otherwise the skip reason (recorded in docs)."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return ("pure full-attention stack: a 500k-token KV cache has no "
                "sub-quadratic variant in the reference model (DESIGN.md)")
    return None


def input_specs(arch_id: str, shape_name: str,
                cfg: Optional[ModelConfig] = None) -> LoweringSpec:
    cfg = cfg if cfg is not None else configs.get(arch_id)
    shape = INPUT_SHAPES[shape_name]
    skip = supports_shape(cfg, shape)
    if skip:
        return LoweringSpec(shape.kind, lambda: None, (), (), shape.global_batch,
                            shape.seq_len, skipped=skip)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        state_shape = jax.eval_shape(
            lambda k: train_loop.init_state(cfg, k), key)
        batch: Dict[str, Any] = {
            "tokens": _token_struct(cfg, shape.global_batch, shape.seq_len),
            "labels": _token_struct(cfg, shape.global_batch, shape.seq_len),
        }
        if cfg.modality == "vision":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.vision_tokens, cfg.vision_embed_dim),
                jnp.float32)
            batch["labels"] = _token_struct(
                cfg, shape.global_batch, shape.seq_len - cfg.vision_tokens)
            batch["tokens"] = _token_struct(
                cfg, shape.global_batch, shape.seq_len - cfg.vision_tokens)
        step = train_loop.make_train_step(cfg)
        return LoweringSpec("train", step, (state_shape, batch),
                            ("state", "batch"), shape.global_batch, shape.seq_len)

    params_shape = jax.eval_shape(lambda k: transformer.init(cfg, k), key)

    if shape.kind == "prefill":
        text = shape.seq_len
        args = [params_shape]
        names = ["params", "tokens"]
        if cfg.modality == "vision":
            text = shape.seq_len - cfg.vision_tokens
            args.append(_token_struct(cfg, shape.global_batch, text))
            args.append(jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.vision_tokens, cfg.vision_embed_dim),
                jnp.float32))
            names.append("patch_embeds")
            fn = lambda p, t, pe: transformer.prefill(cfg, p, t, shape.seq_len,
                                                      prefix_embeds=pe)
        else:
            args.append(_token_struct(cfg, shape.global_batch, text))
            fn = lambda p, t: transformer.prefill(cfg, p, t, shape.seq_len)
        return LoweringSpec("prefill", fn, tuple(args), tuple(names),
                            shape.global_batch, shape.seq_len)

    # decode: ONE new token against a seq_len cache
    cache_shape = jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.global_batch, shape.seq_len))
    tokens = _token_struct(cfg, shape.global_batch, 1)
    offset = jax.ShapeDtypeStruct((), jnp.int32)
    fn = lambda p, t, c, o: transformer.decode_step(cfg, p, t, c, o)
    return LoweringSpec("decode", fn,
                        (params_shape, tokens, cache_shape, offset),
                        ("params", "tokens", "cache", "offset"),
                        shape.global_batch, shape.seq_len)
