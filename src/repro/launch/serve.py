"""Serving entry point: run the TridentServe cluster on a workload.

  PYTHONPATH=src python -m repro.launch.serve --pipeline flux \
      --workload dynamic --duration 600 --chips 128 \
      --baselines B1,B5,B6 [--cross-node-sp] [--no-batching]
"""
import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipeline", default="flux",
                    choices=["sd3", "flux", "cogvideox", "hunyuanvideo"])
    ap.add_argument("--workload", default="dynamic",
                    choices=["light", "medium", "heavy", "dynamic",
                             "proprietary"])
    ap.add_argument("--duration", type=float, default=600.0)
    ap.add_argument("--chips", type=int, default=128)
    ap.add_argument("--rate", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--baselines", default="")
    ap.add_argument("--cross-node-sp", action="store_true",
                    help="pod-wide SP (beyond-paper, EXPERIMENTS.md §Perf)")
    ap.add_argument("--no-batching", action="store_true")
    ap.add_argument("--json", default=None, help="append results here")
    args = ap.parse_args()

    from repro.core.baselines import BASELINES
    from repro.core.simulator import SimConfig, run_sim
    from repro.core.trident import TridentScheduler

    sim_cfg = SimConfig(num_chips=args.chips, seed=args.seed)
    results = [run_sim(args.pipeline, TridentScheduler, args.workload,
                       args.duration, sim_cfg=sim_cfg, rate=args.rate,
                       cross_node_sp=args.cross_node_sp,
                       enable_batching=not args.no_batching)]
    for b in (x for x in args.baselines.split(",") if x):
        results.append(run_sim(args.pipeline, BASELINES[b], args.workload,
                               args.duration, sim_cfg=sim_cfg,
                               rate=args.rate))
    for r in results:
        print(r.summary())
        if r.scheduler == "trident":
            print(f"  VR distribution {r.vr_histogram}; "
                  f"{len(r.placement_switches) - 1} placement switches; "
                  f"engine merged={r.engine_stats.get('merged_runs')} "
                  f"pushes={r.engine_stats.get('device_pushes')}")
    if args.json:
        with open(args.json, "a") as f:
            for r in results:
                f.write(json.dumps({
                    "scheduler": r.scheduler, "pipeline": r.pipeline,
                    "workload": args.workload, "oom": r.oom,
                    "slo": r.slo_attainment, "mean": r.mean_latency,
                    "p95": r.p95_latency}) + "\n")


if __name__ == "__main__":
    main()
