import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

The two lines above MUST precede every other import (jax locks the device
count at first init).  Each combination is lowered with ShapeDtypeStruct
inputs (zero allocation), compiled for the production mesh, and its
memory/cost analysis + collective schedule recorded for EXPERIMENTS.md
§Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k --multi-pod
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.configs import INPUT_SHAPES
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib
from repro.roofline import analysis as ra
from repro.roofline import hlo as hlo_mod
from repro.sharding import partition


def shardings_for(spec: specs_lib.LoweringSpec, cfg, mesh, multi_pod: bool,
                  opts: frozenset = frozenset()):
    """in_shardings pytree matching spec.args.

    opts (perf-iteration switches, see EXPERIMENTS.md §Perf):
      zero    — ZeRO-shard AdamW moments over the data axis
      fsdp    — additionally shard params over data (2D expert sharding for
                MoE; weight-gathered FSDP for dense)
    """
    daxes = mesh_lib.data_axes(multi_pod)
    da = daxes if len(daxes) > 1 else daxes[0]
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    shard = lambda t: partition.named(t, mesh)

    def batch_like(tree):
        # replicate instead of sharding when the batch doesn't divide the
        # data axes (long_500k has global_batch=1)
        return jax.tree_util.tree_map(
            lambda v: NamedSharding(
                mesh, P(da if v.shape[0] % dsize == 0 else None,
                        *([None] * (v.ndim - 1)))), tree)

    if spec.kind == "train":
        state_shape, batch_shape = spec.args
        sspec = partition.state_specs(
            cfg, state_shape,
            zero_mesh=mesh if ("zero" in opts or "fsdp" in opts) else None,
            fsdp="fsdp" in opts)
        sspec = partition.validate_divisibility(sspec, state_shape, mesh)
        return (shard(sspec), batch_like(batch_shape))

    params_shape = spec.args[0]
    pspec = partition.param_specs(cfg, params_shape)
    if "fsdp" in opts:
        pspec = partition.zero_shard(pspec, params_shape, mesh)
    pspec = partition.validate_divisibility(pspec, params_shape, mesh)
    if spec.kind == "prefill":
        rest = tuple(batch_like(a) for a in spec.args[1:])
        return (shard(pspec),) + rest
    # decode: (params, tokens, cache, offset)
    _, tokens_shape, cache_shape, _ = spec.args
    cspec = partition.cache_specs(cfg, cache_shape, spec.batch, mesh, da)
    cspec = partition.validate_divisibility(cspec, cache_shape, mesh)
    return (shard(pspec), batch_like(tokens_shape), shard(cspec),
            NamedSharding(mesh, P()))


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            verbose: bool = True, opts: frozenset = frozenset()
            ) -> Dict[str, Any]:
    import dataclasses as _dc

    from repro.models import transformer as _tf

    cfg = configs.get(arch)
    if "gqa" in opts and hasattr(cfg, "gqa_grouped_decode"):
        cfg = _dc.replace(cfg, gqa_grouped_decode=True)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    spec = specs_lib.input_specs(arch, shape_name, cfg=cfg)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "kind": spec.kind,
                           "opts": sorted(opts)}
    if spec.skipped:
        rec["status"] = "skipped"
        rec["reason"] = spec.skipped
        return rec
    chips = mesh.size
    t0 = time.perf_counter()
    try:
        if "seqshard" in opts:
            daxes = mesh_lib.data_axes(multi_pod)
            da = daxes if len(daxes) > 1 else daxes[0]
            _tf.set_activation_sharding(
                jax.sharding.NamedSharding(mesh, P(da, "model", None)))
        in_sh = shardings_for(spec, cfg, mesh, multi_pod, opts)
        # donate the state/cache buffers (production practice: the update
        # aliases its input, halving peak memory for train and decode)
        donate = {"train": (0,), "prefill": (), "decode": (2,)}[spec.kind]
        with mesh:
            lowered = jax.jit(spec.fn, in_shardings=in_sh,
                              donate_argnums=donate).lower(*spec.args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        flops_raw, nbytes_raw = ra.cost_terms(compiled, chips)
        hlo_text = compiled.as_text()
        # trip-count-aware costs (cost_analysis counts while bodies once)
        mc = hlo_mod.module_costs(hlo_text, chips)
        flops, nbytes = mc.flops, mc.hbm_bytes
        coll = ra.CollectiveStats(mc.collective_counts,
                                  {"total": mc.collective_wire_bytes})
        try:
            ma = compiled.memory_analysis()
            peak = int(getattr(ma, "temp_size_in_bytes", 0)
                       + getattr(ma, "argument_size_in_bytes", 0)
                       + getattr(ma, "output_size_in_bytes", 0)
                       - getattr(ma, "alias_size_in_bytes", 0))
            rec["memory_analysis"] = {
                "temp": int(getattr(ma, "temp_size_in_bytes", 0)),
                "args": int(getattr(ma, "argument_size_in_bytes", 0)),
                "out": int(getattr(ma, "output_size_in_bytes", 0)),
                "alias": int(getattr(ma, "alias_size_in_bytes", 0)),
            }
        except Exception as e:   # CPU backend may not implement it
            peak = 0
            rec["memory_analysis"] = f"unavailable: {e}"
        mf = ra.model_flops(cfg, spec.kind, spec.batch, spec.seq_len)
        roof = ra.Roofline(arch=arch, shape=shape_name, mesh=mesh_name,
                           chips=chips, hlo_flops=flops, hlo_bytes=nbytes,
                           coll_bytes=coll.total_bytes / chips,
                           model_flops=mf, coll_counts=coll.counts,
                           peak_mem_bytes=peak)
        rec.update(status="ok", t_lower_s=round(t_lower, 2),
                   t_compile_s=round(t_compile, 2),
                   cost_analysis_flops_raw=flops_raw,
                   cost_analysis_bytes_raw=nbytes_raw,
                   loop_multipliers=mc.loop_multipliers,
                   hlo_flops_per_device=flops, hlo_bytes_per_device=nbytes,
                   coll_wire_bytes_total=coll.total_bytes,
                   coll_counts=coll.counts, model_flops=mf,
                   t_compute_s=roof.t_compute, t_memory_s=roof.t_memory,
                   t_collective_s=roof.t_collective,
                   bottleneck=roof.bottleneck, useful_ratio=roof.useful_ratio,
                   peak_mem_per_device=peak)
        if verbose:
            print(roof.row(), flush=True)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"{arch:26s} {shape_name:12s} {mesh_name:9s} "
                  f"ERROR {type(e).__name__}: {str(e)[:200]}", flush=True)
    finally:
        _tf.set_activation_sharding(None)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(configs.ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) on the chosen mesh")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--opt", default="",
                    help="comma list of perf switches: "
                         "seqshard,zero,fsdp,gqa (see EXPERIMENTS.md §Perf)")
    args = ap.parse_args(argv)
    opts = frozenset(o for o in args.opt.split(",") if o)

    combos = []
    if args.all:
        for a in configs.ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        combos = [(args.arch, args.shape)]

    ok = True
    for a, s in combos:
        rec = run_one(a, s, multi_pod=args.multi_pod, opts=opts)
        ok &= rec["status"] in ("ok", "skipped")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
