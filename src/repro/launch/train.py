"""Distributed training entry point.

Builds a mesh over the available devices, shards the TrainState with the
partition rules (+ optional ZeRO/FSDP/seq-shard switches from §Perf), and
runs the training loop on sharded synthetic batches.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 20 --smoke
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch deepseek-moe-16b \
    --smoke --steps 10 --mesh 4x2 --opt zero
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default=None,
                    help="DxM data x model mesh (default: all devices x 1)")
    ap.add_argument("--opt", default="",
                    help="comma list: zero,fsdp,seqshard (§Perf switches)")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()
    opts = frozenset(o for o in args.opt.split(",") if o)

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    import repro.configs as C
    from repro.data import pipeline as dp
    from repro.models import transformer
    from repro.sharding import partition
    from repro.training import loop

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        d, m = len(jax.devices()), 1
    mesh = jax.make_mesh((d, m), ("data", "model"))
    print(f"arch={cfg.name} mesh={d}x{m} devices={len(jax.devices())} "
          f"opts={sorted(opts)}")

    state = loop.init_state(cfg, jax.random.PRNGKey(0))
    state_shape = jax.eval_shape(lambda: state)
    sspec = partition.state_specs(
        cfg, state_shape,
        zero_mesh=mesh if ("zero" in opts or "fsdp" in opts) else None,
        fsdp="fsdp" in opts)
    sspec = partition.validate_divisibility(sspec, state_shape, mesh)
    shard = partition.named(sspec, mesh)
    state = jax.device_put(state, shard)
    if "seqshard" in opts:
        transformer.set_activation_sharding(
            NamedSharding(mesh, P("data", "model", None)))

    dcfg = dp.DataConfig(batch=args.batch, seq_len=args.seq)
    step_fn = jax.jit(loop.make_train_step(cfg), in_shardings=(shard, None),
                      donate_argnums=(0,))
    t0 = time.perf_counter()
    with mesh:
        for i in range(args.steps):
            batch = {k: jax.device_put(jnp.asarray(v), NamedSharding(
                         mesh, P("data", *([None] * (v.ndim - 1)))))
                     for k, v in dp.synthetic_batch(cfg, dcfg, i).items()}
            state, metrics = step_fn(state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"wall {time.perf_counter() - t0:.1f}s", flush=True)
    transformer.set_activation_sharding(None)


if __name__ == "__main__":
    main()
