"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis crosses DCN; batch shards over ("pod", "data").

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax initialization.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def make_host_mesh(model: int = 1):
    """Degenerate mesh for CPU tests/examples (whatever devices exist)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
