"""Scheduler-agnostic event-clock kernel + the Lane serving abstraction.

One event-loop implementation for every simulator in the repo.  The
single-pipeline ``Simulator`` and the shared-cluster ``FleetSimulator``
used to carry two intentionally-parallel run loops kept in lockstep only
by the 1-pipeline bit-identical test; this module is the extraction of
that loop into a kernel both drive, so the lockstep holds *by
construction*:

* ``EventClock`` — the kernel: the stage-completion event heap, tick-grid
  quantization, the ``max_idle_gap`` heartbeat with its profile-guided
  adaptive widening (deadline/aging-flip tracking), and a plug-in list of
  *wake sources*.  Two clock modes share one per-step body: ``tick`` (the
  legacy fixed-step reference loop, O(horizon/tick)) and ``event``
  (wake only when state can change, O(events); wake-ups are quantized
  *up* to the tick grid so on traces where the skipped ticks are no-ops
  the two modes are bit-identical).
* ``WakeSource`` — a callable ``tau -> Optional[float]`` returning the
  earliest future time its subsystem can change state.  Arrivals,
  Monitor-window boundaries (including the opt-in idle-window wake-ups),
  fleet re-partition windows, lending borrow/return expiries, and the
  predictive scheduler's forecast events (rate-history bin boundaries +
  the armed predicted-shift time, ``forecast_wake``) are all registered
  this way — once, independent of lane count.  Schedulers can
  export their own trigger-crossing wake-ups via ``next_wake`` hooks
  (see ``Scheduler`` / the fleet schedulers), registered by the drivers
  behind the opt-in ``scheduler_wake_hooks`` config flags.
* ``ClockDriver`` — the protocol a simulator implements to ride the
  kernel: ``advance`` (admit arrivals, drain completions, run one
  scheduler step), ``done``, ``heartbeat_pending``, ``still_pending``.
* ``Lane`` — one pipeline's serving stack (scheduler + engine + Monitor +
  pending queue + result bookkeeping).  It exposes exactly the attribute
  surface schedulers were written against (``pending`` / ``engine`` /
  ``monitor`` / ``new_arrivals`` / ``fail_request_oom``), so the
  single-pipeline simulator *is* a one-lane special case of the fleet.
* ``Scheduler`` / ``PendingSet`` — the scheduler interface and the
  O(1)-removal pending queue, shared by every driver (re-exported from
  ``repro.core.simulator`` for compatibility).

docs/architecture.md diagrams the layering and the bit-exactness
contracts the committed BENCH baselines pin on this kernel.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

try:                        # array-backed lane state (SimConfig.array_state)
    import numpy as np
except ImportError:         # pragma: no cover - numpy ships with jax
    np = None

from repro.core.monitor import Monitor
from repro.core.request import Request
from repro.core.runtime import EngineStats, RuntimeEngine

# A wake source answers: "earliest future time you could change state?"
# (None = never / not currently armed).  Sources are consulted after every
# scheduler step; the kernel jumps the clock to the earliest answer.
WakeSource = Callable[[float], Optional[float]]

# unified stage-completion event, one format for every driver:
#   (finish, seq, lane, stage, placement type, duration, batch members,
#    units)
# — the whole batch rides along so per-pipeline SLO windows can count every
# finished request, not one per dispatch decision.  ``units`` is the tuple
# of (pipeline, unit) pairs the stage physically runs on — populated only
# while a fault injector is live (Lane.track_units; core/elastic.py), so
# the default path pushes () and pays nothing.  Heap order never reaches
# it: (finish, seq) is already unique.
Completion = Tuple[float, int, str, str, str, float, Tuple[Request, ...],
                   Tuple[Tuple[str, int], ...]]

# Merged completion events (fleet cross-lane batching): a fused stage run
# spanning several lanes is pushed ONCE with this sentinel in the lane
# field.  Member contract: ``members`` holds every request of every fused
# decision (corequests included), sorted by (pipeline, rid) — so a driver
# draining the event can (a) route ``on_completion`` once per participating
# lane (the sorted-unique pipelines of the members) and (b) count per-
# request SLO finishes via each member's own ``pipeline``, in an order
# independent of PYTHONHASHSEED.  Drivers that never fuse (the single-
# pipeline Simulator) never see the sentinel.
MERGED_LANE = "*merged*"


@dataclasses.dataclass
class ClockConfig:
    """Kernel knobs, distilled from SimConfig/FleetConfig by the drivers."""
    tick: float = 0.25                # quantization grid (s)
    horizon: float = 0.0              # last grid point the loop may visit
    mode: str = "event"               # "event" (O(events)) | "tick" (legacy)
    max_idle_gap: float = 1.0         # max clock jump while work is pending
    adaptive_idle_gap: bool = False   # profile-guided heartbeat widening
    idle_gap_max: float = 16.0        # ceiling for the adaptive gap (s)


class ClockDriver:
    """What a simulator implements to be driven by ``EventClock.run``."""

    def advance(self, tau: float) -> None:
        """One scheduler step at ``tau``: admit arrivals, drain completion
        events, re-place/dispatch.  The kernel never looks inside."""
        raise NotImplementedError

    def done(self) -> bool:
        """True when no arrival, pending request, or in-flight event
        remains — the clock can stop before the horizon."""
        raise NotImplementedError

    def heartbeat_pending(self) -> bool:
        """True while dispatch rewards/aging depend on the passage of time
        (requests are queued) — keeps the ``max_idle_gap`` heartbeat armed."""
        raise NotImplementedError

    def still_pending(self, lane: str, rid: int) -> bool:
        """Is request ``rid`` of ``lane`` still queued?  Consulted when the
        adaptive heartbeat drains tracked deadlines (aging flips)."""
        raise NotImplementedError


class EventClock:
    """The kernel: event heap + wake sources + one while-loop, two modes.

    The drivers own *what* happens at a wake-up (``ClockDriver.advance``);
    the kernel owns *when* wake-ups happen: the next stage completion from
    its heap, the earliest answer among the registered wake sources, and —
    only while the driver reports pending work — a ``max_idle_gap``
    heartbeat whose gap doubles while no tracked deadline is crossed
    (profile-guided ``adaptive_idle_gap``) and resets when one is.  Every
    wake-up is quantized up to the tick grid, so dispatch timestamps land
    exactly where the legacy tick loop would have placed them.
    """

    def __init__(self, cfg: ClockConfig):
        self.cfg = cfg
        self.completions: List[Completion] = []   # stage-completion heap
        self._eseq = 0
        self.sources: List[WakeSource] = []
        self.wakeups = 0                  # scheduler steps taken
        # adaptive heartbeat: tracked deadlines of pending requests, drained
        # as the clock passes them to observe aging flips
        self._deadlines: List[Tuple[float, str, int]] = []

    # -- event heap ------------------------------------------------------------

    def push_completion(self, finish: float, lane: str, stage: str,
                        ptype: str, duration: float,
                        members: Tuple[Request, ...],
                        units: Tuple[Tuple[str, int], ...] = ()) -> None:
        heapq.heappush(self.completions,
                       (finish, self._eseq, lane, stage, ptype, duration,
                        members, units))
        self._eseq += 1

    def pop_due(self, tau: float) -> Sequence[Completion]:
        """Remove and return the completion events with ``finish <= tau``
        in (finish, push-order) order.  Early-exits allocation-free on the
        common no-events-due case — this sits on the per-wakeup hot path
        of the tick reference loop (O(horizon/tick) wake-ups)."""
        heap = self.completions
        if not heap or heap[0][0] > tau:
            return ()
        out = []
        pop = heapq.heappop
        while heap and heap[0][0] <= tau:
            out.append(pop(heap))
        return out

    def remove_completions(self, pred: Callable[[Completion], bool]
                           ) -> List[Completion]:
        """Remove and return every in-flight event matching ``pred`` —
        the fault injector's revocation primitive (core/elastic.py): work
        dispatched onto units that are about to vanish is pulled back off
        the heap so its requests can be requeued.  The survivors are
        re-heapified; the removed events come back sorted by
        (finish, seq) so callers iterate them deterministically (seq is
        unique, so the sort never compares Request objects)."""
        removed = [ev for ev in self.completions if pred(ev)]
        if not removed:
            return removed
        self.completions = [ev for ev in self.completions if not pred(ev)]
        heapq.heapify(self.completions)
        removed.sort(key=lambda ev: (ev[0], ev[1]))
        return removed

    # -- wake sources ----------------------------------------------------------

    def add_source(self, source: WakeSource) -> None:
        self.sources.append(source)

    # -- adaptive heartbeat ----------------------------------------------------

    def track_deadline(self, deadline: float, lane: str, rid: int) -> None:
        heapq.heappush(self._deadlines, (deadline, lane, rid))

    def _aging_flips(self, tau: float, driver: ClockDriver) -> int:
        """Tracked deadlines crossed up to ``tau`` among still-pending
        requests — the events that change dispatch rewards while nothing
        else moves.  No flips -> the heartbeat gap doubles; a flip -> it
        resets to its base."""
        flips = 0
        heap = self._deadlines
        while heap and heap[0][0] <= tau:
            _, lane, rid = heapq.heappop(heap)
            if driver.still_pending(lane, rid):
                flips += 1
        return flips

    # -- the one loop ----------------------------------------------------------

    def run(self, driver: ClockDriver) -> None:
        cfg = self.cfg
        tick = cfg.tick
        horizon = cfg.horizon
        if cfg.mode == "tick":
            # legacy fixed-step reference: every grid point is a wake-up
            i = 0
            while i * tick <= horizon:
                self.wakeups += 1
                driver.advance(i * tick)
                if driver.done():
                    break
                i += 1
            return
        gap_base = max(cfg.max_idle_gap, tick)
        gap_max = max(cfg.idle_gap_max, gap_base)
        gap = gap_base
        i = 0
        while i * tick <= horizon:
            tau = i * tick
            self.wakeups += 1
            driver.advance(tau)
            if driver.done():
                break
            if cfg.adaptive_idle_gap:
                gap = (gap_base if self._aging_flips(tau, driver)
                       else min(gap * 2.0, gap_max))
            t_next = math.inf
            if self.completions:
                t_next = self.completions[0][0]
            for source in self.sources:
                wake = source(tau)
                if wake is not None and wake < t_next:
                    t_next = wake
            if driver.heartbeat_pending():
                t_next = min(t_next, tau + gap)
            if t_next is math.inf:
                break   # nothing can ever change state again
            # quantize up to the tick grid; always advance at least one tick
            i = max(i + 1, int(math.ceil(t_next / tick - 1e-9)))


class PendingSet:
    """Arrival-ordered, rid-indexed set of pending requests.

    Backed by an insertion-ordered dict so dispatch bookkeeping is O(1) per
    removal instead of the O(n) ``list.remove`` scans the tick loop did;
    iteration yields requests in arrival (admission) order.

    ``array_state=True`` additionally maintains a flat float64 deadline
    column aligned with an admission-ordered slot list (tombstoned on
    removal, compacted when the dead outnumber the live), so the dispatch
    hot path's deadline ordering comes from one vectorized stable argsort
    (``by_deadline``) instead of a per-request Python key sort.  Deadlines
    are immutable after admission (workloads stamp them at trace build
    time), so the snapshot taken on ``add`` never goes stale.  Stable
    argsort over the admission-ordered column is bit-identical to
    ``sorted(self, key=lambda r: r.deadline)`` — ties keep admission
    order, float64 comparisons are exactly Python's — which is what lets
    the flag flip without changing a single trajectory
    (tests/test_scale_parity.py pins this).
    """

    __slots__ = ("_by_rid", "_arr", "_req", "_dl", "_slot", "_dead")

    def __init__(self, reqs: Sequence[Request] = (),
                 array_state: bool = False):
        self._by_rid: Dict[int, Request] = {}
        self._arr = bool(array_state) and np is not None
        if self._arr:
            self._req: List[Optional[Request]] = []
            self._dl = np.empty(64, dtype=np.float64)
            self._slot: Dict[int, int] = {}
            self._dead = 0
        for r in reqs:
            self.add(r)

    def add(self, req: Request) -> None:
        if self._arr:
            slot = self._slot.get(req.rid)
            if slot is not None:      # re-add keeps the dict's original slot
                self._req[slot] = req
                self._dl[slot] = req.deadline
            else:
                n = len(self._req)
                if n == self._dl.shape[0]:
                    if self._dead * 2 > n:
                        self._compact()
                        n = len(self._req)
                    else:
                        dl = np.empty(max(64, 2 * n), dtype=np.float64)
                        dl[:n] = self._dl[:n]
                        self._dl = dl
                self._req.append(req)
                self._dl[n] = req.deadline
                self._slot[req.rid] = n
        self._by_rid[req.rid] = req

    append = add   # drop-in for the old list-based field

    def _compact(self) -> None:
        reqs = [r for r in self._req if r is not None]
        self._req = reqs
        n = len(reqs)
        dl = np.empty(max(64, 2 * n), dtype=np.float64)
        for i, r in enumerate(reqs):
            dl[i] = r.deadline
        self._dl = dl
        self._slot = {r.rid: i for i, r in enumerate(reqs)}
        self._dead = 0

    def _drop_slot(self, rid: int) -> None:
        slot = self._slot.pop(rid, None)
        if slot is not None:
            self._req[slot] = None
            self._dead += 1
            if self._dead > len(self._by_rid):
                self._compact()

    def by_deadline(self, cap: Optional[int] = None) -> List[Request]:
        """Pending requests in (deadline, admission) order — the dispatch
        hot path's sort, vectorized when array-backed."""
        if not self._arr:
            out = sorted(self._by_rid.values(), key=lambda r: r.deadline)  # detlint: ignore[DET004] rid-dict is admission-ordered: stable ties are deterministic (and what the array path reproduces)
            return out if cap is None else out[:cap]
        n = len(self._req)
        reqs = self._req
        if self._dead:
            idx = np.fromiter((i for i in range(n) if reqs[i] is not None),
                              dtype=np.int64, count=n - self._dead)
            order = idx[np.argsort(self._dl[idx], kind="stable")]
        else:
            order = np.argsort(self._dl[:n], kind="stable")
        if cap is not None:
            # full stable sort then truncate — identical to sorted()[:cap]
            order = order[:cap]
        return [reqs[i] for i in order]

    def remove(self, req: Request) -> None:
        del self._by_rid[req.rid]
        if self._arr:
            self._drop_slot(req.rid)

    def discard(self, req: Request) -> None:
        if self._by_rid.pop(req.rid, None) is not None and self._arr:
            self._drop_slot(req.rid)

    def has_rid(self, rid: int) -> bool:
        return rid in self._by_rid

    def __contains__(self, req: Request) -> bool:
        return req.rid in self._by_rid

    def __iter__(self) -> Iterator[Request]:
        return iter(self._by_rid.values())

    def __len__(self) -> int:
        return len(self._by_rid)

    def __bool__(self) -> bool:
        return bool(self._by_rid)


class Scheduler:
    """Interface implemented by TridentServe and the B1-B6 baselines.

    A scheduler is also an *event-source plug-in*: ``next_wake`` may
    return the earliest future time one of its trigger conditions can
    newly fire (a pattern-change cooldown expiring, a warm-up window
    ending) so the event clock visits the crossing instead of sleeping
    through it.  Default ``None`` — and drivers only register the hook
    behind the opt-in ``scheduler_wake_hooks`` flag, because extra
    wake-ups (even no-op ones) change heartbeat phase and would break the
    bit-exact reproduction of the committed BENCH traces.
    """

    name = "base"

    def __init__(self, prof, sim_cfg, trace: Sequence[Request]):
        self.prof = prof
        self.sim_cfg = sim_cfg
        self.trace = trace

    def initial_placement(self):
        raise NotImplementedError

    def tick(self, sim, tau: float):
        raise NotImplementedError

    def maybe_replace(self, sim, tau: float):
        return None

    def next_wake(self, sim, tau: float) -> Optional[float]:
        return None


class Lane:
    """One pipeline's serving stack: scheduler + engine + Monitor + queue.

    Exposes the attribute surface schedulers expect from a simulator
    (``pending`` / ``engine`` / ``monitor`` / ``new_arrivals`` /
    ``fail_request_oom``), plus the per-lane result bookkeeping both
    drivers used to duplicate.  ``Simulator`` *is* a one-lane subclass;
    ``FleetSimulator`` holds one Lane per served pipeline.
    """

    def __init__(self, pipeline: str, prof, scheduler: Scheduler,
                 array_state: bool = False):
        self.pipeline = pipeline
        self.prof = prof
        self.sched = scheduler
        self.monitor = Monitor(array_state=array_state)
        self.pending = PendingSet(array_state=array_state)
        self.new_arrivals: List[Request] = []  # admitted since the last step
        self.engine: Optional[RuntimeEngine] = None
        self.request_oom: List[Request] = []
        self.vr_histogram: Dict[int, int] = {}
        self.throughput: Dict[int, int] = {}
        self.placement_log: List[Tuple[float, Dict[str, int]]] = []
        self._stats_base = EngineStats()   # stats of retired engines
        # cross-pipeline unit lending (core/lending.py): borrowed foreign
        # E/C units by hosted stage, and how many stage runs landed on them.
        # base_units marks the engine's own plan size; loan slots live above.
        # track_borrowed is set by the fleet driver while a broker is live.
        self.borrowed_units: Dict[str, Tuple[int, ...]] = {}
        self.borrowed_stage_runs: Dict[str, int] = {}
        self.base_units: int = 0
        self.track_borrowed: bool = False
        # fault injection (core/elastic.py): set by the fleet driver when a
        # FaultInjector is live, so completion events carry the (pipeline,
        # unit) pairs they run on and revocation can match them.  Off (the
        # default), record pushes () — zero overhead, bit-identical.
        self.track_units: bool = False
        # stage-aware drain (core/elastic.py): unit id -> land time while a
        # preemption notice is live.  The dispatcher only hands a draining
        # unit work that finishes before its land; empty (the default) is
        # passed through as None and leaves dispatch byte-identical.
        self.draining_units: Dict[int, float] = {}

    # -- queue ----------------------------------------------------------------

    def fail_request_oom(self, req: Request) -> None:
        self.request_oom.append(req)

    def admit(self, req: Request, clock: Optional[EventClock] = None) -> None:
        """Admit one arrival; with ``clock`` given, also track its deadline
        for the adaptive heartbeat's aging-flip observation."""
        self.pending.add(req)
        self.new_arrivals.append(req)
        if clock is not None:
            clock.track_deadline(req.deadline, self.pipeline, req.rid)

    def requeue(self, req: Request,
                clock: Optional[EventClock] = None) -> None:
        """Re-admit a request whose dispatched stage events were revoked
        (fault-injection requeue, core/elastic.py): back into the pending
        pool under its original arrival and deadline — SLO accounting
        keeps charging the original clock — without re-recording it as an
        arrival (``new_arrivals`` and the demand windows already counted
        it once)."""
        self.pending.add(req)
        if clock is not None:
            clock.track_deadline(req.deadline, self.pipeline, req.rid)

    # -- dispatch bookkeeping -------------------------------------------------

    def record(self, dec, times: Dict[str, Tuple[float, float]],
               clock: EventClock) -> None:
        """Push one decision's stage completions onto the kernel heap and
        update per-lane result accounting.

        Stages in ``dec.xl_skip`` (cross-lane fused runs) still stamp
        ``stage_done`` for the batch members, but push no per-lane event —
        the fleet batcher already pushed ONE merged event (``MERGED_LANE``)
        for the whole fused launch — and count no borrowed-unit runs here:
        the decision's native auxiliary selection went unused, and the
        fused launch's borrowed accounting lands on the *host* lane."""
        members = (dec.request,) + tuple(getattr(dec, "corequests", ()))
        skip = getattr(dec, "xl_skip", ())
        for s, (start, fin) in times.items():
            for req in members:
                req.stage_done[s] = fin
            if s in skip:
                continue
            su = (dec.d_units if s == "D" else
                  dec.e_units if s == "E" else dec.c_units)
            ptype = self.engine.plan.placements[su[0]]
            clock.push_completion(fin, self.pipeline, s, ptype, fin - start,
                                  members,
                                  tuple((self.pipeline, g) for g in su)
                                  if self.track_units else ())
        self.vr_histogram[dec.vr_type] = (self.vr_histogram.get(dec.vr_type, 0)
                                          + len(members))
        if self.track_borrowed:
            # lending invariant: Diffuse never lands on a borrowed unit.
            # D is counted (not just asserted) so the bench JSON's
            # diffuse_runs_on_borrowed_units is a measurement the
            # regression gate can actually trip on, even under python -O.
            for s, units in (("E", dec.e_units), ("D", dec.d_units),
                             ("C", dec.c_units)):
                if s in skip:
                    continue
                if any(g >= self.base_units for g in units):
                    self.borrowed_stage_runs[s] = \
                        self.borrowed_stage_runs.get(s, 0) + 1
            assert "D" not in self.borrowed_stage_runs, \
                "diffuse dispatched to a borrowed foreign unit"

    def on_completion(self, t: float, stage: str, ptype: str,
                      duration: float) -> None:
        """Feed one drained completion event into this lane's Monitor."""
        self.monitor.record_stage(t, stage, ptype, duration)
        if stage == "C":
            self.throughput[int(t // 60)] = (
                self.throughput.get(int(t // 60), 0) + 1)

    def decide(self, tau: float,
               apply_replacement: Callable[..., None]) -> Sequence:
        """Placement-switch check + one scheduler tick; returns the
        decisions *without* executing them.  The fleet's cross-lane batcher
        rides this split: every lane decides first, the batcher plans fused
        stage runs across the decisions, then each lane executes
        (``execute_decisions``).  Lanes own disjoint engines, so deciding
        all lanes before executing any is equivalent to the interleaved
        ``step`` — which remains the plain composition of the two."""
        new_plan = self.sched.maybe_replace(self, tau)
        if new_plan is not None:
            apply_replacement(new_plan, tau)
            self.placement_log.append((tau, new_plan.type_histogram()))
        return self.sched.tick(self, tau)

    def execute_decisions(self, decisions: Sequence, tau: float,
                          clock: EventClock) -> None:
        """Execute a tick's decisions in order: engine timing, completion
        events, pending-queue removal.

        Decisions marked ``xl_hold`` (cross-lane batching's E-hold: the
        auxiliary encode unit is backlogged) execute only if the fleet
        batcher fused them this tick (``xl_efused``); otherwise they are
        skipped entirely — nothing is reserved and the request stays in
        the pending pool for a later tick's fusion or native dispatch."""
        for dec in decisions:
            if getattr(dec, "xl_hold", False) and \
                    getattr(dec, "xl_efused", None) is None:
                continue
            times = self.engine.execute(dec, tau)
            self.record(dec, times, clock)
            self.pending.remove(dec.request)
            for co in getattr(dec, "corequests", ()):
                self.pending.remove(co)

    def step(self, tau: float, clock: EventClock,
             apply_replacement: Callable[..., None]) -> None:
        """One scheduler step for this lane: placement-switch check, then
        dispatch.  ``apply_replacement(new_plan, tau)`` is the
        driver-specific way a fresh sub-plan reaches the engine (the fleet
        also reattaches loan slots and updates the cluster plan)."""
        self.execute_decisions(self.decide(tau, apply_replacement), tau,
                               clock)

    # -- engine-stats banking (survives fleet re-partitions) -------------------

    def bank_engine_stats(self) -> None:
        """Fold the outgoing engine's counters into the lane total before a
        re-partition replaces it."""
        if self.engine is None:
            return
        for f in dataclasses.fields(EngineStats):
            setattr(self._stats_base, f.name,
                    getattr(self._stats_base, f.name)
                    + getattr(self.engine.stats, f.name))

    def engine_stats(self) -> Dict[str, float]:
        total = dataclasses.asdict(self._stats_base)
        if self.engine is not None:
            for k, v in dataclasses.asdict(self.engine.stats).items():
                total[k] += v
        return total


def replace_capable(scheduler: Scheduler) -> bool:
    """Monitor-window boundary wake-ups only matter to schedulers that can
    actually re-place — the drivers skip registering the source otherwise."""
    return type(scheduler).maybe_replace is not Scheduler.maybe_replace


def monitor_boundary_source(monitor: Monitor, armed: Callable[[], bool]
                            ) -> WakeSource:
    """Wake source for a Monitor's sliding-window boundaries: the earliest
    future time a retained sample exits the window (windowed rates — and
    the placement-switch trigger — can only change there or at an event).
    ``armed`` gates it: by default boundaries matter only while work is
    pending or in flight; the opt-in idle-window wake-ups keep it armed
    across idle gaps (the stale-window fix)."""
    def source(tau: float) -> Optional[float]:
        if not armed():
            return None
        boundary = monitor.next_window_boundary()
        if boundary is not None and boundary > tau:
            return boundary
        return None
    return source
