"""Demand forecasting for predictive fleet re-partitioning (core/fleet.py).

The adaptive fleet scheduler re-partitions only *after*
``FleetMonitor.mix_shift`` observes a demand change, so every diurnal flip
pays the full weight-reload downtime — and a detection window of
mis-partitioned serving — exactly when the new mix is already queuing.
This module supplies the missing anticipation (DiffServe-style query-aware
scaling, one level up):

* ``fit_series`` / ``SeriesFit`` — a lightweight per-pipeline demand model
  over the Monitor's windowed-rate history: an OLS linear trend, plus the
  dominant period of the detrended residuals by autocorrelation.  A
  period is *accepted* only when the one-period-back seasonal predictor
  explains the series better than the trend does (seasonal R²) — so
  square waves, tides, and any repeating shape qualify, stationary noise
  never does.
* ``DemandForecaster`` — per-pipeline fits + **seasonal-naive
  extrapolation**: a periodic pipeline's predicted rate at ``t`` is the
  (fold-averaged, 3-bin-smoothed) observed rate one or more whole periods
  earlier, which makes the predicted *phase* exact by construction — no
  harmonic approximation to mis-time a flip by half a lead window.
  Trend-only pipelines extrapolate the trend line.  ``predict_shift``
  scans the extrapolation for the next time the predicted demand shares
  drift from the model's current shares by the re-partition hysteresis
  threshold, returning both the crossing time and the *settled* new-phase
  mix (the drift maximum) that a new partition should be sized against —
  gated on a demand-weighted mean R² so stationary traffic never
  schedules a pre-warm.

Everything here is pure computation over explicit inputs: fits depend only
on the completed history bins and predictions only on (fit, tau), so the
event and tick clocks — which visit the same bin boundaries — derive
identical predictions (tests/test_fleet.py parity matrix), and every
iteration order is sorted so results are independent of
``PYTHONHASHSEED``.

Wake sources and trigger gates (the clock.py standard): this module
registers nothing itself — the fleet driver registers the predictive
scheduler's ``forecast_wake`` closure, which answers with the next
rate-history bin boundary (fits and pre-warm staging only move there; a
fit between boundaries would see the same completed bins and return the
same answer) plus the armed predicted-shift time.  The trigger gates are
the forecaster's confidence gate (demand-weighted mean R² — stationary
traffic never schedules a pre-warm), the pre-warm cooldown, and
``forecast_grace`` (an unconfirmed shift expires; a live shift moving
away from the prediction drops it immediately).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

# completed rate-history bins: (bin-center time, {key: demand rate}).
# Keys are opaque: per-pipeline demand for re-partition prediction, or
# per-placement-class demand (FleetMonitor.class_rate_history) when the
# predictive scheduler pre-warms the placement-type mix the cross-lane
# batcher will want — the fits and extrapolation are key-agnostic.
History = Sequence[Tuple[float, Dict[str, float]]]


def tv_distance(a: Dict[str, float], b: Dict[str, float]) -> float:
    """Total-variation distance between two share distributions.  Sorted
    keys: the sum is order-sensitive in the last ulp and str-set iteration
    follows PYTHONHASHSEED — a threshold comparison must not flip
    run-to-run (same rule as ``FleetMonitor.mix_shift``)."""
    keys = sorted(set(a) | set(b))
    return 0.5 * sum(abs(a.get(k, 0.0) - b.get(k, 0.0)) for k in keys)


@dataclasses.dataclass(frozen=True)
class SeriesFit:
    """One demand series' model: linear trend, and — when accepted — the
    dominant period for seasonal-naive extrapolation."""
    intercept: float
    slope: float
    period: float = 0.0                # 0.0 = no period accepted
    r2: float = 0.0                    # seasonal R² (periodic) / trend R²
    mean: float = 0.0                  # mean demand over the fitted window

    def trend(self, t: float) -> float:
        return max(0.0, self.intercept + self.slope * t)


def fit_series(ts: Sequence[float], ys: Sequence[float],
               min_autocorr: float = 0.3) -> SeriesFit:
    """Fit one demand series.

    1. OLS linear trend (and its R²).
    2. Dominant period of the detrended residuals by autocorrelation
       (lags 2..n/2, length-corrected), considered only above
       ``min_autocorr``.
    3. The period is *accepted* iff the seasonal-naive predictor — each
       sample explained by the sample one period earlier — beats the trend
       on R².  Stationary noise fails both gates (R² ~ 1/n)."""
    n = len(ys)
    mean_t = sum(ts) / n
    mean_y = sum(ys) / n
    var_t = sum((t - mean_t) ** 2 for t in ts)
    cov = sum((t - mean_t) * (y - mean_y) for t, y in zip(ts, ys))
    slope = cov / var_t if var_t > 0.0 else 0.0
    intercept = mean_y - slope * mean_t
    sst = sum((y - mean_y) ** 2 for y in ys)
    if n < 8 or sst <= 1e-12:
        # flat or tiny series: no structure worth acting on (r2 = 0)
        return SeriesFit(intercept, slope, mean=mean_y)
    sse_tr = sum((y - (intercept + slope * t)) ** 2 for t, y in zip(ts, ys))
    r2_trend = max(0.0, 1.0 - sse_tr / sst)
    resid = [y - (intercept + slope * t) for t, y in zip(ts, ys)]
    ss = sum(r * r for r in resid)
    best_lag, best_ac = 0, 0.0
    if ss > 1e-12:
        # a slowly-varying signal correlates at EVERY small lag (plateau
        # neighbours are near-equal), so the raw argmax would latch onto
        # lag 2 and call any smooth series "periodic" — only consider lags
        # past the first decorrelation dip (ac < 0), where a new peak
        # really is the waveform repeating
        dipped = False
        for lag in range(2, n // 2 + 1):
            num = sum(resid[i] * resid[i - lag] for i in range(lag, n))
            ac = (num / ss) * (n / (n - lag))   # length-corrected
            if not dipped:
                dipped = ac < 0.0
                continue
            if ac > best_ac:
                best_lag, best_ac = lag, ac
    if best_lag and best_ac >= min_autocorr:
        sse_seas = sum((ys[i] - ys[i - best_lag]) ** 2
                       for i in range(best_lag, n))
        sst_seas = sum((ys[i] - mean_y) ** 2 for i in range(best_lag, n))
        if sst_seas > 1e-12:
            r2_seas = max(0.0, 1.0 - sse_seas / sst_seas)
            if r2_seas > r2_trend:
                dt = (ts[-1] - ts[0]) / (n - 1)
                return SeriesFit(intercept, slope, period=best_lag * dt,
                                 r2=r2_seas, mean=mean_y)
    return SeriesFit(intercept, slope, r2=r2_trend, mean=mean_y)


@dataclasses.dataclass(frozen=True)
class ShiftPrediction:
    """One predicted traffic-mix shift.

    ``shares``/``demand`` describe the *settled* new phase (the point of
    maximal predicted drift after the crossing), not the mid-transition
    crossing itself — they are what a partition for the new phase should be
    sized against and what live rates are compared to when confirming."""
    t_shift: float                     # when the shares cross the threshold
    confidence: float                  # demand-weighted mean R² of the fits
    shares: Dict[str, float]           # predicted shares, settled new phase
    demand: Dict[str, float]           # predicted rates, settled new phase


class DemandForecaster:
    """Per-pipeline demand fits + the mix-shift predictor.

    ``fit`` consumes ``FleetMonitor.rate_history`` output; ``predict_shift``
    answers "when will the predicted demand shares have drifted from their
    current value by the hysteresis threshold?" — ``None`` whenever the
    fits cannot justify acting (confidence below ``min_conf``) or no
    crossing lies within the horizon.  Mis-predictions are therefore
    bounded upstream: the scheduler only ever stages pre-warm loads for a
    gated, thresholded prediction, at most once per pre-warm cooldown.
    """

    def __init__(self, bin_s: float, min_conf: float = 0.35,
                 min_autocorr: float = 0.3):
        self.bin_s = bin_s
        self.min_conf = min_conf
        self.min_autocorr = min_autocorr
        self.fits: Dict[str, SeriesFit] = {}
        self._ts: List[float] = []
        self._ys: Dict[str, List[float]] = {}

    def fit(self, history: History) -> None:
        self.fits = {}
        self._ts = [t for t, _ in history]
        self._ys = {}
        if not history:
            return
        for p in sorted(history[0][1]):
            ys = [d.get(p, 0.0) for _, d in history]
            self._ys[p] = ys
            self.fits[p] = fit_series(self._ts, ys, self.min_autocorr)

    def _seasonal_value(self, p: str, t: float) -> float:
        """Seasonal-naive rate: the fold-averaged observed rate one (and,
        when available, two) whole periods before ``t``, smoothed over
        3 bins — phase-exact because it *is* the measured waveform."""
        fit = self.fits[p]
        ts, ys = self._ts, self._ys[p]
        n = len(ys)
        dt = self.bin_s
        k = max(1, int(math.ceil((t - ts[-1]) / fit.period - 1e-9)))
        vals = []
        for fold in (k, k + 1):
            tf = t - fold * fit.period
            if tf < ts[0] - dt / 2 or tf > ts[-1] + dt / 2:
                continue
            i0 = int(round((tf - ts[0]) / dt))
            lo = max(0, i0 - 1)
            hi = min(n, i0 + 2)
            if lo < hi:
                vals.append(sum(ys[lo:hi]) / (hi - lo))
        if not vals:
            return fit.trend(t)
        return sum(vals) / len(vals)

    def predict_demand(self, t: float) -> Dict[str, float]:
        out = {}
        for p, fit in sorted(self.fits.items()):
            out[p] = (self._seasonal_value(p, t) if fit.period > 0.0
                      else fit.trend(t))
        return out

    def confidence(self) -> float:
        """Demand-weighted mean R² across the per-pipeline fits: the
        pipelines that carry the load must be the ones the model explains."""
        tot = sum(f.mean for f in self.fits.values())  # detlint: ignore[DET001] fits dict is registry-ordered; BENCH-byte-frozen
        if tot <= 0.0:
            return 0.0
        return sum(f.mean * f.r2
                   for _, f in sorted(self.fits.items())) / tot

    def predict_shift(self, tau: float, threshold: float, horizon: float,
                      step: Optional[float] = None
                      ) -> Optional[ShiftPrediction]:
        """Earliest ``t`` in ``(tau, tau + horizon]`` where the predicted
        demand shares drift from the model's *current* shares (its value at
        ``tau``) by >= ``threshold`` total variation — i.e. the next
        genuine mix shift, not a re-detection of the last one (comparing
        against the Monitor's trailing-window basis would flag "a shift is
        happening" the whole time the window is still catching up).
        ``None`` below the confidence gate or when no crossing is
        predicted."""
        if not self.fits:
            return None
        conf = self.confidence()
        if conf < self.min_conf:
            return None
        d0 = self.predict_demand(tau)
        tot0 = sum(d0.values())  # detlint: ignore[DET001] predict_demand dict is fits-ordered: insertion-ordered
        if tot0 <= 0.0:
            return None
        base = {p: v / tot0 for p, v in sorted(d0.items())}
        step = step if step is not None else self.bin_s
        t_shift = None
        best_tv = 0.0
        best: Optional[Tuple[Dict[str, float], Dict[str, float]]] = None
        k = 1
        while k * step <= horizon + 1e-9:
            t = tau + k * step
            d = self.predict_demand(t)
            tot = sum(d.values())  # detlint: ignore[DET001] predict_demand dict is fits-ordered: insertion-ordered
            if tot > 0.0:
                shares = {p: v / tot for p, v in sorted(d.items())}
                tv = tv_distance(shares, base)
                if t_shift is None:
                    if tv >= threshold:
                        t_shift = t
                        best_tv, best = tv, (shares, d)
                elif tv > best_tv:
                    # past the crossing: walk up to the settled new phase —
                    # the FIRST drift extreme (fold noise wiggles, so only
                    # a substantial fall ends the walk; a global argmax
                    # could overshoot through a whole phase into the
                    # opposite extreme of a smooth waveform)
                    best_tv, best = tv, (shares, d)
                elif tv < best_tv - threshold / 2.0:
                    break
            k += 1
        if t_shift is None or best is None:
            return None
        return ShiftPrediction(t_shift=t_shift, confidence=conf,
                               shares=best[0], demand=best[1])


def stage_announced_capacity(fleet, tau: float, new_total: int,
                             land: Optional[float] = None) -> int:
    """Pre-warm announced-join capacity (core/elastic.py): plan the
    partition the fleet will want once the announced nodes land and mark
    each *incoming* chip's target weights as staged while the node boots
    — incoming chips host no live work yet, so the staging DMA is free,
    and the join-time re-partition charges no reload for them.

    Marks ``fleet.prewarmed`` in exactly the currency ``stage_prewarm``
    uses (the re-partition reload accounting consumes both the same
    way), stamped at ``land`` (the join landing time) so the marks
    cannot expire inside the announce window.  Chips already in the live
    pool are untouched — their reloads follow the normal, possibly
    forecaster-staged path.  Returns the number of incoming chips
    staged."""
    orch = fleet.orch
    old_total = orch.num_chips
    if new_total <= old_total:
        return 0
    recent, measured = fleet._plan_inputs(tau)
    orch.num_chips = new_total
    try:
        demand = fleet.fleet_monitor.demand(tau)
        backlog = fleet.backlog_weights()
        weights = {p: demand.get(p, 0.0) + backlog.get(p, 0.0)
                   for p in fleet.reg.pipelines}
        budgets = orch.budgets(
            fleet.fleet_sched._objective_weights(fleet, tau, weights))
        target = orch.generate(recent, budgets, measured)
    finally:
        orch.num_chips = old_total
    if target is None:
        return 0
    stamp = tau if land is None else land
    staged = 0
    for pid in fleet.reg.pipelines:
        sub = target.subplans[pid]
        lo, _ = target.chip_ranges[pid]
        k = sub.unit_size
        for g, ptype in enumerate(sub.placements):
            need = frozenset(ptype)
            for c in range(lo + g * k, lo + (g + 1) * k):
                if c >= old_total:
                    fleet.prewarmed[c] = (pid, need, stamp)
                    staged += 1
    return staged


def rank_classes(forecast: DemandForecaster, t: float) -> List[str]:
    """Forecast keys by descending predicted demand at ``t`` (stable
    key-ascending tiebreak — deterministic under any PYTHONHASHSEED).

    Used with a forecaster fitted on *per-placement-class* history
    (``FleetMonitor.class_rate_history``): the ranking orders the
    predictive pre-warm's staging walk so the placement types the
    cross-lane batcher will lean on hardest are staged first, inside the
    same mis-prediction budget."""
    demand = forecast.predict_demand(t)
    return [k for k, _ in sorted(demand.items(), key=lambda kv: (-kv[1],
                                                                 kv[0]))]
