"""Shared-cluster co-serving for heterogeneous diffusion pipelines.

TridentServe (Algorithm 1/2) derives one placement plan *per pipeline*; a
multi-model deployment then degenerates to static per-pipeline sub-clusters
— exactly the static, manual paradigm the paper argues against, one level
up.  This module adds the missing layer: **one placement plan for the whole
cluster**, spanning every pipeline, with the chip budget per pipeline
re-derived from the live traffic mix (GENSERVE-style co-serving, DiffServe-
style demand tracking).

* ``PipelineRegistry``     — one ``Profiler`` per served pipeline.
* ``FleetPlacementPlan``   — the cluster-wide plan: per-pipeline chip
  ranges + pipeline-tagged sub-plans, so each scheduling unit carries
  ``(pipeline, placement_type)``.
* ``FleetOrchestrator``    — demand-weighted, node-quantized chip budgets
  (the unit-time footprint of each pipeline's recent traffic — the
  ``alpha_mode="demand"`` idea lifted one level up), then Algorithm 2 runs
  *per pipeline* inside its budget.
* ``FleetScheduler`` quartet — ``static`` (sub-clusters fixed at deploy
  time: today's ``--mixed``), ``proportional`` (re-partition to windowed
  demand every window, no hysteresis), ``adaptive`` (re-partition only on
  a ``FleetMonitor.mix_shift``, with hysteresis + cooldown, demand blended
  with queued backlog so a post-shift queue drains fast), ``predictive``
  (adaptive + a demand forecaster, core/forecast.py: predicts the next
  mix shift from rate history, pre-warms the target partition's weights
  on the units that will flip before the shift lands, and fires the swap
  the moment live rates confirm the prediction).
* ``FleetSimulator``       — one clock over the shared chip pool: a
  multi-lane ``ClockDriver`` over the same ``repro.core.clock.EventClock``
  kernel the single-pipeline ``Simulator`` drives (tests/test_fleet.py
  pins event-vs-tick parity on randomized multi-lane traces).  Each
  pipeline runs the unmodified single-pipeline TridentServe stack
  (``TridentScheduler`` + ``RuntimeEngine`` + ``Monitor``) inside a
  ``Lane``; on re-partition, chips change hands and the per-unit
  weight-swap cost (reload latency, charged on pipeline *or* type change)
  is paid by pre-busying the new units — so an idle Flux unit really can
  be handed to a backlogged SD3 class, at a price the hysteresis must
  beat.
* Cross-lane dynamic batching — with ``FleetConfig.cross_lane_batching``
  the fleet step becomes decide-all → fuse → execute-all: the
  ``CrossLaneBatcher`` (core/dispatcher.py) merges auxiliary E/C runs
  whose units share a ``(stage, placement_type, unit_size)`` shape across
  two or more lanes into one batched launch on a host lane's auxiliary
  units, member-selected by a grouped ILP whose multi-dimensional columns
  charge both the shared batch budget and each lane's batch-curve cap,
  charged the batched duration and completed by ONE merged event
  (``clock.MERGED_LANE``) that ``_drain`` un-merges back into per-lane
  accounting.  Off (the default) the batcher is never constructed and the
  step is the plain per-lane interleave — bit-identical by construction.

Wake-source registration (the clock.py standard: each subsystem registers
one ``tau -> Optional[next-wake-time]`` closure, once, independent of lane
count): the fleet driver registers the next-arrival source, one
Monitor-window boundary source per replace-capable lane, the FleetMonitor
demand/SLO/lending window boundaries when the scheduler can re-partition,
the broker's loan-expiry/lend-window source when lending, and the
predictive scheduler's ``forecast_wake`` (rate-history bin boundaries +
the armed predicted-shift time) when ``mode="predictive"``.  Trigger
*gates* stay in the schedulers: a wake-up is only an opportunity to look —
mix-shift hysteresis, cooldowns, and the forecast confidence gate decide
whether anything fires — so an extra wake-up can never change a decision,
only surface one earlier (``scheduler_wake_hooks`` opts the trigger-gate
crossings themselves in as wake-ups; see docs/architecture.md).

The single-pipeline system is the 1-pipeline special case: a fleet with one
registered pipeline reproduces ``Simulator`` + ``TridentScheduler`` results
exactly (tests/test_fleet.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import repro.configs as configs
from repro.core.clock import (MERGED_LANE, ClockConfig, EventClock, Lane,
                              monitor_boundary_source, replace_capable)
from repro.core.monitor import FleetMonitor
from repro.core.orchestrator import Orchestrator
from repro.core.placement import PlacementPlan
from repro.core.profiler import Profiler
from repro.core.request import Request
from repro.core.runtime import RuntimeEngine
from repro.core.simulator import SimConfig
from repro.core.trident import TridentScheduler
from repro.core import workloads


def request_footprint(prof: Profiler, req: Request) -> float:
    """Unit-time footprint of one request: Diffuse chip-seconds at the
    profiled optimal degree.  The single currency the fleet partitions by —
    demand windows, backlog weights, and chip budgets must all be measured
    in it for ``FleetOrchestrator.budgets`` to mix them."""
    k = prof.optimal_degree(req, "D")
    return prof.stage_time(req, "D", k * prof.k_min) * k * prof.k_min


class PipelineRegistry:
    """One Profiler per served pipeline, keyed by config name."""

    def __init__(self, pipeline_ids: Sequence[str] = (),
                 cross_node_sp: bool = False):
        self.cross_node_sp = cross_node_sp
        self._profs: Dict[str, Profiler] = {}
        for pid in pipeline_ids:
            self.register(pid)

    def register(self, pipeline_id: str,
                 profiler: Optional[Profiler] = None) -> Profiler:
        if profiler is None:
            profiler = Profiler(configs.get(pipeline_id),
                                cross_node_sp=self.cross_node_sp)
        self._profs[pipeline_id] = profiler
        return profiler

    def profiler(self, pipeline_id: str) -> Profiler:
        return self._profs[pipeline_id]

    @property
    def pipelines(self) -> Tuple[str, ...]:
        return tuple(self._profs)

    def __len__(self) -> int:
        return len(self._profs)

    def __contains__(self, pipeline_id: str) -> bool:
        return pipeline_id in self._profs


@dataclasses.dataclass(frozen=True)
class LendableUnit:
    """One unit of the fleet plan that may host E/C stage work for another
    pipeline between re-partitions (cross-pipeline unit lending).

    ``borrow_cost`` maps (borrower pipeline, hosted stage) to the weight-swap
    latency the borrower pays when the unit changes hands; ``return_cost``
    is what the lender pays to reload its own weights on return — advisory
    (a map-build-time estimate): the broker recharges the actual return
    reload from the lender's live plan at close, since a lane re-placement
    may retype the unit while it is on loan."""
    pipeline: str
    unit: int
    ptype: str
    aux_class: bool                    # E/C-class unit (preferred stock)
    node: int
    borrow_cost: Dict[Tuple[str, str], float]
    return_cost: float


@dataclasses.dataclass
class FleetPlacementPlan:
    """One placement plan spanning the whole cluster: contiguous chip
    ranges per pipeline, each carrying a pipeline-tagged ``PlacementPlan``."""
    total_chips: int
    chip_ranges: Dict[str, Tuple[int, int]]     # pipeline -> [lo, hi) chips
    subplans: Dict[str, PlacementPlan]
    chips_per_node: int = 8

    def budget_histogram(self) -> Dict[str, int]:
        return {p: hi - lo for p, (lo, hi) in self.chip_ranges.items()}

    def tagged_units(self) -> List[Tuple[str, str]]:
        """(pipeline, placement_type) for every scheduling unit."""
        out: List[Tuple[str, str]] = []
        for pid, plan in self.subplans.items():
            out.extend((pid, p) for p in plan.placements)
        return out

    def type_histogram(self) -> Dict[Tuple[str, str], int]:
        hist: Dict[Tuple[str, str], int] = {}
        for tag in self.tagged_units():
            hist[tag] = hist.get(tag, 0) + 1
        return hist

    def unit_chips(self, pipeline: str, unit: int) -> Tuple[int, int]:
        """[lo, hi) chip span of one scheduling unit."""
        lo, _ = self.chip_ranges[pipeline]
        k = self.subplans[pipeline].unit_size
        return (lo + unit * k, lo + (unit + 1) * k)

    def node_of_unit(self, pipeline: str, unit: int) -> int:
        """Cluster-global node id of one scheduling unit."""
        return self.unit_chips(pipeline, unit)[0] // self.chips_per_node

    def lending_map(self, registry: "PipelineRegistry"
                    ) -> Dict[int, List[LendableUnit]]:
        """Per-node map of lendable units (cross-pipeline unit lending).

        A unit is lendable to borrower B iff its chip span can hold one of
        B's scheduling units (``unit_size`` covers B's) — the hosted stage is
        always E or C, never D, so B's diffuse placement is untouched.
        Aux-class (⟨E⟩/⟨C⟩) units are the preferred stock; primary-class
        units are listed too and the broker only taps them when the lender
        has idle surplus.  Costs come from ``Profiler.stage_load_time`` via
        the host path — the same currency re-partition swaps are charged in,
        so the min-hold policy can be compared against it directly."""
        out: Dict[int, List[LendableUnit]] = {}
        for pid, sub in self.subplans.items():
            lender_prof = registry.profiler(pid)
            for g, ptype in enumerate(sub.placements):
                if sub.is_extended(g):
                    continue   # borrowed overlay slots are not lendable stock
                costs: Dict[Tuple[str, str], float] = {}
                for bid in registry.pipelines:
                    if bid == pid:
                        continue
                    bsub = self.subplans.get(bid)
                    if bsub is not None and bsub.unit_size > sub.unit_size:
                        continue   # span too small for one borrower unit
                    bprof = registry.profiler(bid)
                    for s in ("E", "C"):
                        costs[(bid, s)] = bprof.stage_load_time(
                            s, via_host=True)
                if not costs:
                    continue
                ret_cost = sum(lender_prof.stage_load_time(s, via_host=True)
                               for s in ptype)
                node = self.node_of_unit(pid, g)
                out.setdefault(node, []).append(LendableUnit(
                    pipeline=pid, unit=g, ptype=ptype,
                    aux_class=ptype in ("E", "C"), node=node,
                    borrow_cost=costs, return_cost=ret_cost))
        return out


class FleetOrchestrator:
    """Chip budgets from demand, Algorithm 2 per pipeline inside each."""

    def __init__(self, registry: PipelineRegistry, num_chips: int = 512,
                 chips_per_node: int = 8):
        self.reg = registry
        self.num_chips = num_chips
        self.chips_per_node = chips_per_node
        # per-pipeline Algorithm-2 orchestrators, resized at each partition
        self._orchs = {pid: Orchestrator(registry.profiler(pid),
                                         num_chips=chips_per_node,
                                         chips_per_node=chips_per_node)
                       for pid in registry.pipelines}

    # -- demand weights --------------------------------------------------------

    def demand_weights(self, reqs: Sequence[Request]) -> Dict[str, float]:
        """Unit-time footprint (chip-seconds of Diffuse work at the profiled
        optimal degree) per pipeline — ``alpha_mode="demand"``, one level up."""
        w = {pid: 0.0 for pid in self.reg.pipelines}
        for r in reqs:
            w[r.pipeline] += request_footprint(self.reg.profiler(r.pipeline), r)
        return w

    # SLO-weighted budget objective: a pipeline missing its deadlines gets
    # its demand weight grossed up by this gain times its windowed miss
    # fraction (miss 50% of a window -> 3x weight at the default gain).
    SLO_PRESSURE_GAIN = 4.0

    def objective_weights(self, weights: Dict[str, float],
                          slo_attainment: Dict[str, float],
                          objective: str = "demand") -> Dict[str, float]:
        """Apply ``FleetConfig.budget_objective`` to raw demand weights.

        ``"demand"`` (the default) returns ``weights`` unchanged — the
        same object, so the default fleet path stays bit-identical.
        ``"slo"`` scales each pipeline's weight by its windowed SLO-miss
        pressure: chips flow toward the pipeline that is actually missing
        deadlines, not just the one with the largest footprint (a video
        pipeline can be demand-heavy yet comfortably inside its SLO while
        an image pipeline starves).  Pipelines with no windowed finishes
        keep their raw weight (no evidence, no boost)."""
        if objective != "slo" or not slo_attainment:
            return weights
        return {p: w * (1.0 + self.SLO_PRESSURE_GAIN
                        * (1.0 - slo_attainment.get(p, 1.0)))
                for p, w in weights.items()}

    # -- chip budgets ----------------------------------------------------------

    def budgets(self, weights: Dict[str, float]) -> Dict[str, int]:
        """Demand-proportional chip budgets, quantized to whole nodes by
        largest remainder; every pipeline keeps at least one node so it can
        always serve (and Algorithm 2 stays feasible within its slice)."""
        upn = self.chips_per_node
        n_nodes = self.num_chips // upn
        pids = list(self.reg.pipelines)
        assert n_nodes >= len(pids), "cluster smaller than one node/pipeline"
        total = sum(max(0.0, weights.get(p, 0.0)) for p in pids)
        if total <= 0.0:
            raw = {p: n_nodes / len(pids) for p in pids}
        else:
            raw = {p: n_nodes * max(0.0, weights.get(p, 0.0)) / total
                   for p in pids}
        base = {p: max(1, math.floor(raw[p])) for p in pids}
        while sum(base.values()) > n_nodes:   # floors may overshoot n_nodes  # detlint: ignore[DET001] int node counts: exact
            p = max(pids, key=lambda p: base[p])
            base[p] -= 1
        rem = n_nodes - sum(base.values())  # detlint: ignore[DET001] int node counts: exact
        order = sorted(pids, key=lambda p: -(raw[p] - math.floor(raw[p])))
        i = 0
        while rem > 0:
            base[order[i % len(order)]] += 1
            rem -= 1
            i += 1
        return {p: base[p] * upn for p in pids}

    # -- plan generation -------------------------------------------------------

    def generate(self, recent: Dict[str, Sequence[Request]],
                 budgets: Dict[str, int],
                 measured: Optional[Dict[str, Dict[str, float]]] = None
                 ) -> Optional[FleetPlacementPlan]:
        """One cluster-wide plan: Algorithm 2 per pipeline on its budget.
        Returns ``None`` when any pipeline has no feasible placement (the
        same contract ``Orchestrator.generate`` exposes)."""
        ranges: Dict[str, Tuple[int, int]] = {}
        subplans: Dict[str, PlacementPlan] = {}
        lo = 0
        for pid in self.reg.pipelines:
            chips = budgets[pid]
            orch = self._orchs[pid]
            orch.resize(chips)
            plan = orch.generate(list(recent.get(pid, ())),
                                 measured_rates=(measured or {}).get(pid))
            if plan is None:
                return None
            plan.pipeline = pid
            ranges[pid] = (lo, lo + chips)
            subplans[pid] = plan
            lo += chips
        return FleetPlacementPlan(self.num_chips, ranges, subplans,
                                  chips_per_node=self.chips_per_node)


@dataclasses.dataclass
class FleetConfig:
    num_chips: int = 512
    chips_per_node: int = 8
    tick: float = 0.25
    horizon_slack: float = 600.0
    seed: int = 0
    proactive_push: bool = True
    adjust_on_dispatch: bool = True
    mode: str = "event"               # "event" | "tick" (legacy reference
                                      # loop; the unified kernel gives the
                                      # fleet the tick mode for free, used
                                      # by the multi-lane parity tests)
    max_idle_gap: float = 1.0
    adaptive_idle_gap: bool = True    # profile-guided heartbeat (fleet runs
                                      # are long; quiet lanes should not pin
                                      # the clock to 1 s jumps)
    idle_gap_max: float = 16.0
    aggregate_ilp: bool = True        # multiplicity-aware dispatch ILP
    t_win: float = 180.0              # fleet demand window (s)
    hysteresis: float = 0.10          # min demand-share move to re-partition
    cooldown: float = 120.0           # min time between re-partitions (s)
    budget_objective: str = "demand"  # "demand" (pure footprint shares) |
                                      # "slo" (demand weighted by windowed
                                      # SLO-miss pressure; see
                                      # FleetOrchestrator.objective_weights).
                                      # Default stays "demand" — bit-
                                      # identical to the committed traces.
    scheduler_wake_hooks: bool = False # register the fleet scheduler's
                                      # ``next_wake`` trigger-crossing hook
                                      # (window cadence / cooldown expiry)
                                      # as a kernel wake source.  Opt-in:
                                      # extra wake-ups shift heartbeat
                                      # phase, so the default keeps the
                                      # committed BENCH traces bit-exact;
                                      # the event/tick parity tests turn it
                                      # on so threshold crossings are seen
                                      # at the same grid point both modes.
    # Monitor-window wake-ups while fully idle (the stale-window fix): off
    # by default so existing fleet traces reproduce bit-identically; the
    # lending clock forces it on (loans must return during idle gaps).
    idle_window_wakeups: bool = False
    # -- cross-pipeline unit lending (core/lending.py), default OFF ----------
    lending: bool = False
    lend_min_hold: float = 45.0       # a loan is held at least this long (s)
    lend_win: float = 20.0            # pressure window for borrow/return (s)
    # pressure is queued chip-seconds of work per owned chip (windowed mean)
    lend_min_pressure: float = 0.5    # borrow above this; lender reclaims at it
    lend_low_pressure: float = 0.05   # drained-borrower / busy-lender bound
    lend_reserve: int = 2             # idle units a lender always keeps
    lend_util_target: float = 0.4     # a lender keeps busy_mean/target units
                                      # for itself; only the surplus is stock
    lend_max_loans: int = 32          # concurrent loans per borrower
    lend_demand_frac: float = 8.0     # loan target per second of pressure
    lend_min_stage_s: float = 0.5     # borrow only when the hosted stage is
                                      # worth at least this long per request
                                      # (reloads never pay for ms decodes)
    # -- predictive re-partitioning (core/forecast.py), used only when the
    # fleet runs mode="predictive"; every other scheduler ignores these and
    # the off path stays byte-identical to the committed baselines ---------
    forecast_bin: float = 10.0        # rate-history bin width (s)
    forecast_history: float = 600.0   # retained rate-history span (s)
    forecast_horizon: float = 240.0   # how far ahead to scan for a shift (s)
    forecast_min_conf: float = 0.35   # R² gate: act on a prediction only
                                      # when the fits explain this much of
                                      # the demand variance (stationary
                                      # traffic never crosses it)
    predictive_confirm: float = 0.4   # fraction of the hysteresis threshold
                                      # the *live* shares must have moved
                                      # (toward the prediction) before a
                                      # predicted shift may fire the swap
    forecast_grace: float = 60.0      # a predicted shift unconfirmed this
                                      # long after its time is dropped as a
                                      # mis-prediction (fall back to plain
                                      # adaptive behavior)
    prewarm_lead: float = 45.0        # start staging this long before the
                                      # predicted shift (must cover the
                                      # weight-reload latency)
    prewarm_budget: int = 16          # max units staged per pre-warm — the
                                      # mis-prediction cost bound
    prewarm_cooldown: float = 60.0    # min time between pre-warm stagings
    prewarm_ttl: float = 240.0        # staged weights are evicted (ignored
                                      # at cutover) after this long
    # -- cross-lane dynamic batching (core/dispatcher.py CrossLaneBatcher),
    # default OFF: the batcher object is never constructed and the per-lane
    # step loop is byte-identical to the committed BENCH trajectories -------
    cross_lane_batching: bool = False
    cross_lane_max_batch: int = 0     # 0 = profiler batch-curve cap; >0
                                      # replaces BOTH the fused launch's
                                      # shared batch budget and the
                                      # per-lane curve caps (an explicit
                                      # operator throughput/latency trade)
    # -- scale-out fast paths (benchmarks/e2e.py --scale), default OFF so
    # every committed BENCH trajectory stays byte-identical ------------------
    array_state: bool = False         # array-backed lane state (PendingSet
                                      # deadline column + Monitor window
                                      # columns); bit-identical trajectories
                                      # by construction, pinned by
                                      # tests/test_scale_parity.py
    incremental_ilp: bool = False     # persist each lane's dispatch model
                                      # across wake-ups: skip the ILP solve
                                      # when (options, budgets) are unchanged
                                      # and thread cross-tick warm incumbents
                                      # through the cross-lane batcher's
                                      # grouped solves (docs/architecture.md:
                                      # incremental-solve contract)
    step_changed_lanes_only: bool = False  # O(changed-lanes) fleet stepping:
                                      # a wake-up steps only lanes with
                                      # pending work or a dirty event
                                      # (arrival / completion / window
                                      # boundary / re-partition), and a
                                      # re-partition rebuilds only lanes
                                      # whose chip range or sub-plan moved.
                                      # Semantics-preserving but trajectory-
                                      # CHANGING (idle lanes skip backlog
                                      # samples), so it guards no committed
                                      # BENCH and is ignored under lending /
                                      # cross-lane batching (both need every
                                      # lane visited every step).
    # -- elastic, failure-prone capacity (core/elastic.py), default OFF: the
    # FaultInjector is never constructed and every committed BENCH
    # trajectory stays byte-identical --------------------------------------
    elastic: bool = False             # play elastic_schedule through a
                                      # FaultInjector wake source
    elastic_schedule: Tuple = ()      # CapacityEvents (core/workloads.py
                                      # builds the preemption-storm and
                                      # region-evacuation schedules)
    elastic_drain: bool = True        # act on preemption notices: doomed
                                      # units drain stage-aware (only work
                                      # landing before the loss), in-flight
                                      # work that would outlive it requeues
                                      # ahead of the loss (the drain-unaware
                                      # bench arm turns this off)
    elastic_prewarm: bool = True      # stage target weights onto announced
                                      # join capacity during the lead window
    degrade_detect_ratio: float = 1.6 # quarantine a unit whose per-run mean
                                      # exceeds this x its pool mean
    degrade_min_samples: int = 6      # per-unit samples before quarantine

    def lane_sim_cfg(self, num_chips: int) -> SimConfig:
        return SimConfig(num_chips=num_chips, tick=self.tick,
                         horizon_slack=self.horizon_slack,
                         proactive_push=self.proactive_push,
                         adjust_on_dispatch=self.adjust_on_dispatch,
                         seed=self.seed, mode="event",
                         max_idle_gap=self.max_idle_gap,
                         adaptive_idle_gap=self.adaptive_idle_gap,
                         idle_gap_max=self.idle_gap_max,
                         array_state=self.array_state)

    def clock_cfg(self, horizon: float) -> ClockConfig:
        return ClockConfig(tick=self.tick, horizon=horizon, mode=self.mode,
                           max_idle_gap=self.max_idle_gap,
                           adaptive_idle_gap=self.adaptive_idle_gap,
                           idle_gap_max=self.idle_gap_max)


def make_lane(pipeline: str, prof: Profiler, sim_cfg: SimConfig,
              trace: Sequence[Request], aggregate_ilp: bool = False,
              cross_lane_batching: bool = False,
              incremental_ilp: bool = False) -> Lane:
    """One pipeline's slice of the fleet: the unmodified single-pipeline
    TridentServe stack over a chip range, inside the shared ``Lane``
    container (repro.core.clock) — so the lane *is* the 1-pipeline
    special case."""
    return Lane(pipeline, prof,
                TridentScheduler(prof, sim_cfg, trace,
                                 aggregate_ilp=aggregate_ilp,
                                 cross_lane_batching=cross_lane_batching,
                                 incremental_ilp=incremental_ilp),
                array_state=sim_cfg.array_state)


# ---------------------------------------------------------------- schedulers

class FleetScheduler:
    """Static sub-clusters: partitioned once from the deploy-time traffic
    sample (the first fleet window of the trace), never moved — today's
    ``--mixed`` behavior expressed inside the fleet substrate."""

    name = "fleet-static"

    def __init__(self, fleet_orch: FleetOrchestrator, fleet_cfg: FleetConfig,
                 fixed_budgets: Optional[Dict[str, int]] = None):
        self.orch = fleet_orch
        self.cfg = fleet_cfg
        self.fixed_budgets = fixed_budgets
        self.basis_shares: Optional[Dict[str, float]] = None

    def initial_budgets(self, trace: Sequence[Request]) -> Dict[str, int]:
        if self.fixed_budgets is not None:
            return dict(self.fixed_budgets)
        prefix = [r for r in trace if r.arrival <= self.cfg.t_win]
        if not prefix:
            prefix = list(trace[:256])
        w = self.orch.demand_weights(prefix)
        total = sum(w.values())  # detlint: ignore[DET001] demand_weights dict is registry-ordered; BENCH-byte-frozen
        if total > 0:
            self.basis_shares = {p: v / total for p, v in w.items()}
        return self.orch.budgets(w)

    def maybe_repartition(self, fleet: "FleetSimulator", tau: float
                          ) -> Optional[Dict[str, int]]:
        return None

    def maybe_prewarm(self, fleet: "FleetSimulator", tau: float) -> None:
        """Predictive hook (``PredictiveFleetScheduler``): stage the next
        partition's weight loads ahead of a predicted shift.  Base: no-op."""
        return None

    def next_wake(self, fleet: "FleetSimulator", tau: float
                  ) -> Optional[float]:
        """Event-source plug-in (opt-in via
        ``FleetConfig.scheduler_wake_hooks``): the earliest future time
        this scheduler's re-partition trigger can *newly* fire — a window
        cadence or cooldown expiring.  Demand-share drift itself only
        moves on arrivals, which are already wake-ups."""
        return None

    def on_repartitioned(self, fleet: "FleetSimulator", tau: float) -> None:
        """A re-partition just landed: adopt the demand basis the new
        partition answers to.  Default: the windowed shares at swap time
        (the trigger must stop firing for the mix it just served).  The
        predictive scheduler overrides this for its anticipatory swaps —
        the trailing window still remembers the old phase there, and
        re-arming against it would chase the swap with redundant
        corrections."""
        self.basis_shares = fleet.fleet_monitor.demand_shares(tau)

    def _objective_weights(self, fleet: "FleetSimulator", tau: float,
                           weights: Dict[str, float]) -> Dict[str, float]:
        return self.orch.objective_weights(
            weights, fleet.fleet_monitor.slo_attainment(tau),
            self.cfg.budget_objective)


class ProportionalFleetScheduler(FleetScheduler):
    """Re-partition to the windowed demand shares at every fleet window —
    no hysteresis, so weight-swap cost is paid whenever node-quantized
    shares wiggle.  The ablation the adaptive scheduler is judged against."""

    name = "fleet-prop"

    def maybe_repartition(self, fleet, tau):
        mon = fleet.fleet_monitor
        if tau - mon.last_repartition < self.cfg.t_win:
            return None
        shares = mon.demand_shares(tau)
        if not shares:
            return None
        budgets = self.orch.budgets(self._objective_weights(fleet, tau,
                                                            shares))
        if budgets == fleet.plan.budget_histogram():
            self.basis_shares = shares
            mon.last_repartition = tau   # window served; check again next win
            return None
        return budgets

    def next_wake(self, fleet, tau):
        cadence = fleet.fleet_monitor.last_repartition + self.cfg.t_win
        return cadence if cadence > tau else None


class AdaptiveFleetScheduler(FleetScheduler):
    """Re-partition only on a Monitor-detected traffic-mix shift (total
    variation of windowed demand shares vs the partition's basis >= the
    hysteresis threshold, past the cooldown).  Budgets weight windowed
    arrival demand *plus* the queued backlog footprint, so chips stranded
    on a now-idle pipeline move to the backlogged one and drain its queue."""

    name = "fleet-adaptive"

    def maybe_repartition(self, fleet, tau):
        mon = fleet.fleet_monitor
        if not mon.mix_shift(tau, self.basis_shares,
                             threshold=self.cfg.hysteresis,
                             cooldown=self.cfg.cooldown):
            return None
        shares = mon.demand_shares(tau)
        demand = mon.demand(tau)
        backlog = fleet.backlog_weights()
        weights = {p: demand.get(p, 0.0) + backlog.get(p, 0.0)
                   for p in self.orch.reg.pipelines}
        budgets = self.orch.budgets(self._objective_weights(fleet, tau,
                                                            weights))
        if budgets == fleet.plan.budget_histogram():
            # partition already matches the shifted demand at node
            # granularity: adopt the shares as the new basis so the trigger
            # stops firing.  Otherwise the basis only moves once the swap
            # actually succeeds (FleetSimulator._repartition) — an aborted
            # re-partition must leave the trigger armed.
            self.basis_shares = shares
            return None
        return budgets

    def next_wake(self, fleet, tau):
        cool = fleet.fleet_monitor.last_repartition + self.cfg.cooldown
        return cool if cool > tau else None


class PredictiveFleetScheduler(AdaptiveFleetScheduler):
    """Adaptive + a demand forecaster (core/forecast.py): predicts the next
    traffic-mix shift from per-pipeline windowed-rate history (trend + one
    harmonic, R²-gated), **pre-warms** the target partition's weights on
    the units that will flip *before* the shift lands (overlapping the
    reload with the tail of the old mix, so the swap charges (near-)zero
    downtime when the prediction is right), and fires the re-partition at
    the predicted shift once the live shares confirm it — instead of a
    detection window after it.  Wrong predictions cost at most the
    pre-warm budget's reloads per pre-warm cooldown; everything else falls
    back to plain adaptive behavior.

    Determinism contract: fits and staging attempts happen only at
    forecast-bin boundaries — grid points both clock modes visit (the
    driver registers ``forecast_wake`` as a kernel wake source, like
    ``broker.next_wake``) — so the event and tick clocks derive identical
    predictions and identical pre-warm trajectories."""

    name = "fleet-predictive"
    uses_forecast = True
    MIN_BINS = 12                      # bins before the first fit attempt

    def __init__(self, fleet_orch: FleetOrchestrator, fleet_cfg: FleetConfig,
                 fixed_budgets: Optional[Dict[str, int]] = None):
        super().__init__(fleet_orch, fleet_cfg, fixed_budgets)
        from repro.core.forecast import DemandForecaster
        self.forecast = DemandForecaster(bin_s=fleet_cfg.forecast_bin,
                                         min_conf=fleet_cfg.forecast_min_conf)
        self._pred = None
        self._fit_bin = -1
        self._last_prewarm = -1e9
        self._fired_shares = None      # target shares of an in-flight
                                       # predictive fire (becomes the basis)
        self._cand = None              # last bin's candidate prediction —
                                       # a prediction arms only when two
                                       # consecutive bins agree on it
        # pre-warm campaign: one per armed prediction, staging incrementally
        # (idle units only) across the lead window under one unit budget
        self._campaign_pred = None
        self._campaign_budgets = None
        self._campaign_staged = 0
        self.early_fires = 0           # predictively fired re-partitions
        self.prewarms = 0              # units staged across the run
        self._class_fc = None          # per-placement-class forecaster,
                                       # built lazily (cross-lane batching
                                       # runs only; see _class_priority)

    # -- wake source (registered by the driver like broker.next_wake) ---------

    def forecast_wake(self, tau: float) -> Optional[float]:
        """Earliest future forecast event the clock must visit: the next
        rate-history bin boundary (fits/staging happen only there), and the
        predicted shift time while a prediction is armed (the predictive
        fire condition crosses there)."""
        nxt = (math.floor(tau / self.cfg.forecast_bin) + 1.0) \
            * self.cfg.forecast_bin
        if self._pred is not None and tau < self._pred.t_shift:
            nxt = min(nxt, self._pred.t_shift)
        return nxt

    # -- forecasting -----------------------------------------------------------

    def maybe_prewarm(self, fleet: "FleetSimulator", tau: float) -> None:
        cfg = self.cfg
        cur_bin = int(tau // cfg.forecast_bin)
        if cur_bin == self._fit_bin:
            return                     # fits only move at bin boundaries
        self._fit_bin = cur_bin
        pred = self._pred
        if pred is not None and tau > pred.t_shift + cfg.forecast_grace:
            pred = self._pred = None   # shift never confirmed: mispredicted
        if pred is None or tau < pred.t_shift - cfg.prewarm_lead:
            # (re)predict freely while outside the pre-warm window; once
            # staging can begin the armed prediction is frozen, so the
            # refit at the shift itself cannot erase it before the live
            # shares get their chance to confirm it
            from repro.core.forecast import tv_distance
            hist = fleet.fleet_monitor.rate_history(
                tau, self.orch.reg.pipelines)
            if len(hist) < self.MIN_BINS:
                self._pred = self._cand = None
                return
            self.forecast.fit(hist)
            cand = self.forecast.predict_shift(
                tau, threshold=cfg.hysteresis, horizon=cfg.forecast_horizon)
            prev, self._cand = self._cand, cand
            # a single bin's fit can blip (a lost period, a spurious trend)
            # and point the campaign at a phantom shift: arm only when two
            # consecutive bins agree on when the shift lands and what mix
            # it lands on
            stable = (cand is not None and prev is not None
                      and abs(cand.t_shift - prev.t_shift)
                      <= 2.0 * cfg.forecast_bin
                      and tv_distance(cand.shares, prev.shares)
                      <= cfg.hysteresis / 2.0)
            pred = self._pred = cand if stable else None
        if pred is None:
            return
        if tau < pred.t_shift - cfg.prewarm_lead:
            return                     # too early: weights would sit staged
        if self._campaign_pred is not pred:
            # one staging campaign per armed prediction, at most one per
            # pre-warm cooldown — the mis-prediction frequency bound
            if tau - self._last_prewarm < cfg.prewarm_cooldown:
                return
            self._last_prewarm = tau
            self._campaign_pred = pred
            self._campaign_budgets = self._target_budgets(fleet, tau, pred)
            self._campaign_staged = 0
        budgets = self._campaign_budgets
        if budgets is None or budgets == fleet.plan.budget_histogram():
            return
        left = cfg.prewarm_budget - self._campaign_staged
        if left > 0:
            # idle units only: busy units are deferred to the next bin's
            # retry, so staging rides the old mix's idle tail instead of
            # stalling live work
            n = fleet.stage_prewarm(
                budgets, tau, limit=left, idle_only=True,
                class_priority=self._class_priority(fleet, tau))
            self._campaign_staged += n
            self.prewarms += n

    def _class_priority(self, fleet: "FleetSimulator",
                        tau: float) -> Optional[List[str]]:
        """Placement classes by predicted demand at the armed shift time
        (the PR 5 follow-up): with cross-lane batching on, fused E/C
        launches concentrate on the hottest auxiliary class, so the
        pre-warm budget should stage the placement-type *mix* the batcher
        will want first — not just per-pipeline chip totals.  ``None``
        (= plan-order staging, byte-identical to the un-prioritized walk)
        unless the batcher is on and the class history has enough bins."""
        if not self.cfg.cross_lane_batching:
            return None
        hist = fleet.fleet_monitor.class_rate_history(tau, ("E", "C"))
        if len(hist) < self.MIN_BINS:
            return None
        from repro.core.forecast import DemandForecaster, rank_classes
        if self._class_fc is None:
            self._class_fc = DemandForecaster(
                bin_s=self.cfg.forecast_bin,
                min_conf=self.cfg.forecast_min_conf)
        self._class_fc.fit(hist)
        t = self._pred.t_shift if self._pred is not None else tau
        return rank_classes(self._class_fc, t)

    def _target_budgets(self, fleet: "FleetSimulator", tau: float,
                        pred) -> Optional[Dict[str, int]]:
        """Chip budgets for the partition the predicted post-shift mix will
        need: the settled new-phase demand rates (``pred.demand``), in the
        fleet's windowed chip-seconds currency."""
        w = {p: pred.demand.get(p, 0.0) * self.cfg.t_win
             for p in self.orch.reg.pipelines}
        if sum(w.values()) <= 0.0:  # detlint: ignore[DET001] dict-comp over registry order: insertion-ordered
            return None
        return self.orch.budgets(self._objective_weights(fleet, tau, w))

    def _recent_rates(self, fleet: "FleetSimulator", tau: float,
                      nbins: int = 3) -> Optional[Dict[str, float]]:
        """Near-instantaneous observed demand rates: the last ``nbins``
        completed rate-history bins.  The t_win demand window needs half a
        window to register a flip; these bins see it within seconds —
        that is what confirms (or refutes) a predicted shift."""
        hist = fleet.fleet_monitor.rate_history(tau, self.orch.reg.pipelines,
                                                last=nbins)
        if len(hist) < nbins:
            return None
        rates = {p: 0.0 for p in self.orch.reg.pipelines}
        for _, d in hist[-nbins:]:
            for p in self.orch.reg.pipelines:
                rates[p] += d.get(p, 0.0) / nbins
        return rates

    # -- re-partitioning -------------------------------------------------------

    def maybe_repartition(self, fleet, tau):
        cfg = self.cfg
        mon = fleet.fleet_monitor
        pred = self._pred
        if pred is None or tau < pred.t_shift - cfg.prewarm_lead:
            # no imminent prediction: plain adaptive behavior
            return super().maybe_repartition(fleet, tau)
        # an imminent predicted shift owns the cooldown: the reactive
        # trigger — whose trailing window would fire late and size the
        # partition for the *old* phase — holds while the live rates are
        # still consistent with "the shift has not landed yet".  The hold
        # is only ever safe against that evidence: the moment the live
        # rates shift AWAY from the prediction, it is wrong *now* and
        # reactive behavior resumes immediately (and ``forecast_grace``
        # expires a shift that never shows at all).
        from repro.core.forecast import tv_distance
        rates = self._recent_rates(fleet, tau)
        tot = sum(rates.values()) if rates else 0.0  # detlint: ignore[DET001] rate dict is bin-fill-ordered; BENCH-byte-frozen
        if tot > 0.0 and self.basis_shares:
            obs = {p: v / tot for p, v in sorted(rates.items())}
            moved = tv_distance(obs, self.basis_shares)
            if moved >= cfg.predictive_confirm * cfg.hysteresis:
                # the live mix has genuinely moved — with or against us?
                # confirmed: past the halfway point toward the predicted
                # mix.  contradicted: a full-threshold move that leaves the
                # observation *farther* from the prediction than the basis
                # was — i.e. the opposite direction, not merely a
                # transition still in flight (mid-swing the observation is
                # a full hysteresis from the basis yet short of halfway;
                # dropping there would kill every correct prediction).
                toward = (tv_distance(obs, pred.shares)
                          < tv_distance(obs, self.basis_shares))
                away = (tv_distance(obs, pred.shares)
                        > tv_distance(self.basis_shares, pred.shares)
                        + cfg.predictive_confirm * cfg.hysteresis)
                if moved >= cfg.hysteresis and away:
                    self._pred = self._cand = None
                    return super().maybe_repartition(fleet, tau)
                if toward and tau - mon.last_repartition >= cfg.cooldown:
                    # confirmed: fire now (even a little before the
                    # predicted instant — the shift is the evidence, the
                    # timestamp was the estimate), sizing each pipeline by
                    # the *larger* of its forecast and its live rate, plus
                    # its queued backlog.  The forecast may add capacity
                    # ahead of demand, but never cut a pipeline below the
                    # live evidence — a wrong extrapolation (a local phase
                    # tail mistaken for a trend) must not defund a lane
                    # the observed traffic still needs.
                    backlog = fleet.backlog_weights()
                    weights = {
                        p: (max(pred.demand.get(p, 0.0),
                                rates.get(p, 0.0)) * cfg.t_win
                            + backlog.get(p, 0.0))
                        for p in self.orch.reg.pipelines}
                    budgets = self.orch.budgets(
                        self._objective_weights(fleet, tau, weights))
                    self._pred = None  # consumed
                    # the basis becomes the *settled predicted mix* — what
                    # the live shares will read once the transition (and
                    # the backlog transient folded into the sizing weights)
                    # has passed.  A weights-derived basis would sit midway
                    # between the phases and read every settled observation
                    # as a fresh shift.
                    if budgets == fleet.plan.budget_histogram():
                        # the partition already fits the shifted mix: adopt
                        # the target shares so the trailing window cannot
                        # re-trigger a redundant swap while it catches up
                        self.basis_shares = dict(pred.shares)
                        return None
                    self.early_fires += 1
                    self._fired_shares = dict(pred.shares)
                    return budgets
        return None

    def on_repartitioned(self, fleet, tau):
        """Predictive fires size the partition for where demand is going;
        the trailing demand window still remembers the old phase for
        ~t_win/2 after the shift, so adopting it as the basis (the default)
        would immediately re-arm the mix-shift trigger against the very mix
        the swap just provisioned — chasing it with redundant corrections
        that burn the cooldown exactly when the *next* flip needs it.
        Predictive fires adopt their target shares; reactive fallback swaps
        adopt the freshest observed rates (near-instantaneous bins) when
        available, the trailing window otherwise."""
        if self._fired_shares is not None:
            self.basis_shares = self._fired_shares
            self._fired_shares = None
            return
        rates = self._recent_rates(fleet, tau)
        tot = sum(rates.values()) if rates else 0.0  # detlint: ignore[DET001] rate dict is bin-fill-ordered; BENCH-byte-frozen
        if tot > 0.0:
            self.basis_shares = {p: v / tot
                                 for p, v in sorted(rates.items())}
        else:
            super().on_repartitioned(fleet, tau)


FLEET_SCHEDULERS = {
    "static": FleetScheduler,
    "proportional": ProportionalFleetScheduler,
    "adaptive": AdaptiveFleetScheduler,
    "predictive": PredictiveFleetScheduler,
}


# ---------------------------------------------------------------- results

@dataclasses.dataclass
class FleetResult:
    scheduler: str
    num_chips: int
    oom: bool
    n_requests: int
    n_finished: int
    n_request_oom: int
    slo_attainment: float
    goodput: float                    # on-time completions / s of trace span
    mean_latency: float
    p95_latency: float
    per_pipeline: Dict[str, Dict[str, float]]
    # cumulative RuntimeEngine counters per lane, summed across the engines
    # retired by re-partitions (Lane.bank_engine_stats)
    engine_stats: Dict[str, Dict[str, float]]
    repartitions: List[Tuple[float, Dict[str, int]]]
    swap_cost_s: float
    units_reloaded: int
    sched_wakeups: int
    # cross-pipeline unit lending (zeros unless FleetConfig.lending)
    loans: int = 0
    borrowed_unit_seconds: float = 0.0
    lend_swap_cost_s: float = 0.0
    borrowed_stage_runs: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    # predictive re-partitioning (zeros unless mode="predictive")
    prewarm_units: int = 0             # target units staged ahead of shifts
    prewarm_cost_s: float = 0.0        # staging reload time charged
    prewarm_hits: int = 0              # cutover units whose reload was
                                       # fully averted by staged weights
    prewarm_loan_returns: int = 0      # loans force-closed by staging
    predictive_repartitions: int = 0   # swaps fired by the forecaster
    # cross-lane dynamic batching (zeros unless
    # FleetConfig.cross_lane_batching)
    cross_lane_merges: int = 0         # fused multi-lane launches charged
    cross_lane_merged_requests: int = 0  # batch items across all fusions
    # elastic capacity / fault injection (zeros unless FleetConfig.elastic)
    capacity_events: int = 0           # join/preempt/degrade/recover landed
    nodes_joined: int = 0
    nodes_lost: int = 0
    requeued_requests: int = 0         # in-flight work revoked + requeued
    drained_units: int = 0             # units drained on preemption notice
    quarantined_units: int = 0         # degraded units detected + removed
    elastic_prewarm_chips: int = 0     # announced-join chips staged ahead
    final_chips: int = 0               # surviving pool size at run end

    def summary(self) -> str:
        if self.oom:
            return f"{self.scheduler:15s} OOM (no feasible fleet plan)"
        lend = (f"  loans={self.loans} "
                f"borrowed={self.borrowed_unit_seconds:.0f}unit-s"
                if self.loans else "")
        return (f"{self.scheduler:15s} SLO={self.slo_attainment * 100:5.1f}%  "
                f"goodput={self.goodput:6.2f}/s  "
                f"mean={self.mean_latency:7.2f}s  "
                f"p95={self.p95_latency:7.2f}s  "
                f"fin={self.n_finished}/{self.n_requests}  "
                f"swaps={len(self.repartitions) - 1}{lend}")


class FleetSimulator:
    """Co-serving simulator: one clock, one chip pool, one fleet placement
    plan; per-pipeline lanes run the production single-pipeline scheduler
    code unchanged.  A multi-lane ``ClockDriver`` over the shared
    ``repro.core.clock.EventClock`` kernel — the same loop the
    single-pipeline ``Simulator`` drives, so the 1-pipeline fleet is
    bit-identical to it by construction."""

    def __init__(self, registry: PipelineRegistry, scheduler: FleetScheduler,
                 trace: Sequence[Request], cfg: Optional[FleetConfig] = None):
        self.reg = registry
        self.fleet_sched = scheduler
        self.orch = scheduler.orch
        self.trace = sorted(trace, key=lambda r: r.arrival)
        self.cfg = cfg or FleetConfig()
        assert all(r.pipeline in registry for r in self.trace), \
            "trace contains requests for unregistered pipelines"
        self.fleet_monitor = FleetMonitor(t_win=self.cfg.t_win,
                                          lend_win=self.cfg.lend_win)
        self.lanes: Dict[str, Lane] = {}
        self.plan: Optional[FleetPlacementPlan] = None
        trace_end = self.trace[-1].arrival if self.trace else 0.0
        self.clock = EventClock(
            self.cfg.clock_cfg(trace_end + self.cfg.horizon_slack))
        self._ai = 0                   # arrival cursor into the trace
        self._fp_cache: Dict[tuple, float] = {}   # class -> footprint
        self.repartition_log: List[Tuple[float, Dict[str, int]]] = []
        self.swap_cost_s = 0.0
        self.units_reloaded = 0
        self._track_flips = (self.cfg.mode == "event"
                             and self.cfg.adaptive_idle_gap)
        self._repartition_capable = (
            type(scheduler).maybe_repartition
            is not FleetScheduler.maybe_repartition)
        self.broker = None
        if self.cfg.lending:
            from repro.core.lending import LendingBroker
            self.broker = LendingBroker(self.cfg, registry)
        # predictive pre-warm (core/forecast.py): chip -> (target pipeline,
        # staged stages, staging time).  Empty — and the rate history
        # disabled — unless the scheduler carries a forecaster, so every
        # other mode's trajectory is byte-identical to the committed runs.
        self.uses_forecast = getattr(scheduler, "uses_forecast", False)
        if self.uses_forecast:
            self.fleet_monitor.enable_rate_history(self.cfg.forecast_bin,
                                                   self.cfg.forecast_history)
        # cross-lane dynamic batching (core/dispatcher.py): the batcher is
        # only constructed when the knob is on — the off path never touches
        # it and the per-lane step loop below stays byte-identical
        self._xl = None
        if self.cfg.cross_lane_batching:
            from repro.core.dispatcher import CrossLaneBatcher
            self._xl = CrossLaneBatcher(
                max_batch=self.cfg.cross_lane_max_batch,
                incremental=self.cfg.incremental_ilp)
        # elastic capacity / fault injection (core/elastic.py): like the
        # broker and the batcher, the injector only exists when the knob is
        # on — the off path never constructs it and stays byte-identical
        self.injector = None
        if self.cfg.elastic:
            from repro.core.elastic import FaultInjector
            self.injector = FaultInjector(self.cfg)
            if self._xl is not None:
                self._xl.track_units = True
        # O(changed-lanes) stepping (tentpole c): a wake-up visits only
        # lanes with pending work or a dirty event.  Disabled under lending
        # and cross-lane batching — the broker samples every lane's
        # pressure each step, and the batcher must see every lane's
        # decisions to fuse across them.
        self._lane_gating = (self.cfg.step_changed_lanes_only
                             and not self.cfg.lending
                             and not self.cfg.cross_lane_batching)
        self._dirty: set = set()
        self._class_hist = (self.uses_forecast
                            and self.cfg.cross_lane_batching)
        if self._class_hist:
            # per-placement-class demand history: lets the predictive
            # scheduler pre-warm the placement-type *mix* the batcher will
            # want, not just per-pipeline totals (see maybe_prewarm)
            self.fleet_monitor.enable_class_history(self.cfg.forecast_bin,
                                                    self.cfg.forecast_history)
        self.prewarmed: Dict[int, Tuple[str, frozenset, float]] = {}
        self.prewarm_cost_s = 0.0
        self.prewarm_units = 0
        self.prewarm_hits = 0
        self.prewarm_loan_returns = 0
        self._tau_last = 0.0

    # ---------------------------------------------------------------- helpers

    @property
    def _events(self):
        """The kernel's completion heap (kept for tests/introspection)."""
        return self.clock.completions

    @property
    def sched_wakeups(self) -> int:
        return self.clock.wakeups

    def backlog_weights(self) -> Dict[str, float]:
        """Outstanding unit-time footprint (chip-seconds) per lane queue."""
        return {pid: sum(request_footprint(lane.prof, r)
                         for r in lane.pending)
                for pid, lane in self.lanes.items()}

    # -- wake sources (registered once in run(), any lane count) --------------

    def _work_in_flight(self) -> bool:
        return (any(lane.pending for lane in self.lanes.values())
                or bool(self.clock.completions))

    def _register_wake_sources(self) -> None:
        self.clock.add_source(self._next_arrival)
        # stale-window fix: with idle_window_wakeups (forced on by lending —
        # loans must be able to return during an idle gap), Monitor-window
        # boundaries stay wake-up sources even while nothing is pending
        idle_wake = self.cfg.idle_window_wakeups or self.cfg.lending
        for lane in self.lanes.values():
            if replace_capable(lane.sched):
                self.clock.add_source(monitor_boundary_source(
                    lane.monitor,
                    lambda lane=lane: bool(lane.pending
                                           or self.clock.completions
                                           or idle_wake)))
        if self._repartition_capable:
            self.clock.add_source(monitor_boundary_source(
                self.fleet_monitor,
                lambda: self._work_in_flight() or idle_wake))
        if self.broker is not None:
            # borrow/return events: min-hold expiries and lend-window
            # re-checks while any loan is outstanding
            self.clock.add_source(self.broker.next_wake)
        if self.uses_forecast:
            # predictive pre-warm events: rate-history bin boundaries (fits
            # and staging only move there) and the armed shift time
            self.clock.add_source(self.fleet_sched.forecast_wake)
        if self.injector is not None:
            # capacity events: join/preempt notices and landings fire at
            # exact schedule times in both clock modes
            self.clock.add_source(self.injector.next_wake)
        if self.cfg.scheduler_wake_hooks:
            self.clock.add_source(
                lambda tau: self.fleet_sched.next_wake(self, tau))

    # ---------------------------------------------------------------- main

    def run(self) -> FleetResult:
        # single-run objects (see Simulator.run): a second run would admit
        # nothing and double-register every wake source — fail loudly
        assert self.clock.wakeups == 0, \
            "FleetSimulator instances are single-run"
        budgets = self.fleet_sched.initial_budgets(self.trace)
        sub_traces = {pid: [r for r in self.trace if r.pipeline == pid]
                      for pid in self.reg.pipelines}
        recent = {pid: sub_traces[pid][:64] for pid in self.reg.pipelines}
        self.plan = self.orch.generate(recent, budgets)
        if self.plan is None:
            return self._oom_result()
        for pid in self.reg.pipelines:
            prof = self.reg.profiler(pid)
            lane = make_lane(pid, prof, self.cfg.lane_sim_cfg(budgets[pid]),
                             sub_traces[pid],
                             aggregate_ilp=self.cfg.aggregate_ilp,
                             cross_lane_batching=self.cfg.cross_lane_batching,
                             incremental_ilp=self.cfg.incremental_ilp)
            lane.engine = RuntimeEngine(
                prof, self.plan.subplans[pid],
                proactive_push=self.cfg.proactive_push,
                adjust_on_dispatch=self.cfg.adjust_on_dispatch)
            lane.base_units = len(lane.engine.units)
            lane.track_borrowed = self.broker is not None
            lane.track_units = self.injector is not None
            lane.placement_log.append(
                (0.0, self.plan.subplans[pid].type_histogram()))
            self.lanes[pid] = lane
        self.repartition_log.append((0.0, dict(budgets)))
        # the initial partition is a partition event: the swap cooldown runs
        # from deployment, so a seconds-old (near-empty) demand window can't
        # trigger an immediate re-partition
        self.fleet_monitor.last_repartition = 0.0
        self._register_wake_sources()
        self.clock.run(self)
        return self._result()

    # -- ClockDriver protocol --------------------------------------------------

    def _next_arrival(self, tau: float) -> Optional[float]:
        if self._ai < len(self.trace):
            return self.trace[self._ai].arrival
        return None

    def advance(self, tau: float) -> None:
        self._admit(tau)
        self._drain(tau)
        self._step(tau)

    def done(self) -> bool:
        return self._ai >= len(self.trace) and not self._work_in_flight()

    def heartbeat_pending(self) -> bool:
        return any(lane.pending for lane in self.lanes.values())

    def still_pending(self, lane: str, rid: int) -> bool:
        alive = self.lanes[lane].pending.has_rid(rid)
        if alive and self._lane_gating:
            self._dirty.add(lane)   # aging flip: dispatch rewards changed
        return alive

    # -- one scheduler step ---------------------------------------------------

    def _admit(self, tau: float) -> None:
        for lane in self.lanes.values():
            lane.new_arrivals = []
        trace = self.trace
        n = len(trace)
        ai = self._ai
        clock = self.clock if self._track_flips else None
        dirty = self._dirty if self._lane_gating else None
        # request_footprint is a pure function of the request class (its
        # profiler sub-calls are already class-memoized, but the two
        # tuple-key probes per arrival still showed up at the million-
        # request tier) — cache the final float per class
        fp_cache = self._fp_cache
        while ai < n and trace[ai].arrival <= tau:
            r = trace[ai]
            lane = self.lanes[r.pipeline]
            lane.admit(r, clock)
            if dirty is not None:
                dirty.add(r.pipeline)
            fk = (r.pipeline, r.resolution, r.seconds, r.cond_len)
            fp = fp_cache.get(fk)
            if fp is None:
                fp = fp_cache[fk] = request_footprint(lane.prof, r)
            self.fleet_monitor.record_arrival(r.arrival, r.pipeline, fp)
            if self._class_hist:
                # auxiliary-stage chip-seconds by placement class: what the
                # cross-lane batcher's fused E/C launches will draw on
                prof = lane.prof
                for s in ("E", "C"):
                    k = prof.optimal_degree(r, s) * prof.k_min
                    self.fleet_monitor.record_class_demand(
                        r.arrival, s, prof.stage_time(r, s, k) * k)
            ai += 1
        self._ai = ai

    def _drain(self, tau: float) -> None:
        dirty = self._dirty if self._lane_gating else None
        inj = self.injector
        for t, _, pid, s, ptype, dur, members, units in self.clock.pop_due(tau):
            if inj is not None and units:
                # degrade detection feed (per-unit vs pool mean); fused
                # MERGED_LANE durations are skipped inside observe
                inj.observe(self, pid, s, ptype, dur, members, units, t)
            if dirty is not None:
                if pid == MERGED_LANE:
                    dirty.update(r.pipeline for r in members)
                else:
                    dirty.add(pid)
            if pid == MERGED_LANE:
                # cross-lane fused launch: un-merge the one event back into
                # per-lane accounting — each participating lane observes the
                # completion once, each member settles under its own lane
                for lp in sorted({r.pipeline for r in members}):
                    self.lanes[lp].on_completion(t, s, ptype, dur)
                if s == "C":
                    for req in members:
                        self.fleet_monitor.record_finish(
                            t, req.pipeline, t <= req.deadline)
                continue
            lane = self.lanes[pid]
            lane.on_completion(t, s, ptype, dur)
            if s == "C":
                for req in members:
                    self.fleet_monitor.record_finish(t, pid,
                                                     t <= req.deadline)

    def _step(self, tau: float) -> None:
        self._tau_last = tau
        if self.injector is not None:
            # capacity events fire before any scheduling this wake-up: a
            # landed join/loss re-partitions here, a notice drains here
            self.injector.step(self, tau)
        self.fleet_sched.maybe_prewarm(self, tau)
        budgets = self.fleet_sched.maybe_repartition(self, tau)
        if budgets is not None:
            self._repartition(budgets, tau)
        if self.broker is not None:
            self.broker.step(self, tau)
        if self._xl is None:
            lanes = self.lanes.values()
            if self._lane_gating:
                # a lane must also wake when a retained Monitor sample exits
                # its window — windowed rates (and the placement-switch
                # trigger) can newly fire there with no lane event at all
                dirty = self._dirty
                for pid, lane in self.lanes.items():
                    if pid in dirty:
                        continue
                    bnd = lane.monitor.next_window_boundary()
                    if bnd is not None and bnd <= tau:
                        dirty.add(pid)
                lanes = [lane for pid, lane in self.lanes.items()
                         if pid in dirty or lane.pending]
                dirty.clear()
            for lane in lanes:
                lane.step(tau, self.clock,
                          lambda new_plan, t, lane=lane:
                              self._apply_lane_plan(lane, new_plan, t))
        else:
            # cross-lane batching: decide every lane first, fuse matching
            # auxiliary runs across lanes, then execute.  Lanes own disjoint
            # engines and the dispatchers see only their own lane's state,
            # so decide-all-then-execute-all is equivalent to the
            # interleaved per-lane stepping above; deferred fused C launches
            # run last, once every member's decode finish is stamped.
            lane_decs = [
                (lane, lane.decide(tau,
                                   lambda new_plan, t, lane=lane:
                                       self._apply_lane_plan(lane, new_plan, t)))
                for lane in self.lanes.values()]
            cgroups = self._xl.plan(lane_decs, tau, self.clock)
            for lane, decs in lane_decs:
                lane.execute_decisions(decs, tau, self.clock)
            self._xl.finalize(cgroups, tau, self.clock)
        if self.broker is not None:
            # sample pressure after dispatch: what is still pending now is
            # genuine backlog, not the arrivals this wake-up just served
            self.broker.sample(self, tau)

    def _apply_lane_plan(self, lane: Lane, new_plan: PlacementPlan,
                         tau: float) -> None:
        """A lane-level placement switch: reattach loan slots first (the
        fresh plan must carry them before the engine sees it), then swap
        the cluster plan's sub-plan."""
        new_plan.pipeline = lane.pipeline
        if self.prewarmed:
            # staged pre-warm marks describe the *old* unit layout: any
            # unit whose placement this switch changes must shed them, or
            # a later re-partition would count a stale mark as a hit and
            # skip a reload the chips genuinely owe
            old = self.plan.subplans[lane.pipeline]
            lo, hi = self.plan.chip_ranges[lane.pipeline]
            if (new_plan.unit_size != old.unit_size
                    or len(new_plan.placements) != len(old.placements)):
                for c in range(lo, hi):
                    self.prewarmed.pop(c, None)
            else:
                k = old.unit_size
                for g, p in enumerate(old.placements):
                    if new_plan.placements[g] != p:
                        for c in range(lo + g * k, lo + (g + 1) * k):
                            self.prewarmed.pop(c, None)
        if self.broker is not None:
            self.broker.reattach(lane, new_plan)
        lane.engine.apply_placement(new_plan, tau)
        self.plan.subplans[lane.pipeline] = new_plan

    # -- re-partitioning ------------------------------------------------------

    def _chip_state(self) -> Tuple[Dict[int, float],
                                   Dict[int, Tuple[str, int, frozenset]]]:
        """Per-chip (free time, (owner pipeline, owner unit, resident
        stages)) over the lanes' own (non-loan) units — the inputs both the
        re-partition reload accounting and the pre-warm staging diff."""
        chip_free: Dict[int, float] = {}
        chip_owner: Dict[int, Tuple[str, int, frozenset]] = {}
        for pid, lane in self.lanes.items():
            lo, _ = self.plan.chip_ranges[pid]
            k = self.plan.subplans[pid].unit_size
            for u in lane.engine.units[:lane.base_units]:
                for c in range(lo + u.uid * k, lo + (u.uid + 1) * k):
                    chip_free[c] = u.free_at
                    chip_owner[c] = (pid, u.uid, frozenset(u.resident))
        return chip_free, chip_owner

    def _plan_inputs(self, tau: float) -> Tuple[Dict, Dict]:
        """(recent requests, measured placement rates) per pipeline — what
        ``FleetOrchestrator.generate`` plans from, shared by re-partitions
        and pre-warm target planning."""
        recent = {}
        measured = {}
        for pid, lane in self.lanes.items():
            recent[pid] = [r for r in lane.sched._recent
                           if r.arrival > tau - lane.sched.t_win][-512:]
            measured[pid] = lane.monitor.placement_rates(
                tau, self.plan.subplans[pid].type_histogram())
        return recent, measured

    def stage_prewarm(self, budgets: Dict[str, int], tau: float,
                      limit: Optional[int] = None,
                      idle_only: bool = False,
                      class_priority: Optional[List[str]] = None) -> int:
        """Stage the predicted target partition's weight loads on the chips
        that will flip, *before* the shift lands (predictive
        re-partitioning, core/forecast.py).  The owning units keep serving
        their current pipeline — each just hosts the staging DMA as busy
        time (``RuntimeEngine.stage_prewarm``), overlapping the tail of the
        old mix — and the staged chips are remembered so the next
        re-partition skips their reloads.

        With ``idle_only`` a unit is staged only when every owning unit is
        idle at ``tau`` (the scheduler retries at each forecast bin across
        the pre-warm lead window, so busy units are deferred to their next
        idle gap instead of stalling live work).  At most ``limit``
        (default ``prewarm_budget``) target units are staged per call —
        the mis-prediction cost bound.  Already-staged chips are skipped,
        so repeated calls converge instead of re-paying.

        ``class_priority`` (cross-lane batching, per-placement-class
        forecast) re-orders the staging walk by placement type — the
        classes the batcher's fused launches will lean on hardest are
        staged first, inside the same unit budget.  The sort is *stable*,
        so ``None`` (and any ranking that lists no present class) walks
        the target plan in exactly the historical plan order.  Returns the
        number of units staged."""
        recent, measured = self._plan_inputs(tau)
        target = self.orch.generate(recent, budgets, measured)
        if target is None:
            return 0
        chip_free, chip_owner = self._chip_state()
        ttl = self.cfg.prewarm_ttl
        cap = self.cfg.prewarm_budget if limit is None else limit
        staged = 0
        units_iter = [(pid, g, ptype)
                      for pid in self.reg.pipelines
                      for g, ptype in
                      enumerate(target.subplans[pid].placements)]
        if class_priority:
            rank = {c: i for i, c in enumerate(class_priority)}
            units_iter.sort(key=lambda u: rank.get(u[2], len(rank)))
        for pid, g, ptype in units_iter:
            sub = target.subplans[pid]
            prof = self.reg.profiler(pid)
            lo, _ = target.chip_ranges[pid]
            k = sub.unit_size
            if staged >= cap:
                return staged
            need = set(ptype)
            chips = range(lo + g * k, lo + (g + 1) * k)
            per_owner: Dict[Tuple[str, int], set] = {}
            for c in chips:
                owner = chip_owner.get(c)
                if owner is None:
                    continue
                missing = need if owner[0] != pid else need - owner[2]
                pw = self.prewarmed.get(c)
                if pw is not None and pw[0] == pid and tau - pw[2] <= ttl:
                    missing = missing - pw[1]
                if missing:
                    per_owner.setdefault((owner[0], owner[1]),
                                         set()).update(missing)
            if not per_owner:
                continue       # nothing (left) to stage for this unit
            if idle_only and any(
                    self.lanes[opid].engine.units[ouid].free_at > tau
                    for opid, ouid in per_owner):
                continue       # owner mid-work: defer to a later bin
            if self.broker is not None:
                for opid, ouid in sorted(per_owner):
                    if self.broker.force_return_unit(self, opid, ouid, tau):
                        # a lent-out unit scheduled for pre-warm returns
                        # its loan before anything is staged on its chips —
                        # no loan may survive the coming cutover
                        self.prewarm_loan_returns += 1
                if any(self.broker.unit_on_loan(opid, ouid)
                       for opid, ouid in sorted(per_owner)):
                    # a force-return deferred past an un-drained fused
                    # launch (core/lending.py) leaves the loan open: defer
                    # this target unit too — the next bin's retry stages it
                    continue
            for opid, ouid in sorted(per_owner):
                # sorted: float sum + str-set iteration (see
                # _repartition's reload note)
                load = sum(prof.stage_load_time(s, via_host=True)
                           for s in sorted(per_owner[(opid, ouid)]))
                self.lanes[opid].engine.stage_prewarm(ouid, tau, load)
                self.prewarm_cost_s += load
            for c in chips:
                self.prewarmed[c] = (pid, frozenset(need), tau)
            self.prewarm_units += 1
            staged += 1
        return staged

    def _repartition(self, budgets: Dict[str, int], tau: float,
                     chip_map: Optional[Dict[int, int]] = None) -> None:
        """Move chips between lanes.  Per-chip in-flight work and stage
        residency carry over; units whose pipeline or placement type changed
        hands pay the weight-reload latency before becoming dispatchable —
        unless the predictive scheduler pre-warmed their chips, in which
        case the staged stages are already loaded and charge nothing.

        ``chip_map`` (capacity re-partitions after a node loss,
        core/elastic.py) translates surviving old chip indices into the
        compacted space; state on unmapped (lost) chips drops out here."""
        if self.broker is not None:
            # loans cannot outlive the partition they were struck under:
            # force-return them first (in-flight borrowed work and the
            # lender's reload land on the lender's chips via free_at below)
            self.broker.release_all(self, tau)
        chip_free, chip_owner = self._chip_state()
        if chip_map is not None:
            chip_free = {chip_map[c]: v for c, v in chip_free.items()
                         if c in chip_map}
            chip_owner = {chip_map[c]: v for c, v in chip_owner.items()
                          if c in chip_map}
            self.prewarmed = {chip_map[c]: v
                              for c, v in self.prewarmed.items()
                              if c in chip_map}
        recent, measured = self._plan_inputs(tau)
        new_plan = self.orch.generate(recent, budgets, measured)
        if new_plan is None:   # no feasible re-partition: keep the old plan
            return
        prewarmed = self.prewarmed
        ttl = self.cfg.prewarm_ttl
        for pid, lane in self.lanes.items():  # detlint: ignore[DET001] lanes dict is registry-ordered; reload-sum order is BENCH-byte-frozen
            sub = new_plan.subplans[pid]
            prof = lane.prof
            if (self._lane_gating and chip_map is None
                    and new_plan.chip_ranges[pid] == self.plan.chip_ranges[pid]
                    and sub.unit_size == self.plan.subplans[pid].unit_size
                    and sub.placements == self.plan.subplans[pid].placements):
                # (chip_map guard: after a node loss, equal numeric ranges
                # map to *different physical chips* — the lane must rebuild)
                # O(changed-lanes) re-partition: this lane's chip range and
                # sub-plan are identical — no chip changed hands, no reload
                # is owed.  Keep the live engine (its free_at state IS the
                # chip state a rebuild would re-seed) instead of paying the
                # rebuild; the retained sub-plan object stays authoritative.
                new_plan.subplans[pid] = self.plan.subplans[pid]
                continue
            lane.bank_engine_stats()
            engine = RuntimeEngine(
                prof, sub, proactive_push=self.cfg.proactive_push,
                adjust_on_dispatch=self.cfg.adjust_on_dispatch)
            busy: Dict[int, float] = {}
            lo, _ = new_plan.chip_ranges[pid]
            k = sub.unit_size
            for g, ptype in enumerate(sub.placements):
                chips = range(lo + g * k, lo + (g + 1) * k)
                base = max(chip_free.get(c, 0.0) for c in chips)
                need = set(ptype)
                reload = 0.0
                averted = False
                for c in chips:
                    owner = chip_owner.get(c)
                    missing = (need if owner is None or owner[0] != pid
                               else need - owner[2])
                    if missing and prewarmed:
                        pw = prewarmed.get(c)
                        if (pw is not None and pw[0] == pid
                                and tau - pw[2] <= ttl and missing & pw[1]):
                            missing = missing - pw[1]
                            averted = True
                    if missing:
                        # sorted: a 3-term float sum is order-sensitive in
                        # the last ulp, and set iteration order over str
                        # keys follows PYTHONHASHSEED — unsorted, the
                        # reload (and everything downstream of the unit's
                        # busy time) would differ run-to-run
                        reload = max(reload, sum(
                            prof.stage_load_time(s, via_host=True)
                            for s in sorted(missing)))
                if averted and reload == 0.0:
                    self.prewarm_hits += 1
                if reload > 0.0:
                    self.swap_cost_s += reload
                    self.units_reloaded += 1
                    busy[g] = max(tau, base) + reload
                elif base > 0.0:
                    busy[g] = base
            engine.seed_unit_state(busy)
            lane.engine = engine
            lane.base_units = len(engine.units)
            lane.sched.orch.resize(budgets[pid])
            lane.placement_log.append((tau, sub.type_histogram()))
        self.plan = new_plan
        # staged weights were either consumed above or are stale now that
        # the chips changed hands — either way the marks are spent
        self.prewarmed.clear()
        if self.broker is not None:
            self.broker.reset_after_repartition(self)
        self.fleet_monitor.last_repartition = tau
        # the swap happened: only now does the partition's demand basis move
        # (an aborted re-partition must leave the mix-shift trigger armed)
        self.fleet_sched.on_repartitioned(self, tau)
        self.repartition_log.append((tau, dict(budgets)))
        if self._lane_gating:
            # every lane's engine/plan may have moved: all must re-step
            self._dirty.update(self.lanes)
        if self.injector is not None:
            # fresh engines and sub-plans: re-derive the injector's
            # overlays (slowdowns, quarantines, a pending drain)
            self.injector.after_repartition(self, tau)

    # -- elastic capacity (core/elastic.py) -----------------------------------

    def mark_lane_dirty(self, pid: str) -> None:
        """A capacity or lending event changed this lane's dispatchable
        state with no lane completion to show for it: under
        O(changed-lanes) stepping the lane must still re-step this
        wake-up (satellite fix — ``step_changed_lanes_only`` must treat
        borrow/return and capacity events as "changed")."""
        if self._lane_gating:
            self._dirty.add(pid)

    def _evict_prewarm_unit(self, pid: str, g: int) -> None:
        """Drop staged pre-warm marks on one unit's chips: the unit was
        mutated under the marks (lent out, retyped, decommissioned), so
        they must not count as hits and avert a reload the chips owe."""
        if not self.prewarmed:
            return
        lo, hi = self.plan.unit_chips(pid, g)
        for c in range(lo, hi):
            self.prewarmed.pop(c, None)

    def _capacity_repartition(self, tau: float,
                              chip_map: Optional[Dict[int, int]] = None
                              ) -> None:
        """Re-partition to the *current* pool size — a join landed or a
        preemption compacted the chip space (core/elastic.py).  Capacity
        re-partitions bypass the mix-shift trigger and its cooldown (the
        pool changed, not the mix) and size lanes by live windowed demand
        plus queued backlog.  An infeasible one is fatal: the fleet
        cannot keep serving a plan sized for chips that no longer exist."""
        demand = self.fleet_monitor.demand(tau)
        backlog = self.backlog_weights()
        weights = {p: demand.get(p, 0.0) + backlog.get(p, 0.0)
                   for p in self.reg.pipelines}
        budgets = self.orch.budgets(
            self.fleet_sched._objective_weights(self, tau, weights))
        self._repartition(budgets, tau, chip_map=chip_map)
        assert self.plan.total_chips == self.orch.num_chips, \
            "no feasible partition for the surviving chip pool"

    # ---------------------------------------------------------------- results

    def _oom_result(self) -> FleetResult:
        return FleetResult(
            scheduler=self.fleet_sched.name, num_chips=self.cfg.num_chips,
            oom=True, n_requests=len(self.trace), n_finished=0,
            n_request_oom=len(self.trace), slo_attainment=0.0, goodput=0.0,
            mean_latency=float("inf"), p95_latency=float("inf"),
            per_pipeline={}, engine_stats={}, repartitions=[],
            swap_cost_s=0.0, units_reloaded=0, sched_wakeups=0)

    @staticmethod
    def _metrics(reqs: Sequence[Request], oom_ids: set,
                 horizon_lat: float) -> Dict[str, float]:
        lat: List[float] = []
        on_time = 0
        finished = 0
        # Request.finished/latency/on_time inlined: each property re-derives
        # the "C" finish stamp, and this loop runs twice per request (lane
        # pass + aggregate pass) over million-request traces — the same
        # floats come out of one dict probe
        for r in reqs:
            if r.rid in oom_ids:
                lat.append(horizon_lat)
                continue
            f = r.stage_done.get("C")
            if f is not None:
                finished += 1
                lat.append(f - r.arrival)
                if f <= r.deadline:
                    on_time += 1
            else:
                lat.append(horizon_lat - r.arrival)   # censored
        lat_sorted = sorted(lat)
        n = len(lat_sorted)
        return {
            "requests": n, "finished": finished, "on_time": on_time,
            "slo": on_time / max(1, n),
            "mean_s": sum(lat) / max(1, n),
            "p95_s": lat_sorted[int(0.95 * (n - 1))] if n else 0.0,
        }

    def _result(self) -> FleetResult:
        trace_end = self.trace[-1].arrival if self.trace else 0.0
        horizon_lat = trace_end + self.cfg.horizon_slack
        oom_ids = {r.rid for lane in self.lanes.values()
                   for r in lane.request_oom}
        per_pipeline: Dict[str, Dict[str, float]] = {}
        # one grouping pass instead of one full-trace scan per lane (order
        # within each group is trace order, same as the per-lane filter)
        by_pid: Dict[str, List[Request]] = {pid: [] for pid in self.lanes}
        for r in self.trace:
            grp = by_pid.get(r.pipeline)
            if grp is not None:
                grp.append(r)
        for pid, lane in self.lanes.items():
            m = self._metrics(by_pid[pid], oom_ids, horizon_lat)
            m["chips"] = self.plan.chip_ranges[pid][1] - \
                self.plan.chip_ranges[pid][0]
            per_pipeline[pid] = m
        agg = self._metrics(self.trace, oom_ids, horizon_lat)
        lend_kw = {}
        if self.broker is not None:
            self.broker.finalize(self._tau_last)
            runs: Dict[str, int] = {}
            for lane in self.lanes.values():
                for s, n in lane.borrowed_stage_runs.items():
                    runs[s] = runs.get(s, 0) + n
            lend_kw = dict(loans=self.broker.loans_granted,
                           borrowed_unit_seconds=round(
                               self.broker.borrowed_unit_seconds, 3),
                           lend_swap_cost_s=round(self.broker.swap_cost_s, 3),
                           borrowed_stage_runs=runs)
        # a fixed pool "survives" at its starting size, so the elastic
        # off path reports the same field the injector would
        elastic_kw: Dict = dict(final_chips=self.cfg.num_chips)
        if self.injector is not None:
            inj = self.injector
            elastic_kw = dict(
                capacity_events=inj.capacity_events,
                nodes_joined=inj.nodes_joined,
                nodes_lost=inj.nodes_lost,
                requeued_requests=inj.requeued_requests,
                drained_units=inj.drained_units,
                quarantined_units=inj.quarantined_units,
                elastic_prewarm_chips=inj.elastic_prewarm_chips,
                final_chips=inj.live_chips)
        return FleetResult(
            scheduler=self.fleet_sched.name, num_chips=self.cfg.num_chips,
            oom=False, n_requests=len(self.trace),
            n_finished=int(agg["finished"]), n_request_oom=len(oom_ids),
            slo_attainment=agg["slo"],
            goodput=agg["on_time"] / max(trace_end, 1e-9),
            mean_latency=agg["mean_s"], p95_latency=agg["p95_s"],
            per_pipeline=per_pipeline,
            engine_stats={pid: lane.engine_stats()
                          for pid, lane in self.lanes.items()},
            repartitions=self.repartition_log,
            swap_cost_s=self.swap_cost_s, units_reloaded=self.units_reloaded,
            sched_wakeups=self.sched_wakeups,
            prewarm_units=self.prewarm_units,
            prewarm_cost_s=round(self.prewarm_cost_s, 3),
            prewarm_hits=self.prewarm_hits,
            prewarm_loan_returns=self.prewarm_loan_returns,
            predictive_repartitions=getattr(self.fleet_sched, "early_fires",
                                            0),
            cross_lane_merges=self._xl.merges if self._xl else 0,
            cross_lane_merged_requests=(self._xl.merged_requests
                                        if self._xl else 0),
            **lend_kw, **elastic_kw)


# ---------------------------------------------------------------- convenience

def run_fleet(pipelines: Sequence[str], mode: str = "adaptive",
              duration: float = 600.0, cfg: Optional[FleetConfig] = None,
              seed: int = 0, rates: Optional[Dict[str, float]] = None,
              phases: Optional[Sequence] = None, level: str = "medium",
              trace: Optional[Sequence[Request]] = None,
              registry: Optional[PipelineRegistry] = None,
              fixed_budgets: Optional[Dict[str, int]] = None) -> FleetResult:
    """Build registry + heterogeneous trace + fleet scheduler and run."""
    cfg = cfg or FleetConfig(seed=seed)
    registry = registry or PipelineRegistry(pipelines)
    if trace is None:
        profs = {pid: registry.profiler(pid) for pid in registry.pipelines}
        trace = workloads.fleet_trace(pipelines, duration, profs, seed=seed,
                                      rates=rates, phases=phases, level=level)
    orch = FleetOrchestrator(registry, num_chips=cfg.num_chips,
                             chips_per_node=cfg.chips_per_node)
    sched = FLEET_SCHEDULERS[mode](orch, cfg, fixed_budgets=fixed_budgets)
    return FleetSimulator(registry, sched, trace, cfg).run()
