"""Baselines B1-B6 (§8.1, Appendix D.2) over the same engine/simulator.

B1-B4 are colocated pipeline-level systems *without* the Appendix-E.2 MP
fold (that is the paper's setting: xDiT-style deployments colocate the full
pipeline per GPU — which is exactly why they OOM on Flux/HunyuanVideo).
B5/B6 disaggregate stages manually (an expert operator would also apply MP
where a stage doesn't fit, so they inherit the automatic k_min fold).
"""
from __future__ import annotations

import math
from collections import Counter
from typing import Dict, List, Optional, Tuple

from repro.core.dispatcher import DispatchDecision, Dispatcher
from repro.core.placement import PlacementPlan
from repro.core.profiler import HBM_BYTES, MEM_RESERVE, Profiler
from repro.core.request import Request
from repro.core.simulator import Scheduler, Simulator
from repro.core.workloads import MIXES


def _max_load_class(pipeline: str) -> Tuple[int, float]:
    classes = {cls for mix in MIXES[pipeline].values() for cls, _ in mix}
    # sorted: the key is injective over (res, sec) tuples, so the wrap is
    # byte-neutral, but it pins the walk order off PYTHONHASHSEED
    return max(sorted(classes), key=lambda c: (c[0] * max(1.0, c[1]), c[1]))


class _ColocatedBase(Scheduler):
    """Shared machinery for the colocated pipeline-level baselines."""

    FORCE_KMIN = 1   # no MP fold — the paper's colocated-system setting

    def initial_placement(self) -> Optional[PlacementPlan]:
        if self.prof.unit_param_bytes("EDC") + MEM_RESERVE > HBM_BYTES:
            return None   # OOM: the whole pipeline cannot colocate
        n = self.sim_cfg.num_chips // self.prof.k_min
        return PlacementPlan(["EDC"] * n, unit_size=self.prof.k_min,
                             units_per_node=8 // self.prof.k_min)

    def _mk(self, sim, req: Request, units: Tuple[int, ...], k: int
            ) -> Optional[DispatchDecision]:
        if not self.prof.fits(req, "EDC", k):
            sim.fail_request_oom(req)
            sim.pending.remove(req)
            return None
        return DispatchDecision(request=req, vr_type=0, degree=k,
                                d_units=units, e_units=units, c_units=units)


class B1StaticPipeline(_ColocatedBase):
    """B1 (xDiT): one global static degree, FIFO, same resources per stage."""

    name = "B1"

    def __init__(self, prof, sim_cfg, trace):
        super().__init__(prof, sim_cfg, trace)
        heavy = Request(prof.cfg.name, *_max_load_class(prof.cfg.name))
        self.k_static = max(1, self.prof.optimal_degree(heavy, "D") // 2)

    def tick(self, sim: Simulator, tau: float) -> List[DispatchDecision]:
        out = []
        avail = set(sim.engine.idle_units(tau))
        for req in sorted(sim.pending, key=lambda r: r.arrival):
            units = Dispatcher.select_units(sim.engine.plan, "EDC",
                                            self.k_static, avail)
            if units is None:
                break   # FIFO: head-of-line blocks
            dec = self._mk(sim, req, units, self.k_static)
            if dec is None:
                continue
            avail -= set(units)
            out.append(dec)
        return out


class B2BucketedPipeline(_ColocatedBase):
    """B2: static degree buckets sized by demand x service time (D.2)."""

    name = "B2"

    def __init__(self, prof, sim_cfg, trace):
        super().__init__(prof, sim_cfg, trace)
        self.bucket_of_unit: Dict[int, int] = {}

    def initial_placement(self) -> Optional[PlacementPlan]:
        plan = super().initial_placement()
        if plan is None:
            return None
        # demand shares per degree from the trace prefix
        sample = list(self.trace[:256]) or [Request(self.prof.cfg.name, 512)]
        load = Counter()
        for r in sample:
            k = self.prof.optimal_degree(r, "D")
            load[k] += self.prof.stage_time(r, "D", k * self.prof.k_min) * k
        total = sum(load.values()) or 1.0  # detlint: ignore[DET001] Counter keyed in trace order: insertion-ordered, BENCH-byte-frozen
        n = plan.num_units
        counts = {}
        used = 0
        for k in (8, 4, 2):
            nk = int(round(n * load.get(k, 0.0) / total / k) * k)
            counts[k] = min(nk, n - used)
            used += counts[k]
        counts[1] = n - used
        uid = 0
        for k in (8, 4, 2, 1):
            for _ in range(counts.get(k, 0)):
                self.bucket_of_unit[uid] = k
                uid += 1
        return plan

    def tick(self, sim: Simulator, tau: float) -> List[DispatchDecision]:
        out = []
        avail = set(sim.engine.idle_units(tau))
        for req in sorted(sim.pending, key=lambda r: r.arrival):
            k = self.prof.optimal_degree(req, "D")
            bucket = {g for g in avail if self.bucket_of_unit.get(g, 1) == k}
            units = Dispatcher.select_units(sim.engine.plan, "EDC", k, bucket)
            if units is None:
                continue   # FIFO within bucket; other buckets proceed
            dec = self._mk(sim, req, units, k)
            if dec is None:
                continue
            avail -= set(units)
            out.append(dec)
        return out


class B3DynamicPipelineFIFO(_ColocatedBase):
    """B3: per-request optimal degree, strict FIFO (head-of-line blocking)."""

    name = "B3"

    def tick(self, sim: Simulator, tau: float) -> List[DispatchDecision]:
        out = []
        avail = set(sim.engine.idle_units(tau))
        for req in sorted(sim.pending, key=lambda r: r.arrival):
            k = self.prof.optimal_degree(req, "D")
            units = Dispatcher.select_units(sim.engine.plan, "EDC", k, avail)
            if units is None:
                break   # HOL blocking
            dec = self._mk(sim, req, units, k)
            if dec is None:
                continue
            avail -= set(units)
            out.append(dec)
        return out


def srtf_key(prof: Profiler, req: Request, tau: float):
    """SRTF with aging (D.2): overdue requests gain priority classes."""
    k = prof.optimal_degree(req, "D") * prof.k_min
    t_star = prof.stage_time(req, "D", k)
    t_hat = tau + t_star
    if t_hat <= req.deadline:
        return (0, t_star)
    scale = math.ceil((t_hat - req.deadline) / max(t_star, 1e-9))
    return (max(1, 5 - scale), t_star)


class B4DynamicPipelineSRTF(_ColocatedBase):
    """B4: as B3 but SRTF+aging; may skip blocked heads."""

    name = "B4"

    def tick(self, sim: Simulator, tau: float) -> List[DispatchDecision]:
        out = []
        avail = set(sim.engine.idle_units(tau))
        for req in sorted(sim.pending, key=lambda r: srtf_key(self.prof, r, tau)):
            k = self.prof.optimal_degree(req, "D")
            units = Dispatcher.select_units(sim.engine.plan, "EDC", k, avail)
            if units is None:
                continue   # SRTF: skip, try next
            dec = self._mk(sim, req, units, k)
            if dec is None:
                continue
            avail -= set(units)
            out.append(dec)
        return out


class _StageDisaggBase(Scheduler):
    """Shared machinery for the manual stage-disaggregated baselines."""

    FORCE_KMIN = None   # experts apply MP where a stage doesn't fit

    def initial_placement(self) -> Optional[PlacementPlan]:
        sample = list(self.trace[:256]) or [Request(self.prof.cfg.name, 512)]
        demand = {}
        for s in "EDC":
            demand[s] = sum(
                self.prof.stage_time(r, s, self.prof.optimal_degree(r, s)
                                     * self.prof.k_min)
                * self.prof.optimal_degree(r, s) for r in sample)
        total = sum(demand.values()) or 1.0  # detlint: ignore[DET001] dict filled in 'EDC' literal order: insertion-ordered
        n = self.sim_cfg.num_chips // self.prof.k_min
        g = {s: max(1, round(n * demand[s] / total)) for s in "EDC"}
        # ensure sum == n by adjusting the largest split (D.2)
        drift = n - sum(g.values())  # detlint: ignore[DET001] int unit counts: exact addition, order-free
        g["D"] += drift
        placements = ["E"] * g["E"] + ["D"] * g["D"] + ["C"] * g["C"]
        return PlacementPlan(placements[:n], unit_size=self.prof.k_min,
                             units_per_node=8 // self.prof.k_min)

    def _mk_disagg(self, sim, req, d_units, k, avail, free_at, tau
                   ) -> Optional[DispatchDecision]:
        disp = Dispatcher(self.prof)
        e_units = disp._aux_units(sim.engine.plan, "E",
                                  self.prof.optimal_degree(req, "E"),
                                  avail, free_at, tau)
        c_units = disp._aux_units(sim.engine.plan, "C",
                                  self.prof.optimal_degree(req, "C"),
                                  avail, free_at, tau)
        if not e_units or not c_units:
            return None
        return DispatchDecision(request=req, vr_type=3, degree=k,
                                d_units=d_units, e_units=tuple(e_units),
                                c_units=tuple(c_units))


class B5BucketedStage(_StageDisaggBase):
    """B5: static stage clusters + degree buckets inside D, FIFO."""

    name = "B5"

    def __init__(self, prof, sim_cfg, trace):
        super().__init__(prof, sim_cfg, trace)
        self.bucket_of_unit: Dict[int, int] = {}

    def initial_placement(self) -> Optional[PlacementPlan]:
        plan = super().initial_placement()
        d_units = plan.units_of_type("D")
        sample = list(self.trace[:256]) or [Request(self.prof.cfg.name, 512)]
        load = Counter()
        for r in sample:
            k = self.prof.optimal_degree(r, "D")
            load[k] += self.prof.stage_time(r, "D", k * self.prof.k_min) * k
        total = sum(load.values()) or 1.0  # detlint: ignore[DET001] Counter keyed in trace order: insertion-ordered, BENCH-byte-frozen
        n = len(d_units)
        used = 0
        idx = 0
        for k in (8, 4, 2, 1):
            nk = (n - used) if k == 1 else min(n - used,
                                               int(round(n * load.get(k, 0.0) / total / k) * k))
            for _ in range(nk):
                self.bucket_of_unit[d_units[idx]] = k
                idx += 1
            used += nk
        return plan

    def tick(self, sim: Simulator, tau: float) -> List[DispatchDecision]:
        out = []
        avail = set(sim.engine.idle_units(tau))
        free_at = sim.engine.free_at()
        for req in sorted(sim.pending, key=lambda r: r.arrival):
            k = self.prof.optimal_degree(req, "D")
            bucket = {g for g in avail if self.bucket_of_unit.get(g, 0) == k}
            units = Dispatcher.select_units(sim.engine.plan, "D", k, bucket)
            if units is None:
                continue
            dec = self._mk_disagg(sim, req, units, k, avail, free_at, tau)
            if dec is None:
                continue
            avail -= set(dec.d_units)
            out.append(dec)
        return out


class B6DynamicStageSRTF(_StageDisaggBase):
    """B6: stage clusters + per-stage dynamic optimal degree, SRTF+aging."""

    name = "B6"

    def tick(self, sim: Simulator, tau: float) -> List[DispatchDecision]:
        out = []
        avail = set(sim.engine.idle_units(tau))
        free_at = sim.engine.free_at()
        for req in sorted(sim.pending, key=lambda r: srtf_key(self.prof, r, tau)):
            k = self.prof.optimal_degree(req, "D")
            units = Dispatcher.select_units(sim.engine.plan, "D", k, avail)
            if units is None:
                continue
            dec = self._mk_disagg(sim, req, units, k, avail, free_at, tau)
            if dec is None:
                continue
            avail -= set(dec.d_units)
            out.append(dec)
        return out


BASELINES = {
    "B1": B1StaticPipeline,
    "B2": B2BucketedPipeline,
    "B3": B3DynamicPipelineFIFO,
    "B4": B4DynamicPipelineSRTF,
    "B5": B5BucketedStage,
    "B6": B6DynamicStageSRTF,
}
