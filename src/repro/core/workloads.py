"""Workload traces (§8.1 + Table 5): Steady, Dynamic, Proprietary.

Mix weights and request rates follow Table 5; ``k x {...}`` compact weights
are expanded to per-class sampling probabilities.  Poisson arrivals.  The
*Proprietary* trace is synthesized with the diurnal/tidal shape of Fig. 9
and scaled to the Steady request budget, per Appendix D.1.
"""
from __future__ import annotations

import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.profiler import Profiler
from repro.core.request import Request

# (resolution, seconds) classes and weights per model & level — Table 5
_R = lambda *rs: [(r, 0.0) for r in rs]
_V = lambda *rv: list(rv)

MIXES: Dict[str, Dict[str, List[Tuple[Tuple[int, float], float]]]] = {
    "sd3": {
        "light": [((128, 0), 2), ((256, 0), 2), ((512, 0), 1), ((1024, 0), 1), ((1536, 0), 1)],
        "medium": [((512, 0), 4), ((128, 0), 1), ((256, 0), 1), ((1024, 0), 1), ((1536, 0), 1)],
        "heavy": [((1024, 0), 2), ((1536, 0), 2), ((128, 0), 1), ((256, 0), 1), ((512, 0), 1)],
    },
    "flux": {
        "light": [((128, 0), 2), ((256, 0), 2), ((512, 0), 2), ((1024, 0), 1),
                  ((2048, 0), 1), ((3072, 0), 1), ((4096, 0), 1)],
        "medium": [((1024, 0), 2), ((2048, 0), 2), ((128, 0), 1), ((256, 0), 1),
                   ((512, 0), 1), ((3072, 0), 1), ((4096, 0), 1)],
        "heavy": [((3072, 0), 2), ((4096, 0), 2), ((128, 0), 1), ((256, 0), 1),
                  ((512, 0), 1), ((1024, 0), 1), ((2048, 0), 1)],
    },
    "cogvideox": {
        "light": [((480, 2), 3), ((720, 2), 3), ((480, 4), 1), ((480, 8), 1), ((480, 10), 1),
                  ((720, 4), 1), ((720, 8), 1), ((720, 10), 1)],
        "medium": [((480, 4), 2), ((480, 8), 2), ((480, 10), 2), ((480, 2), 1),
                   ((720, 2), 1), ((720, 4), 1), ((720, 8), 1), ((720, 10), 1)],
        "heavy": [((720, 4), 2), ((720, 8), 2), ((720, 10), 2), ((480, 2), 1),
                  ((720, 2), 1), ((480, 4), 1), ((480, 8), 1), ((480, 10), 1)],
    },
    "hunyuanvideo": {
        "light": [((540, 1), 3), ((720, 1), 3), ((540, 2), 1), ((540, 4), 1), ((540, 8), 1),
                  ((720, 2), 1), ((720, 4), 1), ((720, 8), 1)],
        "medium": [((540, 2), 2), ((540, 4), 2), ((720, 2), 2), ((540, 1), 1),
                   ((720, 1), 1), ((720, 4), 1), ((540, 8), 1), ((720, 8), 1)],
        "heavy": [((720, 4), 2), ((540, 8), 2), ((720, 8), 2), ((540, 1), 1),
                  ((720, 1), 1), ((540, 2), 1), ((540, 4), 1), ((720, 2), 1)],
    },
}

RATES = {"sd3": 20.0, "flux": 1.5, "cogvideox": 1.0, "hunyuanvideo": 0.5}
T_WIN = {"sd3": 180.0, "flux": 300.0, "cogvideox": 300.0, "hunyuanvideo": 600.0}
SLO_SCALE = 2.5   # SLO = 2.5x latency at optimal parallelism (AlpaServe-style)


def _sample_class(rng: random.Random, mix) -> Tuple[int, float]:
    total = sum(w for _, w in mix)
    x = rng.uniform(0, total)
    acc = 0.0
    for cls, w in mix:
        acc += w
        if x <= acc:
            return cls
    return mix[-1][0]


def _mk_request(pipeline: str, cls: Tuple[int, float], t: float,
                prof: Profiler, slo_scale: float) -> Request:
    res, sec = cls
    req = Request(pipeline, res, float(sec), arrival=t)
    req.deadline = t + slo_scale * prof.pipeline_time(req)
    return req


def steady_trace(pipeline: str, level: str, duration: float, prof: Profiler,
                 seed: int = 0, rate: Optional[float] = None,
                 slo_scale: float = SLO_SCALE) -> List[Request]:
    rng = random.Random(seed)
    rate = rate if rate is not None else RATES[pipeline]
    mix = MIXES[pipeline][level]
    t, out = 0.0, []
    while True:
        t += rng.expovariate(rate)
        if t >= duration:
            break
        out.append(_mk_request(pipeline, _sample_class(rng, mix), t, prof, slo_scale))
    return out


# Fig. 9 left: per-span proportions of the three steady mixes
DYNAMIC_PATTERN = [
    {"light": 0.7, "medium": 0.2, "heavy": 0.1},
    {"light": 0.2, "medium": 0.6, "heavy": 0.2},
    {"light": 0.1, "medium": 0.2, "heavy": 0.7},
    {"light": 0.3, "medium": 0.5, "heavy": 0.2},
    {"light": 0.6, "medium": 0.3, "heavy": 0.1},
    {"light": 0.1, "medium": 0.3, "heavy": 0.6},
]


def dynamic_trace(pipeline: str, duration: float, prof: Profiler,
                  seed: int = 0, rate: Optional[float] = None,
                  slo_scale: float = SLO_SCALE) -> List[Request]:
    rng = random.Random(seed + 17)
    rate = rate if rate is not None else RATES[pipeline]
    span = duration / len(DYNAMIC_PATTERN)
    t, out = 0.0, []
    while True:
        t += rng.expovariate(rate)
        if t >= duration:
            break
        props = DYNAMIC_PATTERN[min(int(t // span), len(DYNAMIC_PATTERN) - 1)]
        level = rng.choices(list(props), weights=list(props.values()))[0]
        out.append(_mk_request(pipeline, _sample_class(rng, MIXES[pipeline][level]),
                               t, prof, slo_scale))
    return out


def proprietary_trace(pipeline: str, duration: float, prof: Profiler,
                      seed: int = 0, rate: Optional[float] = None,
                      slo_scale: float = SLO_SCALE) -> List[Request]:
    """Diurnal/tidal pattern (Fig. 9 right) scaled to the Steady budget."""
    rng = random.Random(seed + 31)
    base = rate if rate is not None else RATES[pipeline]
    t, out = 0.0, []
    while t < duration:
        phase = 2 * math.pi * t / duration
        # two tidal peaks with a burst component
        r = base * (0.35 + 0.8 * max(0.0, math.sin(phase)) ** 2
                    + 0.55 * max(0.0, math.sin(2 * phase + 1.2)) ** 4)
        t += rng.expovariate(max(r, base * 0.05))
        if t >= duration:
            break
        level = rng.choices(["light", "medium", "heavy"],
                            weights=[0.4, 0.4, 0.2])[0]
        out.append(_mk_request(pipeline, _sample_class(rng, MIXES[pipeline][level]),
                               t, prof, slo_scale))
    return out


# -- heterogeneous fleet traces (shared-cluster co-serving, core/fleet.py) ----

# Per-pipeline base rates for the 512-chip shared cluster (requests/s),
# and the canonical traffic-mix flip: image-dominated first half, then
# demand tilts hard toward the heavy pipelines mid-trace.  Tuned so both
# phases run the cluster hot (~60-75% busy chips) with very different
# per-pipeline splits — the regime where the partition, not raw capacity,
# decides SLOs.  A static partition sized for the first half strands chips
# on SD3 exactly when Flux/CogVideoX back up.  ``benchmarks/e2e.py
# --mixed --shared`` passes these explicitly; ``fleet_trace`` itself
# defaults to a flat single phase.
FLEET_RATES: Dict[str, float] = {"sd3": 60.0, "flux": 3.0, "cogvideox": 2.0}
MIX_FLIP: Tuple[Tuple[float, Dict[str, float]], ...] = (
    (0.5, {"sd3": 2.0, "flux": 1.0 / 3.0, "cogvideox": 0.75}),
    (1.0, {"sd3": 0.5, "flux": 2.0, "cogvideox": 1.25}),
)

# Bursty-E/C unit-lending scenario (``--mixed --shared --lending``,
# tests/test_lending.py): a calm sizing phase spanning the first fleet
# demand window fixes the partition, then three anti-correlated sub-window
# decode bursts — cogvideox (vae-decode dominated aux work) spikes 3.5x
# exactly while sd3 sits in its lull.  The bursts are shorter than the
# adaptive scheduler's hysteresis window + cooldown, so re-partitioning
# cannot chase them: without lending the capacity is stranded on sd3's
# range, with lending the decode overflow rides on borrowed sd3 units.
LENDING_RATES: Dict[str, float] = {"sd3": 40.0, "cogvideox": 1.0}
BURST_MULTS: Dict[str, float] = {"cogvideox": 3.5, "sd3": 0.3}


def bursty_ec_phases(duration: float, head: float = 180.0,
                     burst: float = 60.0, calm: float = 60.0
                     ) -> Tuple[Tuple[float, Dict[str, float]], ...]:
    """Phase spans for the bursty-E/C scenario at any duration: the burst
    *lengths* are what the scenario is tuned around (sub-window, so the
    re-partitioner cannot chase them), so they stay absolute — a longer
    trace gets more bursts, not longer ones.  Durations too short for even
    one absolute burst cycle fall back to the tuned 600 s *shape* (spans
    scale down proportionally), so short smoke traces still burst."""
    if duration < head + burst + calm:
        scale = duration / 600.0
        head, burst, calm = head * scale, burst * scale, calm * scale
    spans: List[Tuple[float, Dict[str, float]]] = [(head / duration, {})]
    t = head
    while t + burst + calm <= duration:
        t += burst
        spans.append((t / duration, dict(BURST_MULTS)))
        # an intermediate calm span only when another burst still fits;
        # otherwise the trailing calm runs to the end as one span (span
        # boundaries restart the arrival streams, so structure matters)
        if t + calm + burst + calm <= duration:
            t += calm
            spans.append((t / duration, {}))
        else:
            break
    if spans[-1][0] < 1.0:
        spans.append((1.0, {}))
    return tuple(spans)


BURSTY_EC: Tuple[Tuple[float, Dict[str, float]], ...] = bursty_ec_phases(600.0)


# Cross-lane dynamic batching scenario (``--cross-batch``,
# tests/test_cross_batch.py): a long-prompt burst storm over a flux +
# hunyuanvideo fleet.  A steady cheap-prompt base stream (cond_len 77,
# ``light`` mixes) sizes the frozen plans — each lane gets exactly one
# auxiliary encode unit and flux's EDC pool runs ~90% busy.  On top of
# it, correlated waves of prompt-expansion requests (cond_len 4096,
# CROSS_BATCH_MIXES classes with cheap decode so the encode stage is the
# bottleneck) hit both pipelines at once.  Each wave overloads flux's
# single aux <E> unit (~2.4 unit-equivalents of encode demand against 1);
# cross-lane batching packs flux and hunyuanvideo encodes into one
# batched launch on the freer of the two aux units (~1.55x batch
# amortization at cond 4096).  The alternatives are structurally out:
# unit lending cannot help (flux's encode at cond 4096 runs 0.37 s,
# below the 0.5 s ``lend_min_stage_s`` gate, and the correlated waves
# leave no idle-window-clean supply) and re-partitioning cannot help
# (every plan shape carries exactly one aux E unit regardless of chip
# count, the waves are correlated so shares don't move, and each burst
# is shorter than the detection window + cooldown).  Rates are tuned for
# 96 chips; the wave rate sits just below the regime where fused batches
# serialize — raising it inverts the benefit.
CROSS_BATCH_PIPELINES: Tuple[str, ...] = ("flux", "hunyuanvideo")
CROSS_BATCH_MIXES: Dict[str, List[Tuple[Tuple[int, float], float]]] = {
    "flux": [((128, 0), 1), ((256, 0), 1)],
    "hunyuanvideo": [((540, 1), 1)],
}
CROSS_BATCH_BASE_RATES: Dict[str, float] = {"flux": 2.2, "hunyuanvideo": 0.5}
CROSS_BATCH_WAVE_RATES: Dict[str, float] = {"flux": 7.0, "hunyuanvideo": 0.3}
CROSS_BATCH_COND: Dict[str, int] = {"flux": 4096, "hunyuanvideo": 4096}
# the wave stream draws from an offset seed so base and wave arrivals
# stay independent per-pipeline streams (prime offset, same idiom as the
# dynamic/proprietary trace seed offsets)
CROSS_BATCH_WAVE_SEED_OFFSET = 7919


def cross_batch_phases(duration: float, head: float = 240.0,
                       burst: float = 90.0, calm: float = 150.0,
                       pipelines: Sequence[str] = CROSS_BATCH_PIPELINES
                       ) -> Tuple[Tuple[float, Dict[str, float]], ...]:
    """Burst-gate phase spans for the cross-batch wave stream: multiplier
    0 for every pipeline outside the bursts (the wave simply does not
    exist then), 1 inside.  Like ``bursty_ec_phases`` the burst lengths
    are absolute — each burst must stay shorter than the re-partitioner's
    detection window + cooldown — and durations too short for one full
    cycle fall back to the tuned 900 s shape scaled proportionally."""
    if duration < head + burst + calm:
        scale = duration / 900.0
        head, burst, calm = head * scale, burst * scale, calm * scale
    off = {p: 0.0 for p in pipelines}
    on = {p: 1.0 for p in pipelines}
    spans: List[Tuple[float, Dict[str, float]]] = [(head / duration, dict(off))]
    t = head
    while t < duration:
        t += burst
        spans.append((min(t / duration, 1.0), dict(on)))
        if t >= duration:
            break
        t += calm
        spans.append((min(t / duration, 1.0), dict(off)))
    return tuple(spans)


def cross_batch_trace(duration: float, profs: Dict[str, Profiler],
                      seed: int = 0,
                      base_rates: Optional[Dict[str, float]] = None,
                      wave_rates: Optional[Dict[str, float]] = None,
                      head: float = 240.0, burst: float = 90.0,
                      calm: float = 150.0,
                      slo_scale: float = SLO_SCALE) -> List[Request]:
    """Long-prompt burst-storm trace: the cheap-prompt base stream merged
    with the burst-gated cond-4096 wave stream.  Wave requests carry
    ``cond_len`` from CROSS_BATCH_COND and their deadline is recomputed
    from the profiler at that prompt length, so the SLO reflects the work
    actually requested."""
    pipes = CROSS_BATCH_PIPELINES
    base = fleet_trace(pipes, duration, profs, seed=seed,
                       rates=dict(base_rates or CROSS_BATCH_BASE_RATES),
                       level="light", slo_scale=slo_scale)
    wave = fleet_trace(pipes, duration, profs,
                       seed=seed + CROSS_BATCH_WAVE_SEED_OFFSET,
                       rates=dict(wave_rates or CROSS_BATCH_WAVE_RATES),
                       phases=cross_batch_phases(duration, head, burst, calm,
                                                 pipes),
                       mix_override=CROSS_BATCH_MIXES, slo_scale=slo_scale)
    for r in wave:
        r.cond_len = CROSS_BATCH_COND[r.pipeline]
        r.deadline = r.arrival + slo_scale * profs[r.pipeline].pipeline_time(r)
    out = base + wave
    out.sort(key=lambda r: (r.arrival, r.pipeline, r.rid))
    return out


# Scale-out tier (``benchmarks/e2e.py --scale``, BENCH_scale.json): an
# 8-pipeline, 4096-chip, ~1M-request trace exercising the sim-core hot
# path at one order beyond the committed 512-chip benches.  The 8
# pipelines are the 4 profiled configs plus 4 registry aliases that
# SHARE the base Profiler instances (``PipelineRegistry.register(alias,
# profiler=...)``): the traffic is genuinely 8 independent lanes with 8
# chip ranges and 8 dispatch models, but the memoized profiler tables are
# built once per config — profiling cost is not what this tier measures.
# Rates are per 4096 chips and scale linearly with the chip count (the
# smoke tier runs 512 chips / 100k requests at rates/8), tuned to the
# same ~hot-but-not-saturated operating point as FLEET_RATES.
SCALE_ALIASES: Dict[str, str] = {
    "sd3-v2": "sd3", "flux-v2": "flux", "cogvideox-v2": "cogvideox",
    "hunyuanvideo-v2": "hunyuanvideo",
}
SCALE_PIPELINES: Tuple[str, ...] = (
    "sd3", "flux", "cogvideox", "hunyuanvideo",
    "sd3-v2", "flux-v2", "cogvideox-v2", "hunyuanvideo-v2",
)
SCALE_BASE_CHIPS = 4096
SCALE_RATES: Dict[str, float] = {
    "sd3": 240.0, "flux": 12.0, "cogvideox": 8.0, "hunyuanvideo": 4.0,
    "sd3-v2": 240.0, "flux-v2": 12.0, "cogvideox-v2": 8.0,
    "hunyuanvideo-v2": 4.0,
}


def scale_duration(n_requests: int,
                   num_chips: int = SCALE_BASE_CHIPS) -> float:
    """Trace duration whose Poisson streams yield ``n_requests`` arrivals
    in expectation at the chip-scaled SCALE_RATES."""
    total = sum(SCALE_RATES.values()) * (num_chips / SCALE_BASE_CHIPS)  # detlint: ignore[DET001] module-literal dict: insertion order is fixed
    return n_requests / total


def scale_trace(duration: float, profs: Dict[str, Profiler], seed: int = 0,
                num_chips: int = SCALE_BASE_CHIPS,
                level: str = "medium") -> List[Request]:
    """The scale tier's trace: ``fleet_trace`` over the 8 SCALE_PIPELINES
    at chip-scaled rates; aliases draw from their base config's Table 5
    mix (``mix_override`` — aliases have no MIXES entry of their own).
    ``profs`` must map every alias too (share the base Profiler)."""
    scale = num_chips / SCALE_BASE_CHIPS
    rates = {p: r * scale for p, r in SCALE_RATES.items()}
    mix = {alias: MIXES[base][level]
           for alias, base in SCALE_ALIASES.items()}
    return fleet_trace(SCALE_PIPELINES, duration, profs, seed=seed,
                       rates=rates, level=level, mix_override=mix)


# Elastic, failure-prone fleet scenario (``--elastic``, core/elastic.py,
# tests/test_elastic.py): a steady two-pipeline fleet on a pool that
# refuses to stay fixed.  The schedules below are *capacity* scripts —
# tuples of ``CapacityEvent`` for ``FleetConfig.elastic_schedule`` — not
# traces; pair them with a plain ``fleet_trace`` at ELASTIC_RATES.  Both
# generators track the live node count through their own event sequence,
# so every victim node id is valid in the compacted chip space at apply
# time (the ``CapacityEvent`` contract); degraded nodes are drawn from
# the low end of the pool and victims from the high end, so a loss never
# shifts a still-degraded node's id.  The workload pairs a short-stage
# image pipeline with the *heavy* hunyuanvideo mix (denoise runs of
# 25-75 s, the same order as the notice window): draining matters
# exactly when a stage started inside the lead cannot finish before the
# loss, so the drain-unaware arm both wastes the doomed units' entire
# lead window of execution *and* restarts the victims a full lead later.
# Rates are tuned for a 256-chip starting pool running hot enough that
# losing a storm's worth of nodes visibly backs the queues up — the
# regime where that wasted work decides the recovery tail.
ELASTIC_PIPELINES: Tuple[str, ...] = ("sd3", "hunyuanvideo")
ELASTIC_RATES: Dict[str, float] = {"sd3": 8.0, "hunyuanvideo": 1.6}
ELASTIC_LEVEL = "heavy"            # long-video mix: D-stage ~ lead
ELASTIC_LEAD = 60.0                # spot eviction notice window (s)
ELASTIC_DEGRADE_FACTOR = 2.5       # slow-failing node stage-time multiplier


def preemption_storm_schedule(duration: float, num_chips: int,
                              chips_per_node: int = 8, seed: int = 0,
                              n_storms: int = 2, lead: float = ELASTIC_LEAD,
                              storm_div: int = 6) -> Tuple:
    """Repeated spot-preemption storms with autoscale recovery: each storm
    announces (``lead`` ahead) and then takes a random slice of the upper
    half of the live pool (``live // storm_div`` nodes — smaller divisor,
    bigger storm); a same-size join lands a tenth of the trace later with
    half the announce window.  One low node runs degraded
    (``ELASTIC_DEGRADE_FACTOR``) through the first half.  Deterministic
    per seed."""
    from repro.core.elastic import CapacityEvent
    rng = random.Random(f"elastic-storm:{seed}")
    live = num_chips // chips_per_node
    floor = max(2, live // 2)
    events = []
    bad = rng.randrange(0, max(1, live // 4))
    # the slow node recovers *before* the first storm notice (0.30D - lead):
    # the degrade exercises Monitor detection + quarantine, but a node
    # running at 1/ELASTIC_DEGRADE_FACTOR speed inside the measured
    # recovery windows would confound the drain-vs-requeue comparison the
    # storm exists to make (and, near the knee, tip both arms into
    # collapse regardless of drain policy).
    events.append(CapacityEvent(t=round(duration * 0.05, 3), kind="degrade",
                                nodes=(bad,),
                                factor=ELASTIC_DEGRADE_FACTOR))
    events.append(CapacityEvent(t=round(duration * 0.22, 3), kind="recover",
                                nodes=(bad,)))
    for i in range(n_storms):
        frac = (0.30 + 0.40 * i / (n_storms - 1)) if n_storms > 1 else 0.45
        t = round(duration * frac, 3)
        k = max(1, min(live // storm_div, live - floor))
        if live - k < floor or t - lead <= 0.0:
            break
        victims = tuple(sorted(rng.sample(range(live // 2, live), k)))
        events.append(CapacityEvent(t=t, kind="preempt", nodes=victims,
                                    lead=lead))
        live -= k
        tj = round(t + duration * 0.10, 3)
        if tj < duration * 0.95:
            events.append(CapacityEvent(t=tj, kind="join", n_nodes=k,
                                        lead=lead / 2.0))
            live += k
    return tuple(sorted(events, key=lambda e: (e.t, e.kind)))


def region_evacuation_schedule(duration: float, num_chips: int,
                               chips_per_node: int = 8, seed: int = 0,
                               lead: float = ELASTIC_LEAD) -> Tuple:
    """One announced region evacuation: a quarter of the pool joins first
    (the replacement region, announced ``lead`` ahead so its chips
    pre-warm), then the *old* top quarter is evacuated under a long
    (1.5x) notice window — the migrate-ahead-of-decommission shape.  A
    low node runs degraded early in the trace.  Deterministic per seed."""
    from repro.core.elastic import CapacityEvent
    rng = random.Random(f"elastic-evac:{seed}")
    n0 = num_chips // chips_per_node
    m = max(1, n0 // 4)
    bad = rng.randrange(0, max(1, n0 - m))
    events = [
        CapacityEvent(t=round(duration * 0.12, 3), kind="degrade",
                      nodes=(bad,), factor=ELASTIC_DEGRADE_FACTOR),
        CapacityEvent(t=round(duration * 0.30, 3), kind="recover",
                      nodes=(bad,)),
        CapacityEvent(t=round(duration * 0.40, 3), kind="join", n_nodes=m,
                      lead=lead),
        CapacityEvent(t=round(duration * 0.55, 3), kind="preempt",
                      nodes=tuple(range(n0 - m, n0)), lead=1.5 * lead),
    ]
    return tuple(sorted(events, key=lambda e: (e.t, e.kind)))


# Diurnal predictive scenario (``--predictive``, tests/test_forecast.py):
# anti-phase day/night demand between the image and the video pipeline —
# the periodic structure the demand forecaster (core/forecast.py) exists to
# exploit.  Each flip is sharp (square waveform) and each half-period is
# longer than the adaptive scheduler's cooldown, so the adaptive fleet
# *can* chase every flip — it just always arrives a detection window late
# and pays the reload downtime mid-queue; the predictive scheduler
# pre-warms and fires at the flip.  Tuned for ~256 chips: both phases run
# the cluster hot without saturating the favoured pipeline.
PREDICTIVE_RATES: Dict[str, float] = {"sd3": 28.0, "cogvideox": 0.84}


def diurnal_phases(n_periods: int = 3, spans_per_period: int = 2,
                   amp: float = 0.8, lead_pipeline: str = "sd3",
                   anti_pipelines: Sequence[str] = ("cogvideox",),
                   shape: str = "square"
                   ) -> Tuple[Tuple[float, Dict[str, float]], ...]:
    """Piecewise-constant diurnal rate multipliers for ``fleet_trace``:
    ``lead_pipeline`` runs at ``1 + amp*w(t)`` and every anti-phase
    pipeline at ``1 - amp*w(t)``, with ``w`` a unit periodic waveform —
    ``"square"`` (day/night flips every half period, the canonical diurnal
    mix flip) or ``"sine"`` (smooth tides, sampled at span midpoints).
    Fractions are of the total trace duration, so the period is
    ``duration / n_periods``."""
    spans: List[Tuple[float, Dict[str, float]]] = []
    total = n_periods * spans_per_period
    for i in range(total):
        w = math.sin(2.0 * math.pi * (i + 0.5) / spans_per_period)
        if shape == "square":
            w = 1.0 if w >= 0.0 else -1.0
        mults = {lead_pipeline: 1.0 + amp * w}
        for p in anti_pipelines:
            mults[p] = 1.0 - amp * w
        spans.append(((i + 1) / total, mults))
    return tuple(spans)


def phase_shift_phases(flip_frac: float = 0.5, tilt: float = 2.0,
                       lead_pipeline: str = "sd3",
                       anti_pipelines: Sequence[str] = ("cogvideox",)
                       ) -> Tuple[Tuple[float, Dict[str, float]], ...]:
    """One hard phase shift at ``flip_frac`` of the trace: the lead
    pipeline tilts up then down (anti-phase pipelines mirror it) — the
    single-transition sibling of ``diurnal_phases`` for trend-style
    forecaster inputs and MIX_FLIP-shaped scenarios at any tilt."""
    hi = {lead_pipeline: tilt, **{p: 1.0 / tilt for p in anti_pipelines}}
    lo = {lead_pipeline: 1.0 / tilt, **{p: tilt for p in anti_pipelines}}
    return ((flip_frac, hi), (1.0, lo))


def randomized_fleet_scenario(seed: int,
                              pipelines: Sequence[str] = ("sd3", "flux"),
                              periods: int = 1
                              ) -> Tuple[Dict[str, float],
                                         Tuple[Tuple[float, Dict[str, float]],
                                               ...]]:
    """Seeded random (rates, phases) for the multi-lane event/tick parity
    tests (tests/test_fleet.py): per-pipeline base rates jittered around
    the 128-chip test point and a mid-trace tilt at a random flip point.
    One tuned definition here — like ``FLEET_RATES``/``MIX_FLIP`` — so the
    parity suite and any future bench sweep draw the same scenarios.

    ``periods > 1`` swaps the single flip for a periodic tilt (``2 *
    periods`` equal spans alternating the same random tilt) — the
    forecastable variant the ``predictive`` scheduler's parity runs use.
    The rate/tilt draws are identical either way, so a seed's traffic
    intensity matches across variants."""
    rng = random.Random(f"fleet-scenario:{seed}")
    test_rates = {"sd3": 10.0, "flux": 1.0, "cogvideox": 0.8,
                  "hunyuanvideo": 0.4}
    rates = {p: test_rates.get(p, RATES[p] / 2.0) * rng.uniform(0.6, 1.2)
             for p in pipelines}
    flip = rng.uniform(0.35, 0.65)
    tilt = rng.uniform(1.5, 2.5)
    first, rest = pipelines[0], list(pipelines[1:])
    hi = {first: tilt, **{p: 1.0 / tilt for p in rest}}
    lo = {first: 1.0 / tilt, **{p: tilt for p in rest}}
    if periods <= 1:
        phases = ((flip, hi), (1.0, lo))
    else:
        n = 2 * periods
        phases = tuple(((i + 1) / n, hi if i % 2 == 0 else lo)
                       for i in range(n))
    return rates, phases


def fleet_trace(pipelines: Sequence[str], duration: float,
                profs: Dict[str, Profiler], seed: int = 0,
                rates: Optional[Dict[str, float]] = None,
                phases: Optional[Sequence[Tuple[float, Dict[str, float]]]] = None,
                level: str = "medium",
                slo_scale: float = SLO_SCALE,
                mix_override: Optional[Dict[str, List[Tuple[Tuple[int, float],
                                                            float]]]] = None
                ) -> List[Request]:
    """Merged multi-pipeline trace with piecewise-constant rate multipliers.

    ``phases`` is a sequence of ``(end_fraction, {pipeline: multiplier})``
    spans; within each span pipeline ``p`` arrives as a Poisson process at
    ``rates[p] * multiplier`` (missing multipliers default to 1).  Each
    pipeline draws from its own deterministic stream, so adding a pipeline
    or reordering the list never perturbs the others' arrivals.
    ``mix_override`` maps a pipeline to a class mix used in place of
    ``MIXES[pid][level]`` (scenario-specific mixes like CROSS_BATCH_MIXES
    stay out of the Table 5 tables)."""
    if phases is None:
        phases = ((1.0, {}),)
    out: List[Request] = []
    for pid in pipelines:
        rng = random.Random(f"fleet:{seed}:{pid}")
        base = (rates or FLEET_RATES).get(pid)
        if base is None:   # lazily: alias pipelines have no Table 5 rate
            base = RATES[pid]
        mix = (mix_override or {}).get(pid) or MIXES[pid][level]
        start = 0.0
        for end_frac, mults in phases:
            end = duration * end_frac
            r = base * mults.get(pid, 1.0)
            if r > 0.0:
                t = start
                while True:
                    t += rng.expovariate(r)
                    if t >= end:
                        break
                    out.append(_mk_request(pid, _sample_class(rng, mix), t,
                                           profs[pid], slo_scale))
            start = end
    out.sort(key=lambda r: r.arrival)
    return out


def make_trace(pipeline: str, workload: str, duration: float, prof: Profiler,
               seed: int = 0, rate: Optional[float] = None,
               slo_scale: float = SLO_SCALE) -> List[Request]:
    if workload in ("light", "medium", "heavy"):
        return steady_trace(pipeline, workload, duration, prof, seed, rate, slo_scale)
    if workload == "dynamic":
        return dynamic_trace(pipeline, duration, prof, seed, rate, slo_scale)
    if workload == "proprietary":
        return proprietary_trace(pipeline, duration, prof, seed, rate, slo_scale)
    raise KeyError(workload)
