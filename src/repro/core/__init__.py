"""TridentServe core: dynamic stage-level serving for diffusion pipelines.

The paper's contribution, as a composable system:

* ``placement``    — placement types, Virtual Replicas (Table 3), plans
* ``orchestrator`` — Dynamic Orchestrator (Algorithm 2, Appendix C.1)
* ``dispatcher``   — Resource-Aware Dispatcher (two-step ILP, §6.2, C.2)
* ``ilp``          — in-repo branch-and-bound 0/1 ILP solver
* ``runtime``      — Runtime Engine (§5): reinstance, stage prep with
                     proactive push + handoff buffers, merging execute,
                     Adjust-on-Dispatch placement switches
* ``monitor``      — sliding-window throughput + switch trigger (§5.3)
* ``profiler``     — offline profiler as a calibrated analytic model (§5.1)
* ``clock``        — the scheduler-agnostic event-clock kernel (event heap,
                     tick-grid quantization, heartbeat/adaptive idle gap,
                     wake-source plug-ins) + the ``Lane`` serving stack;
                     every simulator in the repo drives this one loop
* ``simulator``    — discrete-event cluster driving the real planner code
                     (a one-lane driver over the clock kernel)
* ``trident``      — the full TridentServe scheduler (Algorithm 1)
* ``baselines``    — B1-B6 (§8.1, Appendix D.2)
* ``workloads``    — Steady/Dynamic/Proprietary traces (Table 5, Fig. 9)
* ``fleet``        — shared-cluster co-serving of heterogeneous pipelines:
                     one placement plan for the whole cluster, chip budgets
                     re-partitioned with the live traffic mix
"""
from repro.core import (baselines, clock, dispatcher, fleet, ilp, monitor,
                        orchestrator, placement, profiler, request, runtime,
                        simulator, trident, workloads)

__all__ = ["baselines", "clock", "dispatcher", "fleet", "ilp", "monitor",
           "orchestrator", "placement", "profiler", "request", "runtime",
           "simulator", "trident", "workloads"]
