"""Elastic, failure-prone capacity: the fault-injection wake source.

TridentServe's stage-level paradigm (and every fleet layer above it in
this repo) assumed a fixed, immortal chip pool.  A millions-of-users
deployment lives on elastic, failure-prone capacity — autoscale-up,
spot preemption with an eviction notice, slow-failing hardware — and
DisagFusion (PAPERS.md) makes the case that the scheduler must treat
capacity itself as a first-class dynamic input.  The event-clock kernel
(repro.core.clock) makes that one plug-in: the ``FaultInjector`` is a
deterministic, seeded schedule of **capacity events** registered as one
more wake source, so faults land at exact grid points both clock modes
visit and every trajectory reproduces byte-for-byte.

Event kinds (``CapacityEvent.kind``):

* ``"join"`` — autoscale-up: ``n_nodes`` fresh nodes land at ``t`` and
  the logical chip space grows at the top.  With a ``lead`` (the
  announce window) and ``FleetConfig.elastic_prewarm`` on, the notice at
  ``t - lead`` stages the post-join target partition's weights onto the
  incoming chips (``repro.core.forecast.stage_announced_capacity``) so
  the join-time re-partition charges no reload for them.
* ``"preempt"`` — spot eviction: ``nodes`` disappear at ``t``.  The
  notice at ``t - lead`` is the eviction warning; with
  ``FleetConfig.elastic_drain`` on the fleet **drains, stage-aware**:
  doomed units stay in service but only accept launches that finish
  before the land (``Dispatcher.dispatch``'s ``draining`` filter — work
  the loss would kill is exactly the work a drain must refuse, and
  nothing else), loans riding doomed lender units are force-returned
  (deferred past an un-drained fused launch — the satellite-1 guard in
  ``LendingBroker.force_return_unit``), and in-flight stage work that
  would outlive the loss is revoked and requeued immediately, giving
  the surviving pool the whole lead window to re-serve it.  At the loss
  itself everything still in flight on the doomed units is requeued
  (the drain-unaware arm pays this for *all* of it), the chip space is
  compacted (higher chips shift down; ``chip_map``), and the fleet
  re-partitions sized to the surviving pool.
* ``"degrade"`` / ``"recover"`` — slow-failing units: every unit on the
  named nodes takes ``factor``x its profiled stage time
  (``RuntimeEngine.set_unit_slowdown``).  The injector's
  ``DegradeDetector`` watches drained stage completions (per-unit mean
  vs the placement-class pool mean) and **quarantines** a detected unit
  (``decommission`` — dispatch routes around it) once the evidence
  clears ``degrade_detect_ratio`` at ``degrade_min_samples``.

Requeue contract: a dispatched request's stage completions are all
pushed at decision time, so revoking it means removing every one of its
events from the kernel heap (``EventClock.remove_completions``),
clearing its ``stage_done`` stamps, and re-admitting it to its lane's
pending pool under the **original** arrival and deadline — the SLO
accounting keeps charging the original clock, which is exactly the
recovery latency the ``--elastic`` bench measures.  Innocent members of
a fused ``MERGED_LANE`` event keep their completion: the event is
re-pushed with the victims filtered out.  Reservations already charged
on surviving units for revoked work are deliberately left in place — a
conservative, deterministic model of work that cannot be un-launched.

Determinism: the schedule is expanded once into a sorted phase list;
victim sets and requeue walks iterate in sorted ``(pipeline, rid)`` /
``(pipeline, unit)`` order; nothing reads the wall clock or an unseeded
RNG.  With ``FleetConfig.elastic`` (the default: off) the injector is
never constructed and every touched code path is bit-identical to the
committed BENCH trajectories.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple, TYPE_CHECKING

from repro.core.clock import MERGED_LANE

if TYPE_CHECKING:   # import cycle: fleet.py builds the injector
    from repro.core.fleet import FleetSimulator


@dataclasses.dataclass(frozen=True)
class CapacityEvent:
    """One scheduled capacity event.

    ``t`` is the *landing* time (the join/loss/degrade applies there);
    ``lead`` opens the announce window at ``t - lead`` (preemption
    notice, join announcement).  ``nodes`` are logical node ids valid in
    the chip space **at apply time** — the workload generators
    (``repro.core.workloads``) track the live node count through their
    own event sequence so the indices always resolve."""
    t: float
    kind: str                          # "join" | "preempt" | "degrade"
                                       # | "recover"
    nodes: Tuple[int, ...] = ()        # victims (preempt/degrade/recover)
    n_nodes: int = 0                   # join size, in nodes
    lead: float = 0.0                  # notice fires at t - lead
    factor: float = 1.0                # degrade slowdown multiplier

    def __post_init__(self):
        assert self.kind in ("join", "preempt", "degrade", "recover")
        assert self.lead >= 0.0


class DegradeDetector:
    """Monitor-side detection of slow-failing units.

    Per drained (non-merged) stage completion, the duration feeds two
    running means keyed by the completion's full *work class* —
    ``(pipeline, stage, placement type, request class, batch size)`` —
    the pool mean across all units and the per-unit mean of every unit
    the stage ran on.  Keying by work class compares like with like: a
    1536-res batch legitimately runs ~10x a 128-res one, so an unkeyed
    pool mean would quarantine every unit the mix happens to hand heavy
    work (the false-positive storm this keying exists to prevent).  A
    unit whose mean exceeds ``ratio`` x its class pool mean — with at
    least ``min_samples`` of its own in that class and a 4x-deeper pool
    — is reported for quarantine.  Fused ``MERGED_LANE`` launches are
    not samples (batched cross-lane durations live on a different
    curve).  Stats reset on re-partition: unit ids remap, and a
    still-degraded node is simply re-detected on the fresh engines."""

    def __init__(self, ratio: float, min_samples: int):
        self.ratio = ratio
        self.min_samples = min_samples
        self._pool: Dict[tuple, List[float]] = {}
        self._unit: Dict[tuple, List[float]] = {}

    def reset(self) -> None:
        self._pool.clear()
        self._unit.clear()

    def sample(self, pid: str, stage: str, ptype: str, dur: float,
               cls: tuple,
               units: Tuple[Tuple[str, int], ...]) -> List[Tuple[str, int]]:
        """Feed one drained completion (``cls`` = request class + batch
        size); returns the units (if any) whose evidence now clears the
        quarantine threshold."""
        key = (pid, stage, ptype, cls)
        pool = self._pool.setdefault(key, [0.0, 0.0])
        pool[0] += 1.0
        pool[1] += dur
        suspects: List[Tuple[str, int]] = []
        deep = pool[0] >= 4.0 * self.min_samples
        for up in units:
            st = self._unit.setdefault((up, key), [0.0, 0.0])
            st[0] += 1.0
            st[1] += dur
            if (deep and st[0] >= self.min_samples
                    and st[1] / st[0] > self.ratio * (pool[1] / pool[0])):
                suspects.append(up)
        return suspects


class FaultInjector:
    """The capacity-event wake source (one per ``FleetSimulator`` when
    ``FleetConfig.elastic`` is on).

    The schedule is expanded into a sorted ``(time, seq, phase, event)``
    list — ``"notice"`` at ``t - lead`` (when a lead exists), ``"land"``
    at ``t`` — fired in order by ``step`` (called at the top of every
    fleet scheduler step) with ``next_wake`` registered on the kernel so
    the clock visits each phase exactly.  Both bench arms expand the
    same phases; the drain/pre-warm *actions* are gated on the config
    flags, so the arms share one wake grid."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.cpn = cfg.chips_per_node
        self.live_chips = cfg.num_chips
        phases: List[Tuple[float, int, str, CapacityEvent]] = []
        seq = 0
        for ev in sorted(cfg.elastic_schedule, key=lambda e: (e.t, e.kind)):
            if ev.kind in ("join", "preempt") and ev.lead > 0.0:
                phases.append((ev.t - ev.lead, seq, "notice", ev))
                seq += 1
            phases.append((ev.t, seq, "land", ev))
            seq += 1
        phases.sort(key=lambda p: (p[0], p[1]))
        self._phases = phases
        self._pi = 0
        self.detector = DegradeDetector(cfg.degrade_detect_ratio,
                                        cfg.degrade_min_samples)
        self.degraded: Dict[int, float] = {}    # live node id -> factor
        self.doomed_nodes: Tuple[int, ...] = () # notice fired, loss pending
        self.doomed_land: float = 0.0           # when the pending loss lands
        self.quarantined: Set[Tuple[str, int]] = set()
        # accounting (surfaced through FleetResult)
        self.capacity_events = 0
        self.nodes_joined = 0
        self.nodes_lost = 0
        self.requeued_requests = 0
        self.drained_units = 0
        self.quarantined_units = 0
        self.elastic_prewarm_chips = 0

    # -- wake source (registered by the fleet driver) --------------------------

    def next_wake(self, tau: float) -> Optional[float]:
        """Earliest unfired phase time — ``step`` has already consumed
        everything <= tau by the time the kernel consults its sources."""
        i = self._pi
        phases = self._phases
        while i < len(phases) and phases[i][0] <= tau:
            i += 1
        return phases[i][0] if i < len(phases) else None

    # -- per-step hook ---------------------------------------------------------

    def step(self, fleet: "FleetSimulator", tau: float) -> None:
        while self._pi < len(self._phases) \
                and self._phases[self._pi][0] <= tau:
            _, _, phase, ev = self._phases[self._pi]
            self._pi += 1
            if ev.kind == "join":
                if phase == "notice":
                    self._announce_join(fleet, tau, ev)
                else:
                    self._land_join(fleet, tau, ev)
            elif ev.kind == "preempt":
                if phase == "notice":
                    self._notice_preempt(fleet, tau, ev)
                else:
                    self._land_preempt(fleet, tau, ev)
            elif ev.kind == "degrade":
                self._land_degrade(fleet, tau, ev)
            else:
                self._land_recover(fleet, tau, ev)

    # -- chip-space helpers ----------------------------------------------------

    def _chips_of(self, nodes) -> Set[int]:
        cpn = self.cpn
        return {c for n in nodes for c in range(n * cpn, (n + 1) * cpn)}

    def _doomed_pairs(self, fleet: "FleetSimulator",
                      chips: Set[int]) -> Set[Tuple[str, int]]:
        """(pipeline, unit) pairs whose chips intersect ``chips`` — the
        lanes' own units plus borrowed loan slots that physically sit on
        a doomed lender unit."""
        pairs: Set[Tuple[str, int]] = set()
        for pid, lane in fleet.lanes.items():
            lo, _ = fleet.plan.chip_ranges[pid]
            k = fleet.plan.subplans[pid].unit_size
            for g in range(lane.base_units):
                if any(c in chips
                       for c in range(lo + g * k, lo + (g + 1) * k)):
                    pairs.add((pid, g))
        if fleet.broker is not None:
            for loan in fleet.broker.active:
                if (loan.lender, loan.lender_uid) in pairs:
                    pairs.add((loan.borrower, loan.slot))
        return pairs

    # -- join ------------------------------------------------------------------

    def _announce_join(self, fleet: "FleetSimulator", tau: float,
                       ev: CapacityEvent) -> None:
        if not self.cfg.elastic_prewarm:
            return
        from repro.core.forecast import stage_announced_capacity
        n = stage_announced_capacity(
            fleet, tau, self.live_chips + ev.n_nodes * self.cpn, land=ev.t)
        self.elastic_prewarm_chips += n

    def _land_join(self, fleet: "FleetSimulator", tau: float,
                   ev: CapacityEvent) -> None:
        self.live_chips += ev.n_nodes * self.cpn
        self.nodes_joined += ev.n_nodes
        self.capacity_events += 1
        fleet.orch.num_chips = self.live_chips
        # the old chip space is a prefix of the new one: no translation,
        # and any announce-time pre-warm marks on the incoming chips are
        # consumed by this re-partition's reload accounting
        fleet._capacity_repartition(tau, chip_map=None)

    # -- preemption ------------------------------------------------------------

    def _notice_preempt(self, fleet: "FleetSimulator", tau: float,
                        ev: CapacityEvent) -> None:
        self.doomed_nodes = tuple(sorted(ev.nodes))
        self.doomed_land = ev.t
        if not self.cfg.elastic_drain:
            return
        chips = self._chips_of(ev.nodes)
        pairs = self._doomed_pairs(fleet, chips)
        self._drain(fleet, pairs, tau, ev.t)
        # revoke only the in-flight work that would outlive the loss:
        # anything finishing inside the lead window completes naturally
        self.requeued_requests += self._requeue(fleet, pairs, tau,
                                                after=ev.t)

    def _land_preempt(self, fleet: "FleetSimulator", tau: float,
                      ev: CapacityEvent) -> None:
        lost = set(ev.nodes)
        chips = self._chips_of(lost)
        pairs = self._doomed_pairs(fleet, chips)
        # everything still in flight on the doomed units dies with them
        # (the drain-unaware arm pays this for the full lead window's
        # worth of dispatches)
        self.requeued_requests += self._requeue(fleet, pairs, tau)
        # compact the chip space: survivors keep their order, higher
        # chips shift down into the holes
        chip_map: Dict[int, int] = {}
        nxt = 0
        for c in range(self.live_chips):
            if c in chips:
                continue
            chip_map[c] = nxt
            nxt += 1
        self.degraded = {
            n - sum(1 for m in lost if m < n): f  # detlint: ignore[DET001] int count over int set: exact
            for n, f in sorted(self.degraded.items()) if n not in lost}
        self.live_chips -= len(lost) * self.cpn
        self.nodes_lost += len(lost)
        self.capacity_events += 1
        self.doomed_nodes = ()
        self.doomed_land = 0.0
        fleet.orch.num_chips = self.live_chips
        fleet._capacity_repartition(tau, chip_map=chip_map)

    def _drain(self, fleet: "FleetSimulator", pairs: Set[Tuple[str, int]],
               tau: float, land: float) -> None:
        """Stage-aware drain: doomed units stay in service for the rest of
        the notice window but only for launches that *finish before the
        land* (the dispatcher's ``draining`` filter) — short work keeps
        flowing through the doomed capacity while long stages, which would
        be requeued at the loss and re-run from scratch, steer clear.
        Pre-warm marks on doomed units are evicted and loans riding doomed
        lender units are force-returned (deferred past an un-drained fused
        launch)."""
        for pid, g in sorted(pairs):
            lane = fleet.lanes[pid]
            if g >= lane.base_units:
                continue   # loan slots close via the lender's force-return
            if g in lane.draining_units:
                continue
            lane.draining_units[g] = land
            self.drained_units += 1
            fleet._evict_prewarm_unit(pid, g)
            if fleet.broker is not None:
                fleet.broker.force_return_unit(fleet, pid, g, tau)
            fleet.mark_lane_dirty(pid)

    # -- requeue ---------------------------------------------------------------

    def _requeue(self, fleet: "FleetSimulator", pairs: Set[Tuple[str, int]],
                 tau: float, after: Optional[float] = None) -> int:
        """Revoke in-flight stage events touching ``pairs`` (only those
        finishing past ``after``, when given) and requeue their requests.
        Removing one stage of a request breaks its whole chain, so every
        other event carrying a victim is removed too; fused MERGED_LANE
        events keep their innocent members via a filtered re-push."""
        clock = fleet.clock
        first = clock.remove_completions(
            lambda ev: (after is None or ev[0] > after)
            and any(u in pairs for u in ev[7]))
        if not first:
            return 0
        victims: Set[Tuple[str, int]] = set()
        reqs: Dict[Tuple[str, int], object] = {}
        for ev in first:
            for r in ev[6]:
                victims.add((r.pipeline, r.rid))
                reqs[(r.pipeline, r.rid)] = r
        while True:
            extra = clock.remove_completions(
                lambda ev: any((r.pipeline, r.rid) in victims
                               for r in ev[6]))
            grew = False
            for ev in extra:
                if ev[2] == MERGED_LANE:
                    keep = tuple(r for r in ev[6]
                                 if (r.pipeline, r.rid) not in victims)
                    if keep:
                        clock.push_completion(ev[0], MERGED_LANE, ev[3],
                                              ev[4], ev[5], keep, ev[7])
                    continue
                for r in ev[6]:
                    k = (r.pipeline, r.rid)
                    if k not in victims:
                        victims.add(k)
                        reqs[k] = r
                        grew = True
            if not grew:
                break
        for pid, rid in sorted(victims):
            r = reqs[(pid, rid)]
            r.stage_done.clear()
            fleet.lanes[pid].requeue(
                r, fleet.clock if fleet._track_flips else None)
            fleet.mark_lane_dirty(pid)
        return len(victims)

    # -- degrade / recover -----------------------------------------------------

    def _land_degrade(self, fleet: "FleetSimulator", tau: float,
                      ev: CapacityEvent) -> None:
        for n in ev.nodes:
            self.degraded[n] = ev.factor
        self.capacity_events += 1
        self._apply_degrade(fleet)

    def _land_recover(self, fleet: "FleetSimulator", tau: float,
                      ev: CapacityEvent) -> None:
        for n in ev.nodes:
            self.degraded.pop(n, None)
        self.capacity_events += 1
        self._apply_degrade(fleet)
        # a recovered node's quarantined units rejoin the dispatch indices
        chips = self._chips_of(ev.nodes)
        healed = {p for p in self._doomed_pairs(fleet, chips)
                  if p in self.quarantined}
        for pid, g in sorted(healed):
            fleet.lanes[pid].engine.plan.commission(g)
            self.quarantined.discard((pid, g))
            fleet.mark_lane_dirty(pid)

    def _apply_degrade(self, fleet: "FleetSimulator") -> None:
        """Sync every engine's per-unit slowdown to the current degraded
        node map (also re-applied onto fresh engines after every
        re-partition — the slow hardware does not heal when chips change
        hands)."""
        degraded = self.degraded
        cpn = self.cpn
        for pid, lane in fleet.lanes.items():
            lo, _ = fleet.plan.chip_ranges[pid]
            k = fleet.plan.subplans[pid].unit_size
            for g in range(lane.base_units):
                f = 1.0
                for c in range(lo + g * k, lo + (g + 1) * k):
                    nf = degraded.get(c // cpn, 1.0)
                    if nf > f:
                        f = nf
                if lane.engine.units[g].slow != f:
                    lane.engine.set_unit_slowdown(g, f)
                    fleet.mark_lane_dirty(pid)

    # -- detection feed (fleet._drain) -----------------------------------------

    def observe(self, fleet: "FleetSimulator", pid: str, stage: str,
                ptype: str, dur: float, members, units, tau: float) -> None:
        if pid == MERGED_LANE:
            return   # fused batched durations are not solo-run samples
        m = members[0]
        cls = (m.resolution, m.seconds, m.cond_len, len(members))
        for up in self.detector.sample(pid, stage, ptype, dur, cls, units):
            self._quarantine(fleet, up, tau)

    def _quarantine(self, fleet: "FleetSimulator", up: Tuple[str, int],
                    tau: float) -> None:
        pid, g = up
        if up in self.quarantined:
            return
        lane = fleet.lanes[pid]
        if g >= lane.base_units:
            return   # borrowed slot: the lender's unit is the slow one
        plan = lane.engine.plan
        if not plan.is_active(g) or plan.is_decommissioned(g):
            return
        if not self._covers_without(plan, g, lane.base_units):
            return   # never quarantine a lane below full stage coverage
        plan.decommission(g)
        self.quarantined.add(up)
        self.quarantined_units += 1
        fleet.mark_lane_dirty(pid)

    @staticmethod
    def _covers_without(plan, g: int, base_units: int) -> bool:
        for s in ("E", "D", "C"):
            if not any(s in plan.placements[h]
                       for h in range(base_units)
                       if h != g and plan.is_active(h)
                       and not plan.is_decommissioned(h)):
                return False
        return True

    # -- re-partition hook -----------------------------------------------------

    def after_repartition(self, fleet: "FleetSimulator", tau: float) -> None:
        """Engines and sub-plans were rebuilt: re-derive every overlay the
        injector owns.  Detector stats and quarantine marks reset (unit
        ids remapped; still-slow units are re-detected), ground-truth
        slowdowns are re-applied, and — when a loss notice is still
        pending — the doomed chips' fresh units re-enter the drain so a
        mix-shift re-partition inside the notice window cannot hand them
        long work."""
        self.detector.reset()
        self.quarantined.clear()
        for lane in fleet.lanes.values():
            lane.draining_units.clear()   # unit ids were remapped
        self._apply_degrade(fleet)
        if self.doomed_nodes and self.cfg.elastic_drain:
            chips = self._chips_of(self.doomed_nodes)
            self._drain(fleet, self._doomed_pairs(fleet, chips), tau,
                        self.doomed_land)
