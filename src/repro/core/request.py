"""Requests, stages, and dispatch-plan records (the paper's Γ abstraction)."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Tuple

STAGES = ("E", "D", "C")

_req_counter = itertools.count()


@dataclasses.dataclass
class Request:
    """One generative-vision request."""
    pipeline: str                 # pipeline config name (sd3/flux/...)
    resolution: int               # target output resolution (square)
    seconds: float = 0.0          # video duration; 0 for images
    arrival: float = 0.0          # arrival timestamp (s)
    deadline: float = 0.0         # SLO deadline (absolute, s)
    cond_len: int = 77            # prompt token count
    rid: int = dataclasses.field(default_factory=lambda: next(_req_counter))

    # runtime bookkeeping (filled by the engine)
    stage_done: Dict[str, float] = dataclasses.field(default_factory=dict)
    dispatched: Dict[str, "DispatchPlan"] = dataclasses.field(default_factory=dict)

    @property
    def finished(self) -> bool:
        return "C" in self.stage_done

    @property
    def finish_time(self) -> float:
        return self.stage_done.get("C", float("inf"))

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival

    @property
    def on_time(self) -> bool:
        return self.finished and self.finish_time <= self.deadline

    def key(self) -> Tuple[str, int, float]:
        """Workload-class key used by the profiler's tables."""
        return (self.pipeline, self.resolution, self.seconds)


@dataclasses.dataclass
class DispatchPlan:
    """Γ_r^s = (r, G_r^s, {s: φ_s}) — stage-level dispatch record."""
    rid: int
    stage: str                     # "E" | "D" | "C"
    workers: Tuple[int, ...]       # chip ids
    degree: int                    # SP degree (in scheduling units)
    parallelism: str = "ulysses"   # φ_s: ulysses | scan-chunk | spatial
    # execution bookkeeping
    start: float = -1.0
    finish: float = -1.0
    merged_with: Optional[str] = None   # stage merged into this plan's run

    @property
    def launched(self) -> bool:
        return self.start >= 0.0
