"""Runtime Engine (§5): executes placement and dispatch plans.

Implements the paper's three-step dispatch execution adapted to TPU:

* **Dynamic Reinstance** — on NVIDIA this (re)builds NCCL groups; XLA
  collectives are compile-time, so the TPU-native equivalent is a cache of
  pre-compiled SPMD executables keyed by (stage, unit-set shape).  The *hot
  set* (single units and contiguous intra-node groups of size 2/4/8) costs
  nothing at dispatch; other combinations pay a one-time lazy-init cost and
  are cached — same O(ms) behavior and bounded-memory goal as §5.2.
* **Stage Preparation** — proactive push into per-unit handoff buffers
  (bounded by Cap_hb; overflow falls back to the pinned-host path), two-step
  locality-aware transfer (inter-node link to one member, then intra-node
  broadcast), and Adjust-on-Dispatch replica loading (intra-node peer copy
  if any node peer hosts the stage, else host staging).
* **Merging Execute** — consecutive stage plans of one request on an
  identical unit set run as one atomic reservation, eliminating the
  per-dispatch CPU overhead.

The engine is backend-agnostic: the discrete-event simulator drives it with
profiler latencies; the wall-clock example drives it with real JAX stage
executions (examples/serve_pipeline.py).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Sequence, Set, Tuple

from repro.core.dispatcher import DispatchDecision
from repro.core.placement import PlacementPlan
from repro.core.profiler import (COMM_GROUP_INIT, DISPATCH_OVERHEAD, HOST_BW,
                                 Profiler)

CAP_HB = 1 * 2 ** 30          # handoff-buffer capacity per unit (bytes)


@dataclasses.dataclass
class Unit:
    uid: int
    node: int
    placement: str               # metadata placement (may lead residency)
    resident: Set[str]           # stages actually loaded
    free_at: float = 0.0
    hb_staged: float = 0.0       # staged handoff bytes (drained at launch)
    slow: float = 1.0            # degraded-hardware slowdown (core/elastic.py)


@dataclasses.dataclass
class EngineStats:
    dispatches: int = 0
    merged_runs: int = 0
    lazy_group_inits: int = 0
    adjust_loads: int = 0
    adjust_load_time: float = 0.0
    host_path_pushes: int = 0
    device_pushes: int = 0
    transfer_time: float = 0.0
    placement_switches: int = 0
    downtime: float = 0.0
    prewarm_loads: int = 0
    prewarm_load_time: float = 0.0
    # dispatch ILP solutions reused across wake-ups without a re-solve
    # (Dispatcher(incremental=True)'s persisted-model skip; credited by
    # the scheduler driving this engine — core/trident.py)
    ilp_reuses: int = 0


class RuntimeEngine:
    def __init__(self, profiler: Profiler, plan: PlacementPlan, *,
                 proactive_push: bool = True, adjust_on_dispatch: bool = True):
        self.prof = profiler
        self.plan = plan
        self.proactive_push = proactive_push
        self.adjust_on_dispatch = adjust_on_dispatch
        self.units: List[Unit] = [
            Unit(uid=g, node=plan.node_of(g), placement=p, resident=set(p))
            for g, p in enumerate(plan.placements)]
        self._groups: Set[frozenset] = set()
        self.stats = EngineStats()
        # idle tracking: busy units sit in a (free_at, uid) heap and migrate
        # back to the idle set lazily as the clock passes their release time
        # — idle_units() is then O(released) instead of O(units) per wake-up.
        # Stale heap entries (unit re-reserved meanwhile) are dropped on pop.
        self._idle: Set[int] = {u.uid for u in self.units}
        self._busy_heap: List[Tuple[float, int]] = []
        # mirror of every unit's free_at, maintained at the (few) mutation
        # sites so ``free_at()`` is O(1) instead of an O(units) dict build
        # on every dispatch round
        self._free_map: Dict[int, float] = {u.uid: u.free_at
                                            for u in self.units}
        # degraded-hardware modelling (core/elastic.py): True only while
        # some unit carries a slowdown factor — the default path never
        # takes the multiply branches in ``execute``
        self._degraded = False

    # ------------------------------------------------------------------ state

    def _mark_busy(self, uid: int, until: float) -> None:
        self._idle.discard(uid)
        heapq.heappush(self._busy_heap, (until, uid))

    def idle_units(self, tau: float) -> Set[int]:
        """Units idle at ``tau``.  Returns the engine's *live* idle set —
        treat it as read-only and consume it before the next engine
        mutation (every scheduler fetches it fresh per wake-up; copying
        here cost O(units) per tick at fleet scale)."""
        heap = self._busy_heap
        while heap and heap[0][0] <= tau:
            _, uid = heapq.heappop(heap)
            if self.units[uid].free_at <= tau:   # else: re-reserved since
                self._idle.add(uid)
        return self._idle

    def free_at(self) -> Dict[int, float]:
        """Live ``{uid: free_at}`` view (same read-only contract as
        ``idle_units``)."""
        return self._free_map

    def seed_unit_state(self, busy_until: Dict[int, float]) -> None:
        """Pre-busy freshly built units (fleet re-partition, core/fleet.py):
        a unit inherits the in-flight work of the chips it now owns plus the
        weight-reload latency charged when its pipeline or placement type
        changed hands.  The lending broker reuses the same entry point to
        charge weight-reload latency on borrow and on return."""
        for uid, t in busy_until.items():
            u = self.units[uid]
            if t > u.free_at:
                u.free_at = t
                self._free_map[uid] = t
            if u.free_at > 0.0:
                self._mark_busy(uid, u.free_at)

    def stage_prewarm(self, uid: int, tau: float, load_time: float) -> float:
        """Predictive pre-warm (core/fleet.py): stage a *future* partition's
        weights on a unit that keeps serving its current pipeline until the
        cutover.  The staging DMA occupies the unit like a reload (charged
        through ``seed_unit_state``, the same entry point re-partition
        swaps and loans pay), but the unit stays in its engine and remains
        dispatchable afterwards — the load overlaps the tail of the old
        mix instead of charging downtime at the re-partition.  Returns the
        time the unit is busy until."""
        u = self.units[uid]
        until = max(tau, u.free_at) + load_time
        self.seed_unit_state({uid: until})
        self.stats.prewarm_loads += 1
        self.stats.prewarm_load_time += load_time
        return until

    # -- degraded hardware (core/elastic.py) -----------------------------------

    def set_unit_slowdown(self, uid: int, factor: float) -> None:
        """Degraded-unit modelling: stage runs touching this unit take
        ``factor``x their profiled time until reset to 1.0.  Never called
        on the default path — ``_degraded`` stays False and ``execute``
        never reads the factors, keeping the off path bit-identical."""
        self.units[uid].slow = factor
        self._degraded = any(u.slow != 1.0 for u in self.units)

    def _slow_factor(self, unit_ids: Sequence[int]) -> float:
        f = 1.0
        for g in unit_ids:
            s = self.units[g].slow
            if s > f:
                f = s
        return f

    # -- cross-pipeline unit lending (core/lending.py) -------------------------

    def add_loan_unit(self, ptype: str, node: int, busy_until: float) -> int:
        """Append a borrowed foreign unit hosting ``ptype`` (E/C only) for
        this engine's pipeline.  ``node`` is a synthetic id disjoint from the
        plan's own nodes, so transfer/locality modelling treats pushes to the
        borrowed unit as inter-node traffic.  The unit starts busy until
        ``busy_until`` (the borrow-time weight reload)."""
        uid = self.plan.extend(ptype)
        self.units.append(Unit(uid=uid, node=node, placement=ptype,
                               resident=set(ptype), free_at=busy_until))
        self._free_map[uid] = busy_until
        self._mark_busy(uid, busy_until)
        return uid

    def revive_loan_unit(self, uid: int, ptype: str, node: int,
                         busy_until: float) -> None:
        """Reuse a returned loan slot for a new loan (keeps unit ids stable
        across the engine's lifetime — nothing is ever removed)."""
        u = self.units[uid]
        u.placement = ptype
        u.resident = set(ptype)
        u.node = node
        u.hb_staged = 0.0
        u.free_at = max(u.free_at, busy_until)
        self._free_map[uid] = u.free_at
        self.plan.retype(uid, ptype)
        self.plan.set_active(uid, True)
        self._mark_busy(uid, u.free_at)

    # ----------------------------------------------------------- placement plan

    def apply_placement(self, new_plan: PlacementPlan, tau: float,
                        downtime_adjust: bool = False) -> float:
        """Switch placements.  Adjust-on-Dispatch: metadata flips now, replica
        movement deferred to the next dispatch needing it.  The naive
        ``downtime_adjust`` baseline (Fig. 13) halts the cluster while every
        replica change is applied synchronously."""
        assert new_plan.num_units == self.plan.num_units
        self.stats.placement_switches += 1
        cost = 0.0
        if downtime_adjust or not self.adjust_on_dispatch:
            for u, new_p in zip(self.units, new_plan.placements):
                # sorted: str-set iteration order is hash-seed dependent
                # and float accumulation is order-sensitive
                for s in sorted(set(new_p) - u.resident):
                    cost += self.prof.stage_load_time(s, via_host=True)
                u.resident = set(new_p)
            barrier = max([tau] + [u.free_at for u in self.units]) + cost
            for u in self.units:
                u.free_at = barrier
                self._free_map[u.uid] = barrier
                self._mark_busy(u.uid, barrier)
            self.stats.downtime += cost
        for u, new_p in zip(self.units, new_plan.placements):
            u.placement = new_p
        self.plan = new_plan
        return cost

    # ------------------------------------------------------------ internals

    def _reinstance(self, unit_ids: Tuple[int, ...]) -> float:
        """Dynamic Reinstance cost: 0 for the hot set / cached combos."""
        key = frozenset(unit_ids)
        if key in self._groups:
            return 0.0
        nodes = {self.units[g].node for g in unit_ids}
        k = len(unit_ids)
        contiguous = (max(unit_ids) - min(unit_ids) + 1) == k
        hot = len(nodes) == 1 and k in (1, 2, 4, 8) and contiguous
        self._groups.add(key)
        if hot:
            return 0.0
        self.stats.lazy_group_inits += 1
        return COMM_GROUP_INIT

    def _prepare_stage(self, stage: str, unit_ids: Tuple[int, ...],
                       tau: float) -> float:
        """Adjust-on-Dispatch replica load if the stage is not yet resident."""
        cost = 0.0
        for g in unit_ids:
            u = self.units[g]
            if stage in u.resident:
                continue
            peer = any(self.units[o].uid != g and self.units[o].node == u.node
                       and stage in self.units[o].resident
                       for o in range(len(self.units)))
            t = self.prof.stage_load_time(stage, via_host=not peer)
            cost = max(cost, t)      # loads proceed in parallel across units
            u.resident.add(stage)
            self.stats.adjust_loads += 1
            self.stats.adjust_load_time += t
        return cost

    def _push(self, nbytes: float, src: Tuple[int, ...], dst: Tuple[int, ...],
              pred_finish: float) -> float:
        """Proactive push of inter-stage tensors; returns data-ready time.

        Two-step locality-aware: inter-node to one destination member, then
        intra-node broadcast.  HB overflow falls back to the host path."""
        if set(src) == set(dst):
            return pred_finish
        src_nodes = {self.units[g].node for g in src}
        dst_nodes = {self.units[g].node for g in dst}
        intra = bool(src_nodes & dst_nodes)
        du = self.units[dst[0]]
        if du.hb_staged + nbytes <= CAP_HB:
            du.hb_staged += nbytes           # drained when the stage launches
            t = self.prof.transfer_time(nbytes, intra_node=intra)
            if not intra:
                t += self.prof.transfer_time(nbytes, intra_node=True)  # bcast
            self.stats.device_pushes += 1
        else:
            t = nbytes / HOST_BW + 1e-3      # pinned-host overflow path
            self.stats.host_path_pushes += 1
        self.stats.transfer_time += t
        if self.proactive_push:
            return pred_finish + t           # overlaps successor compute
        return pred_finish + t + DISPATCH_OVERHEAD

    def _reserve(self, unit_ids: Sequence[int], start: float, finish: float):
        fm = self._free_map
        for g in unit_ids:
            u = self.units[g]
            u.free_at = finish
            fm[g] = finish
            u.hb_staged = 0.0
            self._mark_busy(g, finish)

    def push_cross(self, nbytes: float) -> float:
        """Transfer cost of pushing inter-stage tensors to a *foreign*
        engine's units (cross-lane fused stage runs, core/dispatcher.py's
        ``CrossLaneBatcher``): always the two-step inter-node path — lanes
        occupy disjoint chip ranges, so source and destination never share
        a node — with no handoff-buffer staging on the destination (the
        host engine owns that unit's buffer accounting).  Returns the
        added latency; stats are charged to this (the member's) engine,
        mirroring ``_push``."""
        t = (self.prof.transfer_time(nbytes, intra_node=False)
             + self.prof.transfer_time(nbytes, intra_node=True))
        self.stats.device_pushes += 1
        self.stats.transfer_time += t
        if self.proactive_push:
            return t
        return t + DISPATCH_OVERHEAD

    # ----------------------------------------------------------- dispatch plans

    def execute(self, dec: DispatchDecision, tau: float) -> Dict[str, Tuple[float, float]]:
        """Execute one request's stage plans; returns {stage: (start, finish)}.

        Timing honors: unit availability, reinstance, Adjust-on-Dispatch
        loads, proactive push, and merging of co-located consecutive stages.

        Cross-lane fused stages (fleet dynamic batching) override parts of
        the plan via decision attributes set by the batcher:

        * ``dec.xl_efused = (start, fin, native, host_units)`` — Encode ran
          (or will run) as one fused launch on the *host* lane's units;
          this engine only models the activation push from those units to
          its own Diffuse set (``_push`` when the host is this engine,
          ``push_cross`` otherwise) and never touches ``dec.e_units``.
        * ``dec.xl_cdefer`` — Decode is fused downstream: release the
          Diffuse units at D-finish and return without a "C" entry; the
          batcher schedules the fused decode from the recorded D-finish.
        """
        req = dec.request
        prof = self.prof
        k_chips = dec.degree * prof.k_min
        bs = getattr(dec, "batch", 1)   # App. E.1 dynamic batching
        xl_e = getattr(dec, "xl_efused", None)
        xl_cdefer = getattr(dec, "xl_cdefer", False)
        t_d = prof.batched_stage_time(req, "D", k_chips, bs)
        if self._degraded:
            t_d *= self._slow_factor(dec.d_units)

        out: Dict[str, Tuple[float, float]] = {}
        if xl_e is not None:
            e_start, e_fin, native, host_units = xl_e
            merged_ed = False
            out["E"] = (e_start, e_fin)
            nbytes = prof.comm_bytes(req, "ED")
            if native:
                data_ready = self._push(nbytes, host_units, dec.d_units,
                                        e_fin)
            else:
                data_ready = e_fin + self.push_cross(nbytes)
            d_start = max(data_ready,
                          max(self.units[g].free_at for g in dec.d_units))
            d_start += self._reinstance(dec.d_units)
            d_start += self._prepare_stage("D", dec.d_units, tau)
            d_fin = d_start + t_d
            out["D"] = (d_start, d_fin)
        else:
            t_e = prof.batched_stage_time(
                req, "E", max(1, len(dec.e_units)) * prof.k_min, bs)
            if self._degraded and dec.e_units:
                t_e *= self._slow_factor(dec.e_units)
            merged_ed = tuple(dec.e_units) == tuple(dec.d_units)

            # --- E -----------------------------------------------------------
            units = self.units
            e_ready = tau
            for g in dec.e_units:
                t = units[g].free_at
                if t > e_ready:
                    e_ready = t
            e_ready += self._reinstance(dec.e_units)
            e_ready += self._prepare_stage("E", dec.e_units, tau)
            if merged_ed:
                # merging execute: E+D single atomic run (one dispatch overhead)
                d_ready = e_ready
                for g in dec.d_units:
                    t = units[g].free_at
                    if t > d_ready:
                        d_ready = t
                d_ready += self._reinstance(dec.d_units)
                d_ready += self._prepare_stage("D", dec.d_units, tau)
                start = d_ready
                e_fin = start + t_e
                d_fin = e_fin + t_d - DISPATCH_OVERHEAD  # merged: one overhead
                self.stats.merged_runs += 1
                out["E"] = (start, e_fin)
                out["D"] = (e_fin, d_fin)
            else:
                e_fin = e_ready + t_e
                out["E"] = (e_ready, e_fin)
                self._reserve(dec.e_units, e_ready, e_fin)
                data_ready = self._push(prof.comm_bytes(req, "ED"),
                                        dec.e_units, dec.d_units, e_fin)
                d_start = data_ready
                for g in dec.d_units:
                    t = units[g].free_at
                    if t > d_start:
                        d_start = t
                d_start += self._reinstance(dec.d_units)
                d_start += self._prepare_stage("D", dec.d_units, tau)
                d_fin = d_start + t_d
                out["D"] = (d_start, d_fin)

        # --- C ---------------------------------------------------------------
        if xl_cdefer:
            # fused decode downstream: hold the Diffuse units through D only
            self._reserve(dec.d_units,
                          out["E"][0] if merged_ed else out["D"][0], d_fin)
            self.stats.dispatches += 1 if xl_e is not None else 2
            return out

        t_c = prof.batched_stage_time(req, "C",
                                      max(1, len(dec.c_units)) * prof.k_min, bs)
        if self._degraded and dec.c_units:
            t_c *= self._slow_factor(dec.c_units)
        merged_dc = (dec.c_units == dec.d_units
                     or set(dec.c_units) <= set(dec.d_units))
        if merged_dc:
            c_start = d_fin
            c_fin = c_start + t_c - DISPATCH_OVERHEAD
            self.stats.merged_runs += 1
            self._prepare_stage("C", dec.c_units, tau)
            out["C"] = (c_start, c_fin)
            self._reserve(dec.d_units, out["E"][0] if merged_ed else out["D"][0], c_fin)
            extra = set(dec.c_units) - set(dec.d_units)
            if extra:
                self._reserve(tuple(extra), c_start, c_fin)
        else:
            self._reserve(dec.d_units, out["D"][0], d_fin)
            data_ready = self._push(prof.comm_bytes(req, "DC"),
                                    dec.d_units, dec.c_units, d_fin)
            units = self.units
            c_start = data_ready
            for g in dec.c_units:
                t = units[g].free_at
                if t > c_start:
                    c_start = t
            c_start += self._reinstance(dec.c_units)
            c_start += self._prepare_stage("C", dec.c_units, tau)
            c_fin = c_start + t_c
            out["C"] = (c_start, c_fin)
            self._reserve(dec.c_units, c_start, c_fin)

        self.stats.dispatches += 2 if xl_e is not None else 3
        return out
