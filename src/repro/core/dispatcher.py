"""Resource-Aware Dispatcher (§6.2): the two-step dispatch-plan generator.

Step 1 — solve the per-tick myopic ILP for Γ^D (OBJ, C0–C4) with the
paper's Appendix-C.2 weights: completion reward W_r (SLO-aware, with aging
past the starvation threshold α), communication penalty Q_{r,i} = β_i · l_r.

Step 2 — derive Γ^E and Γ^C from Γ^D: reuse the primary's unit set when the
stage co-resides (E merges with D; C takes a subset of D's units), otherwise
route to an idle/earliest-free auxiliary replica at the profiled optimal
parallelism.

``CrossLaneBatcher`` extends the dispatch step one level up (fleet-level
dynamic batching, ``FleetConfig.cross_lane_batching``): when the fleet's
per-lane dispatchers produce auxiliary E/C stage runs in two or more lanes
whose units share a ``(placement_type, stage)`` shape, the batcher merges
them into ONE batched launch on a single host lane's units — StreamDiffusion
Stream-Batch-style batching across logically independent requests, across
pipelines.  Member selection is a grouped ILP with multi-dimensional
columns (``ilp.solve_grouped``), capped by the profiler's measured
batch-latency curve; the fused run is charged as one merged completion
event (``clock.MERGED_LANE``) whose members span lanes.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import ilp
from repro.core.placement import (PRIMARY_PLACEMENTS, PlacementPlan,
                                  primary_of_vr)
from repro.core.profiler import COMM_GROUP_INIT, PARALLEL_DEGREES, Profiler
from repro.core.request import DispatchPlan, Request

# Appendix C.2 constants
C_ON = 1000.0
C_LATE = 200.0
ALPHA_STARVE = 5.0
BETAS = {0: 0.0, 1: 1e-6, 2: 5e-6, 3: 6e-6}   # per Virtual-Replica index
EFF_THRESHOLD = 0.8                            # E_{r,k} filter
# Runtime-preference weight: among on-time (i,k) choices the paper's OBJ is
# indifferent, which lets the solver park requests at degree 1 and inflate
# mean latency.  A small per-second penalty (<< C_on - C_late) breaks the
# tie toward faster configs without ever flipping an SLO decision.
GAMMA_TIME = 2.0
# Cross-pipeline unit lending: reward discount on options whose auxiliary
# stage would land on a borrowed foreign unit (kept well below C_LATE so a
# borrow never outbids a native on-time config, but still biases the solver
# toward native capacity when both are idle).
BORROW_PENALTY = 25.0


@dataclasses.dataclass
class DispatchDecision:
    request: Request
    vr_type: int                  # chosen Virtual Replica index (0..3)
    degree: int                   # units for the D stage
    d_units: Tuple[int, ...]
    e_units: Tuple[int, ...]
    c_units: Tuple[int, ...]
    # App. E.1 dynamic batching: same-class requests served in this run
    corequests: Tuple[Request, ...] = ()

    @property
    def batch(self) -> int:
        return 1 + len(self.corequests)

    def plans(self) -> Dict[str, DispatchPlan]:
        r = self.request
        return {
            "E": DispatchPlan(r.rid, "E", self.e_units, max(1, len(self.e_units))),
            "D": DispatchPlan(r.rid, "D", self.d_units, self.degree),
            "C": DispatchPlan(r.rid, "C", self.c_units, max(1, len(self.c_units))),
        }


class Dispatcher:
    def __init__(self, profiler: Profiler, max_batch: int = 64,
                 solver_time_cap: float = 0.05, aggregate: bool = False,
                 incremental: bool = False):
        """``aggregate`` turns on multiplicity-aware ILP aggregation:
        pending requests with identical option lists (same class, same
        reward state) enter the solver once with a count instead of N
        times, so dense same-class floods build capacity-bounded instances
        (see ``ilp.solve_grouped``).  Off by default so the single-pipeline
        dispatch path is bit-identical to its pre-aggregation behavior; the
        fleet layer (core/fleet.py) turns it on.

        ``incremental`` persists the dispatch model across wake-ups: when
        the option matrix and budgets are unchanged since the previous
        ``dispatch`` call (pure-completion wake-ups, heartbeat re-checks
        with a frozen pending set), the previous solution is reused without
        re-solving.  Reuse is exact whenever the previous solve proved
        optimality (the branch-and-bound only replaces an incumbent on
        *strictly* better reward, so a warm re-solve of the identical
        instance returns the identical choices); under a node-capped solve
        it may pin an improvable incumbent, which is why the flag defaults
        off and the committed BENCH trajectories never see it
        (docs/architecture.md: incremental-solve contract)."""
        self.prof = profiler
        self.max_batch = max_batch
        self.solver_time_cap = solver_time_cap
        self.aggregate = aggregate
        self.incremental = incremental
        self.last_solve_stats: Dict[str, float] = {}
        # previous solve's surviving (dim, usage) per request id — warm-starts
        # the ILP incumbent under steady load (requests pending across ticks)
        self._warm: Dict[int, Tuple[int, int]] = {}
        # per-class feasibility cache: (req.key(), cond_len) -> the budget-
        # independent (runtime, vr, k) triples of build_options' nested scan,
        # in scan order.  Pure memoization of profiler-table functions of the
        # request class — byte-identical to the uncached scan, so it is
        # always on (unlike the flag-gated solve reuse above).
        self._feas: Dict[tuple, Tuple[Tuple[float, int, int], ...]] = {}
        # persisted model for the flag-gated cross-tick solve reuse
        self._sig: Optional[tuple] = None
        self._sig_choices: Optional[Dict[int, ilp.Option]] = None
        self._sig_stats: Optional[Dict[str, float]] = None
        self.solve_reuses = 0    # solves skipped via the persisted model

    # -- reward / penalty (App. C.2) ----------------------------------------

    def _w_r(self, req: Request, tau: float, best_finish: float) -> float:
        """App. C.2 completion reward with aging.  The overtime factor is
        measured in *relative* time (how many deadline-windows the request
        is overdue) so escalation is bounded and gradual: a request must be
        α=5 windows late before its C_late reward starts growing — fresh
        on-time requests (C_on) always dominate until then."""
        if best_finish <= req.deadline:
            return C_ON
        window = max(req.deadline - req.arrival, 1e-6)
        scale = max(1.0, (best_finish - req.arrival) / window)
        return C_LATE * max(1.0, scale - ALPHA_STARVE + 1.0)

    def _q_ri(self, req: Request, vr: int) -> float:
        l_r = self.prof.proc_len(req, "D")
        return BETAS[vr] * l_r * C_ON  # scaled to stay orders below W_r

    def _req_runtime(self, req: Request, vr: int, k_units: int) -> float:
        """t_{r,i,k}: runtime of the stages hosted by primary type i at k."""
        prim = primary_of_vr(vr)
        k_chips = k_units * self.prof.k_min
        t = self.prof.stage_time(req, "D", k_chips)
        if "E" in prim:
            t += self.prof.stage_time(req, "E", k_chips)
        if "C" in prim:
            kc = min(k_chips, self.prof.optimal_degree(req, "C") * self.prof.k_min)
            t += self.prof.stage_time(req, "C", kc)
        return t

    # -- ILP construction ------------------------------------------------------

    # auxiliary stages each Virtual Replica routes off-primary (Table 3)
    _VR_AUX = {0: (), 1: ("E",), 2: ("C",), 3: ("E", "C")}

    def _feas_configs(self, req: Request) -> Tuple[Tuple[float, int, int], ...]:
        """Budget-independent feasible (runtime, vr, k) triples for one
        request class, in ``build_options``' scan order (vr outer 0..3, k
        inner over the efficient degrees).  Budgets — the only tau- or
        state-dependent input of the scan — are filtered at use time, so
        the cached triples reproduce the uncached loop bit-for-bit."""
        key = (req.key(), req.cond_len)
        cached = self._feas.get(key)
        if cached is None:
            # E_{r,k}: efficient degrees only (plus degree 1, always
            # allowed); capped at one node's worth of units (intra-machine
            # SP)
            eff_ks = [k for k in PARALLEL_DEGREES
                      if k <= self.prof.max_degree_units
                      and (k == 1 or self.prof.efficiency(
                          req, "D", k * self.prof.k_min) > EFF_THRESHOLD)]
            cached = tuple(
                (self._req_runtime(req, vr, k), vr, k)
                for vr in range(4)
                for k in eff_ks
                if self.prof.fits(req, primary_of_vr(vr), k))   # F_{r,i,k}
            self._feas[key] = cached
        return cached

    def build_options(self, reqs: Sequence[Request], tau: float,
                      idle_by_type: Dict[str, int],
                      aux_penalty: Optional[Dict[str, float]] = None
                      ) -> Tuple[List[List[ilp.Option]], List[int]]:
        budgets = [idle_by_type.get(primary_of_vr(v), 0) for v in range(4)]
        vr_pen = [0.0] * 4
        if aux_penalty:
            # lending: a VR whose auxiliary stage would land on a borrowed
            # foreign unit carries the borrow discount (extra columns the
            # solver may still take when native capacity is the binding
            # constraint)
            vr_pen = [sum(aux_penalty.get(s, 0.0) for s in self._VR_AUX[v])
                      for v in range(4)]
        options: List[List[ilp.Option]] = []
        # per-call class cache: budgets, tau, and vr_pen are fixed for the
        # whole call, so the budget-filtered triples, the best/worst
        # predicted finishes, and — for requests every config beats the
        # deadline of — the complete option list are functions of the
        # request *class* alone.  Same-class floods (the common fleet-scale
        # wave) then build their options once instead of once per request;
        # cached option lists are shared (ilp.Option is frozen and no
        # downstream consumer mutates an option list).
        cache: Dict[tuple, list] = {}
        for req in reqs:
            ckey = (req.key(), req.cond_len)
            ent = cache.get(ckey)
            if ent is None:
                # the class feasibility cache holds the budget-independent
                # triples; the budget filter reproduces the original nested
                # scan's order
                filt = [t for t in self._feas_configs(req)
                        if budgets[t[1]] > 0 and t[2] <= budgets[t[1]]]
                best_finish = max_f = None
                for rt, vr, k in filt:
                    f = tau + rt
                    if best_finish is None or f < best_finish:
                        best_finish = f
                    if max_f is None or f > max_f:
                        max_f = f
                ent = cache[ckey] = [filt, best_finish, max_f, None]
            filt, best_finish, max_f = ent[0], ent[1], ent[2]
            if best_finish is None:
                options.append([])
                continue
            deadline = req.deadline
            if max_f <= deadline:
                # every config makes the deadline: W_r = C_on and no option
                # is filtered, so the list is deadline-independent — reuse
                # the class's cached on-time list
                opts = ent[3]
                if opts is None:
                    base: List[Optional[float]] = [None] * 4
                    opts = []
                    for rt, vr, k in filt:
                        f = tau + rt
                        b = base[vr]
                        if b is None:
                            b = base[vr] = (C_ON - self._q_ri(req, vr)
                                            - vr_pen[vr])
                        opts.append(ilp.Option(
                            dim=vr, usage=k,
                            reward=b - GAMMA_TIME * (f - tau)))
                    ent[3] = opts
                options.append(opts)
                continue
            w = self._w_r(req, tau, best_finish)
            # per-VR reward base hoisted out of the option loop; the final
            # subtraction keeps the original left-to-right association so
            # rewards stay bit-identical
            base = [None] * 4
            opts = []
            for rt, vr, k in filt:
                f = tau + rt
                # C3a-guided: drop configs that blow the deadline unless
                # nothing makes it (then keep the fastest)
                if f <= deadline or f == best_finish:
                    b = base[vr]
                    if b is None:
                        b = base[vr] = w - self._q_ri(req, vr) - vr_pen[vr]
                    opts.append(ilp.Option(
                        dim=vr, usage=k,
                        reward=b - GAMMA_TIME * (f - tau)))
            options.append(opts)
        return options, budgets

    def _solve_grouped(self, reqs: Sequence[Request],
                       options: List[List[ilp.Option]], budgets: List[int]
                       ) -> Tuple[Dict[int, ilp.Option], Dict[str, float]]:
        """Multiplicity-aware solve: requests with identical option lists
        form one group with a count.  Granted copies map back to the
        group's members in deadline order (``reqs`` is deadline-sorted),
        best-reward option first, so the earliest-deadline member gets the
        fastest grant."""
        groups: Dict[Tuple[ilp.Option, ...], int] = {}
        members: List[List[int]] = []
        gopts: List[List[ilp.Option]] = []
        for ri, opts in enumerate(options):
            if not opts:
                continue
            key = tuple(opts)
            g = groups.get(key)
            if g is None:
                g = groups[key] = len(gopts)
                gopts.append(opts)
                members.append([])
            members[g].append(ri)
        warm: Dict[int, List[Tuple[int, int]]] = {}
        for g, mem in enumerate(members):
            seeds = [self._warm[reqs[ri].rid] for ri in mem
                     if reqs[ri].rid in self._warm]
            if seeds:
                warm[g] = seeds
        gsol = ilp.solve_grouped(gopts, budgets,
                                 [len(mem) for mem in members],
                                 time_cap=self.solver_time_cap, warm=warm,
                                 dp=self.incremental)
        choices: Dict[int, ilp.Option] = {}
        for g, granted in gsol.alloc.items():
            for ri, opt in zip(members[g], granted):
                choices[ri] = opt
        return choices, {"nodes": gsol.nodes, "optimal": gsol.optimal,
                         "reward": gsol.reward, "n_solved": gsol.n_slots,
                         "n_groups": len(gopts)}

    # -- unit selection ---------------------------------------------------------

    @staticmethod
    def select_units(plan: PlacementPlan, ptype: str, k: int,
                     idle_units: set, cross_node: bool = False
                     ) -> Optional[Tuple[int, ...]]:
        """k idle units of placement ``ptype`` within one node (intra-machine
        constraint §6.2); contiguous-first for ICI locality.  With
        ``cross_node`` (TPU pods: ICI everywhere) adjacent nodes combine
        when no single node suffices."""
        upn = plan.units_per_node
        by_node: Dict[int, List[int]] = {}
        for g in plan.units_of_type(ptype):
            if g in idle_units:
                by_node.setdefault(g // upn, []).append(g)
        # node id as total tie-break: insertion is already ascending-node
        # (units_of_type walks unit ids), so this is byte-neutral but makes
        # the equal-count order explicit rather than stability-dependent
        for node, units in sorted(by_node.items(),
                                  key=lambda kv: (-len(kv[1]), kv[0])):
            if len(units) >= k:
                return tuple(sorted(units)[:k])
        if cross_node:
            pool: List[int] = []
            for node in sorted(by_node):
                pool.extend(sorted(by_node[node]))
            if len(pool) >= k:
                return tuple(pool[:k])
        return None

    def _aux_units(self, plan: PlacementPlan, stage: str, k: int,
                   idle_units: set, free_at: Dict[int, float], tau: float,
                   borrowed: Optional[set] = None,
                   exclude: Optional[Dict[int, float]] = None
                   ) -> Tuple[int, ...]:
        """Idle-or-earliest-free auxiliary units for E/C (Monitor-reported).

        With active loans (``borrowed``), native units win ties: a borrowed
        foreign unit is only taken when it is strictly the better host
        (idle while every native auxiliary is busy, or earlier-free).
        ``exclude`` steers auxiliary work off draining units (doomed by a
        preemption notice, core/elastic.py) — but only while a healthy
        candidate exists: a lane whose sole auxiliary sits on a doomed
        node keeps serving through it (short aux runs mostly beat the
        land, and stragglers are requeued there anyway)."""
        cands = plan.units_of_type(stage)
        if exclude:
            healthy = [g for g in cands if g not in exclude]
            if healthy:
                cands = healthy
        if not cands:
            return ()
        # nsmallest == sorted(...)[:k] (stable, documented), at O(n) instead
        # of O(n log n) — k is a profiled optimal degree, i.e. tiny, while
        # the candidate list is every auxiliary unit of the stage type
        if borrowed:
            cands = heapq.nsmallest(k, cands,
                                    key=lambda g: (g not in idle_units,
                                                   free_at.get(g, tau),
                                                   g in borrowed))
        else:
            cands = heapq.nsmallest(k, cands,
                                    key=lambda g: (g not in idle_units,
                                                   free_at.get(g, tau)))
        return tuple(cands)

    # -- main entry ---------------------------------------------------------------

    def dispatch(self, pending: Sequence[Request], plan: PlacementPlan,
                 idle_units: set, free_at: Dict[int, float], tau: float,
                 borrowed: Optional[Dict[str, Tuple[int, ...]]] = None,
                 draining: Optional[Dict[int, float]] = None
                 ) -> List[DispatchDecision]:
        """One dispatch round over the pending set.

        ``idle_units`` and ``free_at`` are the engine's *live* views
        (``ServingEngine.idle_units`` / ``free_at``): never mutated here —
        grants consume from a private ``avail`` copy — and only valid
        until the caller applies the returned decisions to the engine.

        ``draining`` maps doomed unit ids to their loss time (a preemption
        notice is live, core/elastic.py): a draining unit may still host a
        primary launch that *finishes before its land time* — short work
        keeps flowing through the doomed capacity for the rest of the
        notice window — but never a launch that would straddle the loss
        (that work would be requeued at the land and re-run from scratch).
        Auxiliary stages avoid draining units entirely.  ``None`` (the
        default, and always when elasticity is off) takes the pooled
        fast path byte-for-byte unchanged.
        """
        # candidate set scales with idle capacity: a fixed cap would only
        # ever show the solver the oldest (often already-late) requests
        # under high-churn workloads and starve fresh feasible ones
        cap = max(self.max_batch, 2 * len(idle_units))
        reqs = sorted(pending, key=lambda r: r.deadline)[:cap]
        if not reqs:
            return []
        # C-speed set intersection == counting units_of_type members in the
        # idle set (same active view); the genexpr walked every unit of
        # every primary type per dispatch round.  A draining unit counts
        # toward its type's budget only while its remaining window can
        # still host the *shortest* candidate launch of that type: promise
        # more and the solver grants work that unit selection then has to
        # refuse (burning the round's throughput — the metastable-collapse
        # shape); promise less and doomed capacity sits idle for work that
        # could legally land before the loss.
        budget_idle = idle_units
        if draining:
            min_rt: Dict[str, float] = {}
            seen_cls = set()
            for req in reqs:
                ck = (req.key(), req.cond_len)
                if ck in seen_cls:
                    continue
                seen_cls.add(ck)
                for rt, vr, _k in self._feas_configs(req):
                    t = primary_of_vr(vr)
                    if t not in min_rt or rt < min_rt[t]:
                        min_rt[t] = rt
            inf = float("inf")
            budget_idle = idle_units - {
                g for g, land in draining.items()
                if land - tau < min_rt.get(plan.placements[g], inf)}
        idle_by_type = {t: len(budget_idle & plan.type_set(t))
                        for t in PRIMARY_PLACEMENTS}
        # cross-pipeline unit lending (core/lending.py): borrowed foreign
        # units appear as E/C-only candidates.  An option whose auxiliary
        # stage would land on one (no idle native auxiliary of that type)
        # carries the borrow discount.
        borrowed_all: set = set()
        aux_penalty: Optional[Dict[str, float]] = None
        if borrowed:
            borrowed_all = {g for gs in borrowed.values() for g in gs}
            aux_penalty = {}
            for s in ("E", "C"):
                native_idle = any(g in idle_units and g not in borrowed_all
                                  for g in plan.units_of_type(s))
                lent_idle = any(free_at.get(g, 0.0) <= tau
                                for g in borrowed.get(s, ()))
                if lent_idle and not native_idle:
                    aux_penalty[s] = BORROW_PENALTY
        options, budgets = self.build_options(reqs, tau, idle_by_type,
                                              aux_penalty)
        # incremental re-solve: the solver only ever sees (options, budgets)
        # — the request identities, tau, and unit ids are outside the model —
        # so an unchanged signature means the previous solution is a valid
        # solution of this instance (and the optimum, when the previous
        # solve proved optimality).  Pure-completion wake-ups, where freed
        # units are auxiliary and the pending head is frozen, hit this path.
        sig = ((tuple(budgets), tuple(tuple(o) for o in options))
               if self.incremental else None)
        if (sig is not None and sig == self._sig
                and self._sig_choices is not None):
            choices = self._sig_choices
            stats = {**self._sig_stats, "nodes": 0, "reused": True}
            self.solve_reuses += 1
        elif self.aggregate:
            choices, stats = self._solve_grouped(reqs, options, budgets)
        else:
            warm = {ri: self._warm[req.rid] for ri, req in enumerate(reqs)
                    if req.rid in self._warm}
            sol = ilp.solve(options, budgets, time_cap=self.solver_time_cap,
                            warm=warm, dp=self.incremental)
            choices = sol.choices
            stats = {"nodes": sol.nodes, "optimal": sol.optimal,
                     "reward": sol.reward, "n_solved": len(reqs)}
        if sig is not None and not stats.get("reused"):
            self._sig = sig
            self._sig_choices = choices
            self._sig_stats = dict(stats)
        self._warm = {reqs[ri].rid: (opt.dim, opt.usage)
                      for ri, opt in choices.items()}
        self.last_solve_stats = {**stats, "n_reqs": len(reqs)}

        decisions: List[DispatchDecision] = []
        avail = set(idle_units)
        # Maintained unit pools: ``select_units`` rebuilds its by-node map by
        # walking *every* unit of the placement type on each grant — O(units)
        # per grant, the dominant dispatch cost on multi-thousand-chip plans.
        # Placement types partition the unit space and only primary grants
        # consume from ``avail``, so each type's by-node map can be built
        # once per dispatch round (lazily, from the then-current ``avail``)
        # and maintained across grants.  Selection is byte-identical to
        # ``select_units``: lists are kept ascending, the node scan walks
        # ascending node ids taking the first strict count maximum (== the
        # first len>=k entry of the (-count, node)-sorted order), and the
        # cross-node pool concatenates ascending nodes.
        upn = plan.units_per_node
        pools: Dict[str, Dict[int, List[int]]] = {}
        # lazy max-heap per type over (-count, node): the top valid entry is
        # the max-count node with the smallest node id — exactly the winner
        # of the ascending strict-max scan — without an O(nodes) walk per
        # grant.  Entries go stale when a node's count changes; they are
        # popped (never trusted) once the stored count mismatches.
        heaps: Dict[str, List[Tuple[int, int]]] = {}

        def _pool(ptype: str) -> Dict[int, List[int]]:
            by_node = pools.get(ptype)
            if by_node is None:
                by_node = pools[ptype] = {}
                for g in plan.units_of_type(ptype):
                    if g in avail:
                        by_node.setdefault(g // upn, []).append(g)
                h = heaps[ptype] = [(-len(u), nd) for nd, u in by_node.items()]
                heapq.heapify(h)
            return by_node

        def _take(ptype: str, k: int) -> Optional[Tuple[int, ...]]:
            by_node = _pool(ptype)
            heap = heaps[ptype]
            best, best_n = None, 0
            while heap:
                neg, node = heap[0]
                if -neg == len(by_node[node]):
                    best, best_n = node, -neg
                    break
                heapq.heappop(heap)   # stale count
            if best_n >= k:
                units = by_node[best]
                out = tuple(units[:k])
                del units[:k]
                heapq.heapreplace(heap, (k - best_n, best))
                return out
            if self.prof.cross_node_sp:
                pool = [g for node in sorted(by_node) for g in by_node[node]]
                if len(pool) >= k:
                    out = tuple(pool[:k])
                    taken = set(out)
                    for node, units in by_node.items():
                        units[:] = [g for g in units if g not in taken]
                        heapq.heappush(heap, (-len(units), node))
                    return out
            return None

        def _give_back(ptype: str, units: Tuple[int, ...]) -> None:
            by_node = _pool(ptype)
            heap = heaps[ptype]
            for g in units:
                node = g // upn
                bisect.insort(by_node[node], g)
                heapq.heappush(heap, (-len(by_node[node]), node))

        for ri, opt in sorted(choices.items(), key=lambda kv: -kv[1].reward):  # detlint: ignore[DET004] choices is solver-walk-ordered; equal-reward order is BENCH-byte-frozen
            req = reqs[ri]
            prim = primary_of_vr(opt.dim)
            if draining:
                # stage-aware drain: a doomed unit is eligible only when
                # this launch lands before the unit does.  Slow legacy
                # selection (no pools) — active only inside a notice
                # window on an elastic fleet.
                rt = self._req_runtime(req, opt.dim, opt.usage)
                elig = {g for g in avail
                        if g not in draining or tau + rt <= draining[g]}
                units = self.select_units(plan, prim, opt.usage, elig,
                                          self.prof.cross_node_sp)
            else:
                units = _take(prim, opt.usage)
            if units is None:
                continue   # stay undispatched for next round (paper §6.2)
            avail -= set(units)
            # Γ^E: merge with D when co-resident, else aux ⟨E⟩ replicas
            if "E" in prim:
                e_units = units
            else:
                ke = self.prof.optimal_degree(req, "E")
                e_units = self._aux_units(plan, "E", ke, avail, free_at, tau,
                                          borrowed_all or None,
                                          exclude=draining)
            # Γ^C: subset of D's units when co-resident, else aux ⟨C⟩
            kc = self.prof.optimal_degree(req, "C")
            if "C" in prim:
                c_units = units[: max(1, min(kc, len(units)))]
            else:
                c_units = self._aux_units(plan, "C", kc, avail, free_at, tau,
                                          borrowed_all or None,
                                          exclude=draining)
            if not e_units or not c_units:
                avail |= set(units)
                if not draining:
                    _give_back(prim, units)
                continue   # no auxiliary capacity -> undispatched this tick
            decisions.append(DispatchDecision(
                request=req, vr_type=opt.dim, degree=opt.usage,
                d_units=units, e_units=tuple(e_units), c_units=tuple(c_units)))
        if borrowed:
            self._offload_decode(decisions, pending, borrowed, free_at, tau)
        return decisions

    def _offload_decode(self, decisions: List[DispatchDecision],
                        pending: Sequence[Request],
                        borrowed: Dict[str, Tuple[int, ...]],
                        free_at: Dict[int, float], tau: float) -> None:
        """Work-conserving decode offload onto borrowed foreign units.

        When requests are still left waiting after this round's grants, a
        decision whose primary co-hosts C (⟨EDC⟩/⟨DC⟩ — the common all-V0
        plan) hands its Decode to an idle borrowed ⟨C⟩ unit instead of
        merging it: the primary frees t_C earlier, which is exactly the
        stranded capacity lending is meant to recover.  D never moves — the
        borrower's diffuse placement is untouched by construction."""
        pool = [g for g in borrowed.get("C", ())
                if free_at.get(g, 0.0) <= tau]
        if not pool:
            return
        granted = sum(d.batch for d in decisions)
        if len(pending) <= granted:
            return   # no backlog: merged execution stays strictly better
        # offload the heaviest decodes first — they strand the most time
        order = sorted(
            (d for d in decisions
             if "C" in primary_of_vr(d.vr_type)
             and set(d.c_units) <= set(d.d_units)),
            key=lambda d: -self.prof.stage_time(
                d.request, "C", len(d.c_units) * self.prof.k_min))
        for dec in order:
            if not pool:
                return
            req = dec.request
            kc = min(self.prof.optimal_degree(req, "C"), len(dec.c_units))
            take = pool[:max(1, min(kc, len(pool)))]
            if not self.prof.fits(req, "C", len(take)):
                continue
            # degree- and deadline-aware: a thinner pool slows this
            # request's own decode, and even at the merged degree the
            # offload pays the inter-node latent push (plus a possible
            # comm-group init) that merged execution avoids — only degrade
            # when the request still makes its SLO, or misses it either way
            k = self.prof.k_min
            t_merged = self.prof.stage_time(req, "C", kc * k)
            t_off = self.prof.stage_time(req, "C", len(take) * k)
            q_dc = self.prof.comm_bytes(req, "DC")
            t_push = (self.prof.transfer_time(q_dc, intra_node=False)
                      + self.prof.transfer_time(q_dc, intra_node=True)
                      + COMM_GROUP_INIT)
            runtime = self._req_runtime(req, dec.vr_type, dec.degree)
            # start when the granted primary units actually free up, not
            # at tau — a queueing-blind estimate would bless offloads that
            # push the real finish past the deadline
            start = max([tau] + [free_at.get(g, tau) for g in dec.d_units])
            fin_merged = start + runtime
            fin_off = fin_merged - t_merged + t_off + t_push
            if fin_off > req.deadline and fin_merged <= req.deadline:
                continue
            dec.c_units = tuple(take)
            del pool[:len(take)]


class CrossLaneBatcher:
    """Fleet-level cross-lane dynamic batching (``FleetConfig.cross_lane_batching``).

    After every lane's dispatcher has produced its tick decisions (but
    before any engine executes them), the batcher scans the fleet-wide
    decision set for auxiliary E/C stage runs whose units share a
    ``(stage, placement_type, unit_size)`` shape across two or more lanes,
    and fuses each such group into ONE batched launch on a single *host*
    lane's auxiliary units:

    * **Member selection** is the ILP's multiplicity-aware aggregation with
      the grouping key extended across lanes: each candidate run becomes a
      grouped column with a *multi-dimensional* ``ilp.Option`` spanning the
      shared batch-capacity dimension and its own lane's dimension
      (``dim=(0, lane)``, ``usage=(b, b)``), so one ``solve_grouped`` call
      packs the launch under both the fleet-wide batch cap and each lane's
      own batch-curve cap.  Rewards are the native solo stage times the
      fusion releases.
    * **Batch cap** comes from the profiler's measured batch-latency curve
      (``Profiler.optimal_batch``) unless ``max_batch`` overrides it.
    * **Duration** charged is the *batched* stage time at the combined
      batch size — conservatively the max over the member lanes' profiles —
      on the host units only; every other member lane's native auxiliary
      selection goes unused (that is the capacity the fusion pools).
    * **Completion** is one merged event under the ``clock.MERGED_LANE``
      sentinel whose members span lanes; per-lane results are un-merged by
      the fleet driver's drain loop (one ``on_completion`` per
      participating lane, per-member finish accounting).

    E-groups launch at plan time (E has no intra-tick dependency); C-groups
    are deferred via ``dec.xl_cdefer`` and scheduled in :meth:`finalize`
    once every lane's engine has executed and stamped ``stage_done["D"]``.

    Only constructed when the fleet knob is on — the off path never sees
    this class, keeping it bit-identical by construction.
    """

    def __init__(self, max_batch: int = 0, solver_time_cap: float = 0.05,
                 incremental: bool = False):
        self.max_batch = max_batch          # 0 = profiler batch-curve cap
        self.solver_time_cap = solver_time_cap
        self.incremental = incremental
        self.merges = 0                     # fused launches charged
        self.merged_requests = 0            # batch items across all fusions
        self.warm_solves = 0                # selects seeded from prior grants
        # previous grants per shape key: (stage, ptype, unit_size) ->
        # {gkey: [(dim, usage), ...]} — warm incumbents for the next select
        # of the same shape group.  Flag-gated like Dispatcher.incremental:
        # warm seeding changes which of several equally-optimal member sets
        # the DFS lands on, so the off path stays bit-identical.
        self._warm_grants: Dict[tuple, Dict[tuple, list]] = {}
        # host units of un-drained fused launches: (host pid, unit) ->
        # latest fused finish.  The lending broker consults ``fused_busy``
        # before force-returning a borrowed host unit (a fused launch in
        # flight pins the loan); entries are pruned lazily.
        self.inflight_hosts: Dict[Tuple[str, int], float] = {}
        # set by the fleet driver when a FaultInjector is live: merged
        # events then carry their host (pipeline, unit) pairs so fault
        # revocation can match them (core/elastic.py)
        self.track_units: bool = False

    # -- candidate assembly ---------------------------------------------------

    @staticmethod
    def _units(dec: DispatchDecision, stage: str) -> Tuple[int, ...]:
        return dec.e_units if stage == "E" else dec.c_units

    def _collect(self, lane_decs) -> Dict[tuple, list]:
        """Group fusable (lane, dec, stage) candidates by shape key.

        The key is ``(stage, placement_type, unit_size)`` — the contract the
        merged launch relies on: same stage weights resident, same replica
        shape, same per-unit chip count.  Same placement_type but different
        stage deliberately yields distinct keys (a ⟨C⟩-typed unit hosting a
        warm E replica must not merge with a C run)."""
        groups: Dict[tuple, list] = {}
        for lane, decs in lane_decs:
            plan = lane.engine.plan
            for dec in decs:
                for stage in getattr(dec, "xl_candidate", ()):
                    units = self._units(dec, stage)
                    if not units:
                        continue
                    key = (stage, plan.placements[units[0]], plan.unit_size)
                    groups.setdefault(key, []).append((lane, dec))
        return groups

    # -- member selection (grouped ILP, cross-lane columns) -------------------

    def _select(self, stage: str, per_lane: Dict[str, list], tau: float,
                skey: tuple = ()):
        """Pick the fused member set for one shape group.

        ``skey`` is the shape key the group was collected under — the
        stable identity the flag-gated warm store is keyed by across ticks.
        Returns ``(fused, host_lane, host_units, n_total, T)`` or ``None``
        when no fusion spanning >= 2 lanes fits under the caps."""
        # host = lane whose leading candidate's aux units free up earliest
        # (its units carry the fused launch); deterministic pipeline tiebreak
        host_pid = min(
            sorted(per_lane),
            key=lambda pid: (max(per_lane[pid][0][0].engine.units[g].free_at
                                 for g in self._units(per_lane[pid][0][1], stage)),
                             pid))
        host, anchor = per_lane[host_pid][0]
        host_units = self._units(anchor, stage)
        k_chips = len(host_units) * host.prof.k_min
        # per-lane batch caps from each profile's measured batch curve, at
        # the HOST launch shape (that is where the fused run executes); a
        # positive max_batch override replaces BOTH the shared and the
        # per-lane curve caps (the operator is asserting a throughput/
        # latency trade the 1.2x-single curve knee would refuse)
        cap_of = {}
        for pid, cands in per_lane.items():
            rep = min(cands, key=lambda c: (c[1].request.deadline,
                                            c[1].request.rid))[1].request
            cap_of[pid] = (self.max_batch if self.max_batch > 0
                           else cands[0][0].prof.optimal_batch(rep, stage,
                                                               k_chips))
        shared_cap = (self.max_batch if self.max_batch > 0
                      else max(cap_of[p] for p in sorted(cap_of)))
        b_anchor = anchor.batch
        if shared_cap - b_anchor < 1:
            return None            # no room to span a second lane
        # grouped ILP: dim 0 = shared fleet batch budget, dims 1..L = lanes
        lane_dim = {pid: i + 1 for i, pid in enumerate(per_lane)}
        budgets = [shared_cap - b_anchor] + [
            max(0, cap_of[pid] - (b_anchor if pid == host_pid else 0))
            for pid in per_lane]
        gindex: Dict[tuple, int] = {}
        gopts: List[List[ilp.Option]] = []
        counts: List[int] = []
        gmembers: List[list] = []
        for pid, cands in per_lane.items():
            for lane, dec in cands:
                if dec is anchor:
                    continue
                b = dec.batch
                units = self._units(dec, stage)
                # reward: native solo auxiliary time this member releases
                saving = lane.prof.batched_stage_time(
                    dec.request, stage, len(units) * lane.prof.k_min, b)
                gkey = (lane_dim[pid], b, saving)
                g = gindex.get(gkey)
                if g is None:
                    g = gindex[gkey] = len(gopts)
                    gopts.append([ilp.Option(dim=(0, lane_dim[pid]),
                                             usage=(b, b), reward=saving)])
                    counts.append(0)
                    gmembers.append([])
                counts[g] += 1
                gmembers[g].append((lane, dec))
        if not gopts:
            return None
        # cross-tick warm incumbents (flag-gated): re-seed each surviving
        # group's grants from the previous select of this shape group, so
        # the branch-and-bound starts at last tick's member set under a
        # steady burst instead of rediscovering it from the greedy incumbent
        warm = None
        if self.incremental:
            prev = self._warm_grants.get(skey, {})
            warm = {g: prev[gk] for gk, g in gindex.items()
                    if gk in prev} or None
            if warm:
                self.warm_solves += 1
        sol = ilp.solve_grouped(gopts, budgets, counts,
                                time_cap=self.solver_time_cap, warm=warm,
                                dp=self.incremental)
        if self.incremental:
            self._warm_grants[skey] = {
                gk: [(o.dim, o.usage) for o in sol.alloc[g]]
                for gk, g in gindex.items() if g in sol.alloc}
        fused = [(host, anchor)]
        for g in sorted(sol.alloc):
            grants = sol.alloc[g]
            # deadline-ordered un-merging: earliest-deadline members of the
            # class take the granted slots
            ordered = sorted(gmembers[g],
                             key=lambda c: (c[1].request.deadline,
                                            c[1].request.pipeline,
                                            c[1].request.rid))
            fused.extend(ordered[:len(grants)])
        if len({lane.pipeline for lane, _ in fused}) < 2:
            return None            # fusion must actually span lanes
        n_total = sum(dec.batch for _, dec in fused)
        # batched duration at the combined size: conservative max over the
        # member lanes' profiles (sorted walk -> deterministic float max)
        reps: Dict[str, Request] = {}
        for lane, dec in fused:
            cur = reps.get(lane.pipeline)
            r = dec.request
            if cur is None or (r.deadline, r.rid) < (cur.deadline, cur.rid):
                reps[lane.pipeline] = r
        by_lane = {lane.pipeline: lane for lane, _ in fused}
        T = max(by_lane[pid].prof.batched_stage_time(reps[pid], stage,
                                                     k_chips, n_total)
                for pid in sorted(reps))
        return fused, host, host_units, n_total, T

    # -- fused launch scheduling ----------------------------------------------

    @staticmethod
    def _members(fused) -> Tuple[Request, ...]:
        """All batch items of all fused decisions, in the merged event's
        canonical (pipeline, rid) member order (detlint DET001: sorted
        before any accumulation downstream)."""
        return tuple(sorted(
            (r for _, dec in fused
             for r in (dec.request,) + tuple(dec.corequests)),
            key=lambda r: (r.pipeline, r.rid)))

    def _charge_borrowed(self, host, host_units, stage: str) -> None:
        """A fused launch spanning a borrowed (lending) unit counts ONE
        stage run against the host lane's borrow ledger — the owning
        lane's BORROW_PENALTY accounting is untouched (its dispatcher
        already discounted the native decision that borrowed the unit)."""
        if host.track_borrowed and any(g >= host.base_units for g in host_units):
            host.borrowed_stage_runs[stage] = \
                host.borrowed_stage_runs.get(stage, 0) + 1

    def _launch_e(self, fused, host, host_units, n_total: float, T: float,
                  tau: float, clock) -> None:
        from repro.core.clock import MERGED_LANE
        eng = host.engine
        start = max(tau, max(eng.units[g].free_at for g in host_units))
        start += eng._reinstance(host_units)
        start += eng._prepare_stage("E", host_units, tau)
        fin = start + T
        eng._reserve(host_units, start, fin)
        eng.stats.dispatches += 1
        self._charge_borrowed(host, host_units, "E")
        ptype = eng.plan.placements[host_units[0]]
        clock.push_completion(fin, MERGED_LANE, "E", ptype, T,
                              self._members(fused),
                              tuple((host.pipeline, g) for g in host_units)
                              if self.track_units else ())
        self._note_inflight(host.pipeline, host_units, fin)
        for lane, dec in fused:
            dec.xl_efused = (start, fin, lane is host, host_units)
            dec.xl_skip = tuple(getattr(dec, "xl_skip", ())) + ("E",)
        self.merges += 1
        self.merged_requests += n_total

    def plan(self, lane_decs, tau: float, clock) -> list:
        """Fuse this tick's cross-lane candidates.

        ``lane_decs`` is the ordered ``(lane, decisions)`` list for every
        lane, produced by ``Lane.decide`` *before* any lane executes.
        E-groups are scheduled immediately (the fused E run depends on
        nothing this tick); C-groups are returned for :meth:`finalize`
        after the lanes' engines have stamped ``stage_done["D"]``."""
        cgroups = []
        groups = self._collect(lane_decs)
        for key in sorted(groups):
            stage = key[0]
            per_lane: Dict[str, list] = {}
            for lane, dec in groups[key]:
                per_lane.setdefault(lane.pipeline, []).append((lane, dec))
            if len(per_lane) < 2:
                continue
            picked = self._select(stage, per_lane, tau, skey=key)
            if picked is None:
                continue
            fused, host, host_units, n_total, T = picked
            if stage == "E":
                self._launch_e(fused, host, host_units, n_total, T, tau, clock)
            else:
                for _, dec in fused:
                    dec.xl_cdefer = True
                    dec.xl_skip = tuple(getattr(dec, "xl_skip", ())) + ("C",)
                cgroups.append((fused, host, host_units, n_total, T))
        return cgroups

    def finalize(self, cgroups: list, tau: float, clock) -> None:
        """Schedule the deferred fused C launches.

        Runs after every lane executed its decisions: each member's
        ``stage_done["D"]`` now holds its decode finish, so the fused C
        start is gated on the slowest member's latent push to the host
        units (host-lane members use the engine's locality-aware push;
        foreign members pay the two-step cross-lane path)."""
        from repro.core.clock import MERGED_LANE
        for fused, host, host_units, n_total, T in cgroups:
            eng = host.engine
            ready = tau
            for lane, dec in fused:
                d_fin = dec.request.stage_done["D"]
                nbytes = lane.prof.comm_bytes(dec.request, "DC")
                if lane is host:
                    dr = eng._push(nbytes, dec.d_units, host_units, d_fin)
                else:
                    dr = d_fin + lane.engine.push_cross(nbytes)
                ready = max(ready, dr)
            start = max(ready, max(eng.units[g].free_at for g in host_units))
            start += eng._reinstance(host_units)
            start += eng._prepare_stage("C", host_units, tau)
            fin = start + T
            eng._reserve(host_units, start, fin)
            eng.stats.dispatches += 1
            self._charge_borrowed(host, host_units, "C")
            members = self._members(fused)
            for r in members:
                r.stage_done["C"] = fin
            ptype = eng.plan.placements[host_units[0]]
            clock.push_completion(fin, MERGED_LANE, "C", ptype, T, members,
                                  tuple((host.pipeline, g)
                                        for g in host_units)
                                  if self.track_units else ())
            self._note_inflight(host.pipeline, host_units, fin)
            self.merges += 1
            self.merged_requests += n_total

    # -- in-flight host tracking (lending force-return guard) ------------------

    def _note_inflight(self, pid: str, host_units, fin: float) -> None:
        for g in host_units:
            key = (pid, g)
            if fin > self.inflight_hosts.get(key, 0.0):
                self.inflight_hosts[key] = fin

    def fused_busy(self, pid: str, unit: int, tau: float) -> bool:
        """Is a fused launch hosted on ``(pid, unit)`` still un-drained at
        ``tau``?  The lending broker's force-return guard: a borrowed host
        unit inside a live ``MERGED_LANE`` event must not change hands
        until the merge drains (stale entries are pruned lazily)."""
        fin = self.inflight_hosts.get((pid, unit))
        if fin is None:
            return False
        if fin <= tau:
            del self.inflight_hosts[(pid, unit)]
            return False
        return True
