"""Cross-pipeline unit lending (fleet layer, between re-partitions).

Fleet sub-plans are hard range-partitioned (core/fleet.py): until the next
re-partition, a backlogged pipeline cannot touch a neighbour's idle chips
even when both sit on the same cluster.  Re-partitioning is the right tool
for *sustained* mix shifts — it moves whole node-quantized budgets and pays
full weight reloads — but bursts shorter than the hysteresis/cooldown
window strand exactly the capacity GENSERVE-style co-serving recovers.

The ``LendingBroker`` fills that gap with *loans*: an idle unit owned by
pipeline A temporarily hosts **E/C (encode / vae-decode) stage work** for a
backlogged pipeline B.  Hard invariants:

* **Diffuse never moves.**  A borrowed unit enters B's plan as an ⟨E⟩ or
  ⟨C⟩ auxiliary; it can never carry a primary (D) placement, so B's diffuse
  placement — and the ILP's primary budget columns — are untouched.
* **Reloads are charged.**  A loan pays the borrower's weight-reload
  latency when granted and the lender's when returned (both via
  ``RuntimeEngine.seed_unit_state``, the same entry point re-partition
  swaps are charged through).
* **Min-hold beats thrash.**  A loan is held at least ``lend_min_hold``
  seconds, so flapping between borrow and return can never out-compete the
  re-partition path on reload cost.

Matching runs on ``FleetMonitor``'s lending windows (per-pipeline backlog
pressure and idle-unit supply over ``lend_win`` seconds) against the fleet
plan's per-node ``lending_map``: aux-class (⟨E⟩/⟨C⟩) units are the
preferred stock, primary-class units are tapped only while the lender keeps
``lend_reserve`` idle units of its own.  With ``FleetConfig.lending=False``
(the default) the broker is never constructed and every touched code path
is bit-identical to the lending-free fleet.

Wake sources and trigger gates (the clock.py standard): the fleet driver
registers the broker's ``next_wake`` — the earliest loan min-hold expiry
and the next lending-window boundary — and lending forces
``idle_window_wakeups`` on (a loan must be returnable during an idle gap
the heartbeat would otherwise widen past).  The trigger gate lives in
``step``: a wake-up only makes the broker *look*; the pressure/supply
thresholds (``lend_min_pressure``, idle-window-clean supply,
``lend_min_stage_s`` — stage runs shorter than that gate never justify a
reload round-trip) and the min-hold decide whether a loan actually moves.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, TYPE_CHECKING, Tuple

if TYPE_CHECKING:   # import cycle: fleet.py builds the broker
    from repro.core.fleet import FleetSimulator, Lane

# synthetic node-id space for borrowed units inside a borrower engine:
# disjoint from any plan-local node id, so locality modelling treats pushes
# to a borrowed unit as inter-node traffic (the data really does cross the
# partition boundary)
FOREIGN_NODE_BASE = 1_000_000


@dataclasses.dataclass
class Loan:
    """One active loan: lender unit ``lender_uid`` hosts ``ptype`` work for
    ``borrower`` through slot ``slot`` of the borrower's engine.

    No return-cost snapshot is kept: a lane re-placement may retype the
    lender's unit while it is on loan, so ``_close`` always recomputes the
    return reload from the lender's *live* plan — one source of truth."""
    lender: str
    lender_uid: int
    borrower: str
    slot: int
    ptype: str                   # "E" | "C"
    start: float
    borrow_cost: float
    # a force-return arrived while the borrowed slot hosted an un-drained
    # cross-lane fused launch (MERGED_LANE event in flight): the close is
    # deferred to the merge drain — ``step`` retries it on every wake-up
    force_return_pending: bool = False


class LendingBroker:
    def __init__(self, cfg, registry):
        self.cfg = cfg
        self.reg = registry
        self.active: List[Loan] = []
        self._free_slots: Dict[str, List[int]] = {}
        self._map_plan = None          # lending-map cache key (plan identity)
        self._map = None
        # accounting (surfaced through FleetResult)
        self.loans_granted = 0
        self.borrowed_unit_seconds = 0.0
        self.swap_cost_s = 0.0
        self.reloads = 0
        self.forced_returns = 0        # re-partition force-closed loans
        self.loans_by_pair: Dict[Tuple[str, str], int] = {}

    # ---------------------------------------------------------------- helpers

    def _lend_map(self, fleet: "FleetSimulator"):
        if self._map is None or self._map_plan is not fleet.plan:
            self._map = fleet.plan.lending_map(self.reg)
            self._map_plan = fleet.plan
        return self._map

    @staticmethod
    def _idle_active_units(lane: "Lane", tau: float) -> List[int]:
        """Idle, still-active, non-borrowed units of one lane.  Units
        decommissioned by the fault injector (draining ahead of a
        preemption, or quarantined as degraded) are never lendable stock —
        their chips are about to vanish or are suspect."""
        plan = lane.engine.plan
        return [g for g in lane.engine.idle_units(tau)
                if g < lane.base_units and plan.is_active(g)
                and not plan.is_decommissioned(g)]

    def _loans_of(self, pid: str, role: str = "borrower") -> List[Loan]:
        key = "borrower" if role == "borrower" else "lender"
        return [ln for ln in self.active if getattr(ln, key) == pid]

    def has_lent(self, pid: str) -> bool:
        return any(ln.lender == pid for ln in self.active)

    def _sync_borrowed(self, fleet: "FleetSimulator", pid: str) -> None:
        lane = fleet.lanes[pid]
        by_stage: Dict[str, Tuple[int, ...]] = {}
        for ln in self._loans_of(pid):
            by_stage[ln.ptype] = by_stage.get(ln.ptype, ()) + (ln.slot,)
        lane.borrowed_units = by_stage

    # ---------------------------------------------------------------- grants

    def _want_loans(self, pressure: float) -> int:
        """Loan target: ``lend_demand_frac`` units per second of backlog
        pressure (queued chip-seconds per chip), capped."""
        return min(self.cfg.lend_max_loans,
                   int(math.ceil(pressure * self.cfg.lend_demand_frac)))

    def _stage_worth(self, lane: "Lane", stage: str) -> float:
        """Typical per-request time of ``stage`` at its optimal degree over
        the borrower's queued work — the payload a borrowed unit would
        actually host.  Millisecond stages can never amortize the reloads."""
        prof = lane.prof
        sample = [r for _, r in zip(range(16), lane.pending)]
        if not sample:
            return 0.0
        tot = 0.0
        for r in sample:
            k = prof.optimal_degree(r, stage) * prof.k_min
            tot += prof.stage_time(r, stage, k)
        return tot / len(sample)

    def _pick_ptype(self, lane: "Lane") -> str:
        """Hosted-stage heuristic: ⟨E⟩ only when the borrower's plan has
        E-needing primaries (⟨DC⟩/⟨D⟩) and no native ⟨E⟩ auxiliaries at all;
        Decode is otherwise always the stage worth offloading (it dwarfs
        Encode on every profiled pipeline)."""
        plan = lane.engine.plan
        needs_e = bool(plan.units_of_type("DC") or plan.units_of_type("D"))
        has_e = bool(plan.units_of_type("E"))
        if needs_e and not has_e and not lane.borrowed_units.get("E"):
            return "E"
        return "C"

    def _grant(self, fleet: "FleetSimulator", tau: float, borrower: str,
               lu, stage: str) -> None:
        lender_lane = fleet.lanes[lu.pipeline]
        borrower_lane = fleet.lanes[borrower]
        cost = lu.borrow_cost[(borrower, stage)]
        lender_lane.engine.plan.set_active(lu.unit, False)
        node = FOREIGN_NODE_BASE + lu.node
        slots = self._free_slots.get(borrower)
        if slots:
            slot = slots.pop()
            borrower_lane.engine.revive_loan_unit(slot, stage, node,
                                                  tau + cost)
        else:
            slot = borrower_lane.engine.add_loan_unit(stage, node, tau + cost)
        self.active.append(Loan(
            lender=lu.pipeline, lender_uid=lu.unit, borrower=borrower,
            slot=slot, ptype=stage, start=tau, borrow_cost=cost))
        self.loans_granted += 1
        pair = (lu.pipeline, borrower)
        self.loans_by_pair[pair] = self.loans_by_pair.get(pair, 0) + 1
        self.swap_cost_s += cost
        self.reloads += 1
        self._sync_borrowed(fleet, borrower)
        # the lender unit's chips now host borrower weights: any staged
        # pre-warm marks there are physically overwritten (satellite fix —
        # a stale mark would under-charge the next re-partition's reload)
        fleet._evict_prewarm_unit(lu.pipeline, lu.unit)
        fleet.mark_lane_dirty(lu.pipeline)
        fleet.mark_lane_dirty(borrower)

    # ---------------------------------------------------------------- returns

    def _close(self, fleet: "FleetSimulator", loan: Loan, tau: float) -> None:
        """Return one loan: the borrower's slot goes inactive, the lender's
        unit comes back after its weight reload.  The reload covers the
        unit's *current* placement type — a lane re-placement may have
        retyped it since the loan was struck, so the grant-time snapshot in
        ``loan.return_cost`` would be stale."""
        borrower_lane = fleet.lanes[loan.borrower]
        lender_lane = fleet.lanes[loan.lender]
        slot_free = borrower_lane.engine.units[loan.slot].free_at
        t_free = max(tau, slot_free)
        borrower_lane.engine.plan.set_active(loan.slot, False)
        self._free_slots.setdefault(loan.borrower, []).append(loan.slot)
        prof = lender_lane.prof
        ret_cost = sum(prof.stage_load_time(s, via_host=True)
                       for s in lender_lane.engine.plan.placements[
                           loan.lender_uid])
        lender_lane.engine.plan.set_active(loan.lender_uid, True)
        lender_lane.engine.seed_unit_state(
            {loan.lender_uid: t_free + ret_cost})
        self.borrowed_unit_seconds += t_free - loan.start
        self.swap_cost_s += ret_cost
        self.reloads += 1
        self.active.remove(loan)
        self._sync_borrowed(fleet, loan.borrower)
        fleet.mark_lane_dirty(loan.lender)
        fleet.mark_lane_dirty(loan.borrower)

    def release_all(self, fleet: "FleetSimulator", tau: float) -> None:
        """Force-return every loan (called right before a re-partition —
        the whole pool is about to change hands anyway).  Forced closes may
        legitimately cut a loan short of its min-hold."""
        self.forced_returns += len(self.active)
        for loan in list(self.active):
            self._close(fleet, loan, tau)

    @staticmethod
    def _fused_inflight(fleet: "FleetSimulator", loan: Loan,
                        tau: float) -> bool:
        """Does the borrowed slot host an un-drained cross-lane fused
        launch?  Closing the loan mid-flight would hand the lender chips
        that are still executing another lane's merged batch."""
        xl = fleet._xl
        return xl is not None and xl.fused_busy(loan.borrower, loan.slot,
                                                tau)

    def unit_on_loan(self, lender: str, uid: int) -> bool:
        return any(ln.lender == lender and ln.lender_uid == uid
                   for ln in self.active)

    def force_return_unit(self, fleet: "FleetSimulator", lender: str,
                          uid: int, tau: float, hard: bool = False) -> bool:
        """Force-close the loan (if any) riding on one lender unit.  The
        predictive pre-warm path (core/fleet.py) must reclaim a lent-out
        unit before staging the next partition's weights on its chips — a
        loan must never survive a cutover, and staging under a live loan
        would double-book the chips; the fault injector reclaims doomed
        lender units the same way when a preemption notice lands.  Counted
        like re-partition forced returns (min-hold does not apply; the
        usual return reload is charged by ``_close``).

        Guard: when the borrowed slot hosts an un-drained ``MERGED_LANE``
        fused launch, the close is *deferred* (``force_return_pending``) —
        ``step`` closes it at the merge drain.  ``hard=True`` skips the
        guard (re-partition semantics: the engines are about to be
        rebuilt anyway).  Returns True when a loan was closed now."""
        for loan in list(self.active):
            if loan.lender == lender and loan.lender_uid == uid:
                if not hard and self._fused_inflight(fleet, loan, tau):
                    loan.force_return_pending = True
                    return False
                self.forced_returns += 1
                self._close(fleet, loan, tau)
                return True
        return False

    def reset_after_repartition(self, fleet: "FleetSimulator") -> None:
        """Engines were rebuilt from a fresh plan: loan slots are gone."""
        assert not self.active, "loans must be released before re-partition"
        self._free_slots.clear()
        self._map = None
        self._map_plan = None
        for lane in fleet.lanes.values():
            lane.borrowed_units = {}

    def reattach(self, lane: "Lane", new_plan) -> None:
        """A lane-level placement switch replaced this lane's sub-plan:
        re-append its loan slots (uid-aligned) so the engine's
        ``apply_placement`` sees a consistent unit count, keep lent-out
        base units deactivated in the fresh plan (their chips are serving
        another pipeline — reactivating them would double-book), and drop
        the cached lending map (unit types/costs may have changed)."""
        old_plan = lane.engine.plan
        for uid in range(lane.base_units, len(lane.engine.units)):
            new_uid = new_plan.extend(lane.engine.units[uid].placement)
            assert new_uid == uid
            if not old_plan.is_active(uid):
                new_plan.set_active(uid, False)
        for loan in self.active:
            if loan.lender == lane.pipeline:
                new_plan.set_active(loan.lender_uid, False)
        self._map = None
        self._map_plan = None

    def finalize(self, tau: float) -> None:
        """End-of-run accounting for still-open loans (no return charge —
        the simulation is over, nothing runs after)."""
        for loan in self.active:
            self.borrowed_unit_seconds += max(0.0, tau - loan.start)

    # ---------------------------------------------------------------- step

    def next_wake(self, tau: float) -> Optional[float]:
        """Earliest future borrow/return event the clock must visit: the
        next min-hold expiry, else the next lend-window re-check while any
        loan is outstanding.  Registered by the fleet driver as a wake
        source on the event-clock kernel (repro.core.clock), so loans are
        granted/returned for any lane count without loop plumbing."""
        if not self.active:
            return None
        expiries = [ln.start + self.cfg.lend_min_hold for ln in self.active
                    if ln.start + self.cfg.lend_min_hold > tau]
        nxt = tau + self.cfg.lend_win
        if expiries:
            nxt = min(nxt, min(expiries))
        return nxt

    def sample(self, fleet: "FleetSimulator", tau: float) -> None:
        """Record one pressure sample per lane into the Monitor's lending
        windows: queued chip-seconds per owned chip — the fleet's footprint
        currency, so pipelines of very different request rates compare
        fairly.  Called *after* the dispatch loop: what is still pending
        then is genuine backlog, not the batch that just arrived."""
        from repro.core.fleet import request_footprint
        for pid, lane in fleet.lanes.items():
            chips = max(1, lane.base_units * lane.engine.plan.unit_size)
            queued = sum(request_footprint(lane.prof, r)
                         for r in lane.pending)
            fleet.fleet_monitor.record_util(
                tau, pid, queued / chips,
                len(self._idle_active_units(lane, tau)))

    def _lend_budgets(self, fleet: "FleetSimulator", tau: float
                      ) -> Dict[str, int]:
        """How many units each pipeline can have out on loan right now: its
        own windowed-mean busy units are grossed up to ``lend_util_target``
        utilization (a lender never lends itself hot), plus an absolute
        ``lend_reserve`` floor."""
        cfg = self.cfg
        supply = fleet.fleet_monitor.idle_supply(tau)
        lent = {}
        for ln in self.active:
            lent[ln.lender] = lent.get(ln.lender, 0) + 1
        budgets: Dict[str, int] = {}
        for pid, lane in fleet.lanes.items():
            active_now = lane.base_units - lent.get(pid, 0)
            busy_mean = max(0.0, active_now - supply.get(pid, 0.0))
            keep = int(math.ceil(busy_mean / cfg.lend_util_target))
            budgets[pid] = max(0, lane.base_units - keep - cfg.lend_reserve)
        return budgets

    def step(self, fleet: "FleetSimulator", tau: float) -> None:
        cfg = self.cfg
        # 1. deferred force-returns: close as soon as the fused launch that
        #    pinned the borrowed slot has drained (its completion event is
        #    itself a wake-up, so the close is never missed)
        for loan in list(self.active):
            if loan.force_return_pending \
                    and not self._fused_inflight(fleet, loan, tau):
                self.forced_returns += 1
                self._close(fleet, loan, tau)
        pressure = fleet.fleet_monitor.backlog_pressure(tau)
        budgets = self._lend_budgets(fleet, tau)
        lent_count: Dict[str, int] = {}
        for ln in self.active:
            lent_count[ln.lender] = lent_count.get(ln.lender, 0) + 1

        # 2. returns, as soon as the slot is idle:
        #    * reclaim — the lender is over its lending budget (its own
        #      load came back): min-hold does NOT apply.  The hold exists
        #      so borrow/return thrash can't beat the re-partition path on
        #      reload cost, but a hot lender's demand justifies the extra
        #      reload — and a hot lender won't re-lend, so no thrash loop;
        #    * drained — the borrower's burst is over: respects min-hold.
        over = {pid: n - budgets.get(pid, 0)
                for pid, n in lent_count.items() if n > budgets.get(pid, 0)}
        for loan in list(self.active):
            drained = pressure.get(loan.borrower, 0.0) < cfg.lend_low_pressure
            reclaim = over.get(loan.lender, 0) > 0
            if not reclaim and (tau - loan.start < cfg.lend_min_hold
                                or not drained):
                continue
            lane = fleet.lanes[loan.borrower]
            if lane.engine.units[loan.slot].free_at > tau:
                continue   # mid-flight work: return at a later wake-up
            if over.get(loan.lender, 0) > 0:
                over[loan.lender] -= 1
            lent_count[loan.lender] -= 1
            self._close(fleet, loan, tau)

        # 3. grants: most-pressured borrower first, aux-class stock first,
        #    cheapest reload first.  A pipeline with units lent out is never
        #    also a borrower (and vice versa) — reciprocal lending would
        #    just shuttle reload costs back and forth.
        lending_out = {ln.lender for ln in self.active}
        borrowing = {ln.borrower for ln in self.active}
        borrowers = sorted(  # detlint: ignore[DET004] equal-pressure ties keep lane registry order; BENCH-byte-frozen
            (pid for pid, lane in fleet.lanes.items()
             if pressure.get(pid, 0.0) >= cfg.lend_min_pressure
             and lane.pending and pid not in lending_out),
            key=lambda p: -pressure.get(p, 0.0))
        if not borrowers:
            return
        lend_map = self._lend_map(fleet)
        on_loan = {(ln.lender, ln.lender_uid) for ln in self.active}
        idle_by_pid = {pid: set(self._idle_active_units(lane, tau))
                       for pid, lane in fleet.lanes.items()}
        for pid in borrowers:
            have = len(self._loans_of(pid))
            want = self._want_loans(pressure[pid])
            if have >= want:
                continue
            lane = fleet.lanes[pid]
            stage = self._pick_ptype(lane)
            if self._stage_worth(lane, stage) < cfg.lend_min_stage_s:
                continue   # reloads can never pay for millisecond stages
            cands = []
            for node_units in lend_map.values():
                for lu in node_units:
                    if lu.pipeline == pid or (pid, stage) not in lu.borrow_cost:
                        continue
                    if (lu.pipeline, lu.unit) in on_loan:
                        continue
                    if lu.pipeline in borrowing:
                        continue   # an active borrower never lends
                    if pressure.get(lu.pipeline, 0.0) >= cfg.lend_low_pressure:
                        continue   # lender is backlogged itself
                    if budgets.get(lu.pipeline, 0) \
                            <= lent_count.get(lu.pipeline, 0):
                        continue   # lender has no surplus beyond its target
                    idle = idle_by_pid[lu.pipeline]
                    if lu.unit not in idle:
                        continue
                    cands.append(lu)
            cands.sort(key=lambda lu: (not lu.aux_class,
                                       lu.borrow_cost[(pid, stage)]))
            for lu in cands:
                if have >= want:
                    break
                if budgets.get(lu.pipeline, 0) \
                        <= lent_count.get(lu.pipeline, 0):
                    continue
                self._grant(fleet, tau, pid, lu, stage)
                on_loan.add((lu.pipeline, lu.unit))
                idle_by_pid[lu.pipeline].discard(lu.unit)
                lent_count[lu.pipeline] = lent_count.get(lu.pipeline, 0) + 1
                have += 1
