"""Monitor (§5.1): clock-driven run-time statistics for the planners.

Tracks per-stage completion throughput and per-placement-type processing
rates over a sliding window T_win, plus worker status (delegated to the
engine).  Placement-switch trigger (§5.3): the fastest stage's throughput
at least 1.5x the slowest — with a secondary congestion signal (dispatch
backlog vs idle primary capacity) to catch starvation transients where
throughput ratios alone are uninformative.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.placement import PRIMARY_PLACEMENTS
from repro.core.request import Request

SWITCH_RATIO = 1.5
MIN_SAMPLES = 8


class Monitor:
    def __init__(self, t_win: float = 180.0):
        self.t_win = t_win
        self._completions: Deque[Tuple[float, str, str, float]] = collections.deque()
        self._backlog: Deque[Tuple[float, int, int]] = collections.deque()
        self.last_switch: float = -1e9

    # -- recording -------------------------------------------------------------

    def record_stage(self, tau: float, stage: str, ptype: str,
                     duration: float = 0.0):
        self._completions.append((tau, stage, ptype, duration))
        self._trim(tau)

    def record_backlog(self, tau: float, pending: int, idle_primary: int):
        self._backlog.append((tau, pending, idle_primary))
        self._trim(tau)

    def _trim(self, tau: float):
        for q in (self._completions, self._backlog):
            while q and q[0][0] < tau - self.t_win:
                q.popleft()

    # -- queries ---------------------------------------------------------------

    def stage_rates(self, tau: float) -> Dict[str, float]:
        self._trim(tau)
        counts = collections.Counter(s for _, s, _, _ in self._completions)
        return {s: counts.get(s, 0) / self.t_win for s in "EDC"}

    def placement_rates(self, tau: float, plan_hist: Dict[str, int],
                        min_count: int = 8) -> Dict[str, float]:
        """v_pi: service *capacity* (1/mean busy time) per replica of each
        placement type.  Throughput-over-window would conflate idleness with
        slowness and mis-drive the Split — capacity is what balances rates."""
        self._trim(tau)
        sums: Dict[str, float] = collections.defaultdict(float)
        counts: Dict[str, int] = collections.Counter()
        for _, _, p, dur in self._completions:
            if dur > 0:
                sums[p] += dur
                counts[p] += 1
        return {p: counts[p] / sums[p] for p in counts
                if counts[p] >= min_count and sums[p] > 0}

    def pattern_change(self, tau: float, cooldown: float = 60.0) -> bool:
        if tau - self.last_switch < cooldown or tau < self.t_win / 2:
            return False   # warm-up: pipeline lag makes early ratios noise
        self._trim(tau)
        counts = collections.Counter(s for _, s, _, _ in self._completions)
        trigger = False
        if all(counts.get(s, 0) >= MIN_SAMPLES for s in "EDC"):
            rates = [counts.get(s, 0) for s in "EDC"]
            if max(rates) / min(rates) >= SWITCH_RATIO:
                trigger = True
        # congestion: backlog persistently exceeds idle primary capacity
        if len(self._backlog) >= MIN_SAMPLES:
            recent = list(self._backlog)[-MIN_SAMPLES:]
            if all(p > 2 * max(1, i) for _, p, i in recent):
                trigger = True
        if trigger:
            self.last_switch = tau
        return trigger
