"""Monitor (§5.1): clock-driven run-time statistics for the planners.

Tracks per-stage completion throughput and per-placement-type processing
rates over a sliding window T_win, plus worker status (delegated to the
engine).  Placement-switch trigger (§5.3): the fastest stage's throughput
at least 1.5x the slowest — with a secondary congestion signal (dispatch
backlog vs idle primary capacity) to catch starvation transients where
throughput ratios alone are uninformative.

Windowed aggregates (per-stage counts, per-placement busy-time sums) are
maintained incrementally on record/trim, so every query is O(1) in the
window size — this sits on the scheduler wake-up hot path.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.placement import PRIMARY_PLACEMENTS
from repro.core.request import Request

SWITCH_RATIO = 1.5
MIN_SAMPLES = 8


class Monitor:
    def __init__(self, t_win: float = 180.0):
        self.t_win = t_win
        self._completions: Deque[Tuple[float, str, str, float]] = collections.deque()
        self._backlog: Deque[Tuple[float, int, int]] = collections.deque()
        self.last_switch: float = -1e9
        # incremental window aggregates (kept in lockstep with _completions)
        self._stage_counts: Dict[str, int] = collections.defaultdict(int)
        self._ptype_sums: Dict[str, float] = collections.defaultdict(float)
        self._ptype_counts: Dict[str, int] = collections.defaultdict(int)

    # -- recording -------------------------------------------------------------

    def record_stage(self, tau: float, stage: str, ptype: str,
                     duration: float = 0.0):
        self._completions.append((tau, stage, ptype, duration))
        self._stage_counts[stage] += 1
        if duration > 0:
            self._ptype_sums[ptype] += duration
            self._ptype_counts[ptype] += 1
        self._trim(tau)

    def record_backlog(self, tau: float, pending: int, idle_primary: int):
        self._backlog.append((tau, pending, idle_primary))
        self._trim(tau)

    def _trim(self, tau: float):
        cutoff = tau - self.t_win
        q = self._completions
        while q and q[0][0] < cutoff:
            _, s, p, dur = q.popleft()
            self._stage_counts[s] -= 1
            if dur > 0:
                self._ptype_sums[p] -= dur
                self._ptype_counts[p] -= 1
        b = self._backlog
        while b and b[0][0] < cutoff:
            b.popleft()

    # -- queries ---------------------------------------------------------------

    def next_window_boundary(self) -> Optional[float]:
        """Earliest future time a retained sample exits the sliding window.

        The event-driven simulator wakes at these boundaries so windowed
        rates (and the placement-switch trigger) are re-evaluated exactly
        when they can change, instead of every tick."""
        heads = [q[0][0] for q in (self._completions, self._backlog) if q]
        if not heads:
            return None
        return min(heads) + self.t_win

    def stage_rates(self, tau: float) -> Dict[str, float]:
        self._trim(tau)
        return {s: self._stage_counts.get(s, 0) / self.t_win for s in "EDC"}

    def placement_rates(self, tau: float, plan_hist: Dict[str, int],
                        min_count: int = 8) -> Dict[str, float]:
        """v_pi: service *capacity* (1/mean busy time) per replica of each
        placement type.  Throughput-over-window would conflate idleness with
        slowness and mis-drive the Split — capacity is what balances rates."""
        self._trim(tau)
        return {p: self._ptype_counts[p] / self._ptype_sums[p]
                for p in self._ptype_counts
                if self._ptype_counts[p] >= min_count and self._ptype_sums[p] > 0}

    def pattern_change(self, tau: float, cooldown: float = 60.0) -> bool:
        if tau - self.last_switch < cooldown or tau < self.t_win / 2:
            return False   # warm-up: pipeline lag makes early ratios noise
        self._trim(tau)
        counts = self._stage_counts
        trigger = False
        if all(counts.get(s, 0) >= MIN_SAMPLES for s in "EDC"):
            rates = [counts.get(s, 0) for s in "EDC"]
            if max(rates) / min(rates) >= SWITCH_RATIO:
                trigger = True
        # congestion: backlog persistently exceeds idle primary capacity
        # (peek the newest MIN_SAMPLES right-to-left; copying the whole
        # window deque per wake-up is O(T_win))
        if len(self._backlog) >= MIN_SAMPLES:
            it = reversed(self._backlog)
            if all(p > 2 * max(1, i)
                   for _, p, i in (next(it) for _ in range(MIN_SAMPLES))):
                trigger = True
        if trigger:
            self.last_switch = tau
        return trigger
