"""Monitor (§5.1): clock-driven run-time statistics for the planners.

Tracks per-stage completion throughput and per-placement-type processing
rates over a sliding window T_win, plus worker status (delegated to the
engine).  Placement-switch trigger (§5.3): the fastest stage's throughput
at least 1.5x the slowest — with a secondary congestion signal (dispatch
backlog vs idle primary capacity) to catch starvation transients where
throughput ratios alone are uninformative.

Windowed aggregates (per-stage counts, per-placement busy-time sums) are
maintained incrementally on record/trim, so every query is O(1) in the
window size — this sits on the scheduler wake-up hot path.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional, Tuple

try:                        # array-backed window state (SimConfig.array_state)
    import numpy as np
except ImportError:         # pragma: no cover - numpy ships with jax
    np = None


SWITCH_RATIO = 1.5
MIN_SAMPLES = 8


class _Cols:
    """Append-only parallel sample columns with a head cursor — the
    array-state twin of a deque of tuples.  Appends go at the tail, trims
    advance the head; the dead prefix is compacted away once it dominates
    the buffer, so both operations stay O(1) amortized and memory stays
    window-bounded.  Columns are plain lists: every consumer reads scalars
    (the incremental window sums are maintained outside), and a list
    append is several times cheaper than a float64 element store — which
    sat directly on the per-completion hot path at fleet scale.  Values
    are stored untouched, so the window sums are bit-identical to the
    deque path."""

    __slots__ = ("t", "cols", "h", "n")

    def __init__(self, n_cols: int, cap: int = 256):
        self.t: List[float] = []
        self.cols: List[List[float]] = [[] for _ in range(n_cols)]
        self.h = 0          # head: index of the oldest retained sample
        self.n = 0          # tail: one past the newest sample

    def __len__(self) -> int:
        return self.n - self.h

    def append(self, t: float, *vals: float) -> None:
        h = self.h
        if h > 8192 and 2 * h > self.n:
            del self.t[:h]
            for c in self.cols:
                del c[:h]
            self.n -= h
            self.h = 0
        self.t.append(t)
        for c, v in zip(self.cols, vals):
            c.append(v)
        self.n += 1

    def head_t(self) -> Optional[float]:
        return self.t[self.h] if self.n > self.h else None


def next_boundary(*windows) -> Optional[float]:
    """Earliest future time a retained sample exits one of the given
    sliding windows (``(deque, window_length)`` pairs; empty deques are
    skipped).  The event-clock kernel (repro.core.clock) wakes at these
    boundaries so windowed rates — and every trigger derived from them —
    are re-evaluated exactly when they can change, instead of every tick.
    Shared by ``Monitor`` and ``FleetMonitor`` so both expose the same
    wake-source contract."""
    heads = [q[0][0] + win for q, win in windows if q]
    return min(heads) if heads else None


class Monitor:
    """Per-lane window tracker; ``array_state=True`` swaps the deque-of-
    tuples sample stores for flat parallel columns (``_Cols``) with string
    stages/placement-types interned to integer codes.  The incremental
    aggregates (``_stage_counts`` / ``_ptype_sums`` / ``_ptype_counts``)
    are shared by both paths and updated with the *same* float adds and
    subtracts in the *same* order, so every query — and therefore every
    trajectory — is bit-identical flag on or off
    (tests/test_scale_parity.py)."""

    def __init__(self, t_win: float = 180.0, array_state: bool = False):
        self.t_win = t_win
        self._arr = bool(array_state) and np is not None
        if self._arr:
            self._c = _Cols(3)      # (stage code, ptype code, duration)
            self._b = _Cols(2)      # (pending, idle primary)
            self._code: Dict[str, int] = {}
            self._name: List[str] = []
        else:
            self._completions: Deque[Tuple[float, str, str, float]] = \
                collections.deque()
            self._backlog: Deque[Tuple[float, int, int]] = collections.deque()
        self.last_switch: float = -1e9
        # incremental window aggregates (kept in lockstep with the samples)
        self._stage_counts: Dict[str, int] = collections.defaultdict(int)
        self._ptype_sums: Dict[str, float] = collections.defaultdict(float)
        self._ptype_counts: Dict[str, int] = collections.defaultdict(int)
        # earliest time the oldest retained sample can exit the window:
        # ``_trim`` is a strict no-op until then, so it returns in O(1)
        # off that bound instead of re-deriving it from the heads on every
        # recorded sample (``_trim`` sits on the per-sample hot path)
        self._trim_due: float = float("inf")

    def _intern(self, s: str) -> int:
        code = self._code.get(s)
        if code is None:
            code = self._code[s] = len(self._name)
            self._name.append(s)
        return code

    # -- recording -------------------------------------------------------------

    def record_stage(self, tau: float, stage: str, ptype: str,
                     duration: float = 0.0):
        if self._arr:
            self._c.append(tau, self._intern(stage), self._intern(ptype),
                           duration)
        else:
            self._completions.append((tau, stage, ptype, duration))
        self._stage_counts[stage] += 1
        if duration > 0:
            self._ptype_sums[ptype] += duration
            self._ptype_counts[ptype] += 1
        if tau + self.t_win < self._trim_due:
            self._trim_due = tau + self.t_win
        self._trim(tau)

    def record_backlog(self, tau: float, pending: int, idle_primary: int):
        if self._arr:
            self._b.append(tau, pending, idle_primary)
        else:
            self._backlog.append((tau, pending, idle_primary))
        if tau + self.t_win < self._trim_due:
            self._trim_due = tau + self.t_win
        self._trim(tau)

    def _trim(self, tau: float):
        # a sample exits only when tau - t_win moves strictly past its
        # timestamp, i.e. when tau > head + t_win == _trim_due; before that
        # both scan loops below are guaranteed zero-iteration no-ops
        if tau <= self._trim_due:
            return
        cutoff = tau - self.t_win
        if self._arr:
            c = self._c
            ct, cs, cp, cd = c.t, c.cols[0], c.cols[1], c.cols[2]
            h, n = c.h, c.n
            while h < n and ct[h] < cutoff:
                self._stage_counts[self._name[int(cs[h])]] -= 1
                dur = float(cd[h])
                if dur > 0:
                    p = self._name[int(cp[h])]
                    self._ptype_sums[p] -= dur
                    self._ptype_counts[p] -= 1
                h += 1
            c.h = h
            b = self._b
            h, n, bt = b.h, b.n, b.t
            while h < n and bt[h] < cutoff:
                h += 1
            b.h = h
            heads = [t for t in (self._c.head_t(), self._b.head_t())
                     if t is not None]
            self._trim_due = (min(heads) + self.t_win) if heads \
                else float("inf")
            return
        q = self._completions
        while q and q[0][0] < cutoff:
            _, s, p, dur = q.popleft()
            self._stage_counts[s] -= 1
            if dur > 0:
                self._ptype_sums[p] -= dur
                self._ptype_counts[p] -= 1
        b = self._backlog
        while b and b[0][0] < cutoff:
            b.popleft()
        heads = [dq[0][0] for dq in (q, b) if dq]
        self._trim_due = (min(heads) + self.t_win) if heads else float("inf")

    # -- queries ---------------------------------------------------------------

    def next_window_boundary(self) -> Optional[float]:
        """Earliest future time a retained sample exits the sliding window
        (the kernel's Monitor-window wake source; see ``next_boundary``)."""
        if self._arr:
            heads = [t + self.t_win
                     for t in (self._c.head_t(), self._b.head_t())
                     if t is not None]
            return min(heads) if heads else None
        return next_boundary((self._completions, self.t_win),
                             (self._backlog, self.t_win))

    def stage_rates(self, tau: float) -> Dict[str, float]:
        self._trim(tau)
        return {s: self._stage_counts.get(s, 0) / self.t_win for s in "EDC"}

    def placement_rates(self, tau: float, plan_hist: Dict[str, int],
                        min_count: int = 8) -> Dict[str, float]:
        """v_pi: service *capacity* (1/mean busy time) per replica of each
        placement type.  Throughput-over-window would conflate idleness with
        slowness and mis-drive the Split — capacity is what balances rates."""
        self._trim(tau)
        return {p: self._ptype_counts[p] / self._ptype_sums[p]
                for p in self._ptype_counts
                if self._ptype_counts[p] >= min_count and self._ptype_sums[p] > 0}

    def pattern_change(self, tau: float, cooldown: float = 60.0) -> bool:
        if tau - self.last_switch < cooldown or tau < self.t_win / 2:
            return False   # warm-up: pipeline lag makes early ratios noise
        self._trim(tau)
        counts = self._stage_counts
        trigger = False
        if all(counts.get(s, 0) >= MIN_SAMPLES for s in "EDC"):
            rates = [counts.get(s, 0) for s in "EDC"]
            if max(rates) / min(rates) >= SWITCH_RATIO:
                trigger = True
        # congestion: backlog persistently exceeds idle primary capacity
        # (peek the newest MIN_SAMPLES right-to-left; copying the whole
        # window deque per wake-up is O(T_win))
        if self._arr:
            b = self._b
            if len(b) >= MIN_SAMPLES:
                bp, bi = b.cols[0], b.cols[1]
                if all(bp[j] > 2 * max(1, int(bi[j]))
                       for j in range(b.n - 1, b.n - 1 - MIN_SAMPLES, -1)):
                    trigger = True
        elif len(self._backlog) >= MIN_SAMPLES:
            it = reversed(self._backlog)
            if all(p > 2 * max(1, i)
                   for _, p, i in (next(it) for _ in range(MIN_SAMPLES))):
                trigger = True
        if trigger:
            self.last_switch = tau
        return trigger


class FleetMonitor:
    """Cross-pipeline windows for the shared-cluster fleet (core/fleet.py).

    Per-pipeline sliding-window aggregates over a heterogeneous trace:

    * *demand* — unit-time footprint of arrivals (chip-seconds of Diffuse
      work at the profiled optimal degree), the quantity the fleet
      orchestrator weights chip budgets by (``alpha_mode="demand"`` lifted
      one level up);
    * *SLO attainment* — windowed on-time fraction per pipeline.

    ``mix_shift`` is the fleet's re-partition trigger: the windowed demand
    shares have drifted from the shares the current partition was built for
    (the *basis*) by at least the hysteresis threshold, and the swap
    cooldown has elapsed — so weight-swap cost is not paid on noise.
    Aggregates are maintained incrementally (O(1) amortized per record),
    like ``Monitor``'s: queries sit on the fleet wake-up path.
    """

    def __init__(self, t_win: float = 180.0, lend_win: float = 30.0):
        self.t_win = t_win
        self._arrivals: Deque[Tuple[float, str, float]] = collections.deque()
        self._demand: Dict[str, float] = collections.defaultdict(float)
        self._fin: Deque[Tuple[float, str, bool]] = collections.deque()
        self._fin_n: Dict[str, int] = collections.defaultdict(int)
        self._fin_on: Dict[str, int] = collections.defaultdict(int)
        self.last_repartition: float = -1e9
        # unit-lending pressure windows (core/lending.py): short sliding
        # window of (backlog-pressure, idle active units) samples per
        # pipeline — borrow/return decisions react on lend_win, not the
        # re-partition window.  Pressure is measured in queued chip-seconds
        # per owned chip (the fleet's unit-time footprint currency), so a
        # 1 req/s video pipeline minutes behind outranks a 40 req/s image
        # pipeline with a healthy sub-second queue.  Empty unless the broker
        # records into them, so the lending-off path is untouched
        # (next_window_boundary skips empties).
        self.lend_win = lend_win
        self._util: Deque[Tuple[float, str, float, int]] = collections.deque()
        self._util_bl: Dict[str, float] = collections.defaultdict(float)
        self._util_idle: Dict[str, int] = collections.defaultdict(int)
        self._util_n: Dict[str, int] = collections.defaultdict(int)
        # forecast rate history (core/forecast.py): fixed-width bins of
        # per-pipeline arrival demand, retained far beyond t_win so the
        # predictive scheduler can fit diurnal structure.  Disabled (and
        # recording nothing) unless ``enable_rate_history`` is called —
        # the default fleet path is untouched.
        self._rh_bin: float = 0.0
        self._rh_keep: int = 0
        self._rh: Dict[int, Dict[str, float]] = {}
        self._rh_lo: int = 0
        # per-placement-class demand history: same binning, keyed by the
        # placement type an arrival's auxiliary stages will demand ("E"/"C")
        # instead of by pipeline.  Disabled unless ``enable_class_history``
        # is called (predictive + cross-lane batching only).
        self._ch_bin: float = 0.0
        self._ch_keep: int = 0
        self._ch: Dict[int, Dict[str, float]] = {}
        self._ch_lo: int = 0
        # earliest time any head sample (arrival/finish on t_win, util on
        # lend_win) can exit its window — same O(1) ``_trim`` gate as the
        # lane Monitor's
        self._trim_due: float = float("inf")

    # -- recording -------------------------------------------------------------

    def enable_rate_history(self, bin_s: float, span_s: float) -> None:
        """Turn on the forecast rate history: per-pipeline arrival demand
        accumulated into ``bin_s``-wide bins, the last ``span_s`` seconds
        retained.  Called once by the predictive fleet scheduler's driver;
        every other path leaves the history disabled and records nothing."""
        self._rh_bin = bin_s
        self._rh_keep = max(2, int(round(span_s / bin_s)))

    def enable_class_history(self, bin_s: float, span_s: float) -> None:
        """Turn on the per-placement-class demand history (the cross-lane
        batching follow-up to the per-pipeline forecast): the fleet driver
        records each admitted request's auxiliary-stage chip-seconds under
        the placement type that stage will run on, so the predictive
        scheduler can forecast the placement-type *mix* the batcher will
        want and prioritize its pre-warm staging accordingly."""
        self._ch_bin = bin_s
        self._ch_keep = max(2, int(round(span_s / bin_s)))

    def record_class_demand(self, tau: float, cls: str, cost: float) -> None:
        """One arrival's demand (chip-seconds) against one placement class.
        No-op unless ``enable_class_history`` was called."""
        if not self._ch_bin:
            return
        b = int(tau // self._ch_bin)
        d = self._ch.setdefault(b, {})
        d[cls] = d.get(cls, 0.0) + cost
        lo = b - self._ch_keep
        while self._ch_lo < lo:
            self._ch.pop(self._ch_lo, None)
            self._ch_lo += 1

    def record_arrival(self, tau: float, pipeline: str, cost: float) -> None:
        self._arrivals.append((tau, pipeline, cost))
        self._demand[pipeline] += cost
        if self._rh_bin:
            b = int(tau // self._rh_bin)
            d = self._rh.setdefault(b, {})
            d[pipeline] = d.get(pipeline, 0.0) + cost
            # rate_history queried from bin b returns bins >= b - keep:
            # pop strictly older ones only, or the window's oldest returned
            # bin would read a spurious zero
            lo = b - self._rh_keep
            while self._rh_lo < lo:
                self._rh.pop(self._rh_lo, None)
                self._rh_lo += 1
        if tau + self.t_win < self._trim_due:
            self._trim_due = tau + self.t_win
        self._trim(tau)

    def record_finish(self, tau: float, pipeline: str, on_time: bool) -> None:
        self._fin.append((tau, pipeline, on_time))
        self._fin_n[pipeline] += 1
        self._fin_on[pipeline] += int(on_time)
        if tau + self.t_win < self._trim_due:
            self._trim_due = tau + self.t_win
        self._trim(tau)

    def record_util(self, tau: float, pipeline: str, backlog: float,
                    idle_units: int) -> None:
        """One lending-pressure sample: queued chip-seconds per owned chip
        and idle active units of one pipeline's lane at ``tau``."""
        self._util.append((tau, pipeline, backlog, idle_units))
        self._util_bl[pipeline] += backlog
        self._util_idle[pipeline] += idle_units
        self._util_n[pipeline] += 1
        if tau + self.lend_win < self._trim_due:
            self._trim_due = tau + self.lend_win
        self._trim(tau)

    def _trim(self, tau: float) -> None:
        # no head sample can exit before _trim_due (strict < comparisons
        # below) — skip the three scans in O(1) until then
        if tau <= self._trim_due:
            return
        cutoff = tau - self.t_win
        q = self._arrivals
        while q and q[0][0] < cutoff:
            _, p, c = q.popleft()
            self._demand[p] -= c
        f = self._fin
        while f and f[0][0] < cutoff:
            _, p, on = f.popleft()
            self._fin_n[p] -= 1
            self._fin_on[p] -= int(on)
        u = self._util
        lend_cut = tau - self.lend_win
        while u and u[0][0] < lend_cut:
            _, p, bl, idle = u.popleft()
            self._util_bl[p] -= bl
            self._util_idle[p] -= idle
            self._util_n[p] -= 1
        heads = [h for h in
                 ((q[0][0] + self.t_win if q else None),
                  (f[0][0] + self.t_win if f else None),
                  (u[0][0] + self.lend_win if u else None))
                 if h is not None]
        self._trim_due = min(heads) if heads else float("inf")

    # -- queries ---------------------------------------------------------------

    def demand(self, tau: float) -> Dict[str, float]:
        """Raw windowed unit-time demand (chip-seconds) per pipeline."""
        self._trim(tau)
        return {p: v for p, v in self._demand.items() if v > 0}

    def demand_shares(self, tau: float) -> Dict[str, float]:
        """Windowed unit-time demand share per pipeline (sums to 1)."""
        self._trim(tau)
        total = sum(v for v in self._demand.values() if v > 0)  # detlint: ignore[DET001] _demand dict is record-ordered (lane order): insertion-ordered
        if total <= 0:
            return {}
        return {p: max(0.0, v) / total for p, v in self._demand.items()
                if v > 0}

    def slo_attainment(self, tau: float) -> Dict[str, float]:
        self._trim(tau)
        return {p: self._fin_on[p] / self._fin_n[p]
                for p in self._fin_n if self._fin_n[p] > 0}

    def backlog_pressure(self, tau: float) -> Dict[str, float]:
        """Windowed mean backlog pressure per pipeline (lend window):
        queued chip-seconds of work per owned chip."""
        self._trim(tau)
        return {p: self._util_bl[p] / self._util_n[p]
                for p in self._util_n if self._util_n[p] > 0}

    def idle_supply(self, tau: float) -> Dict[str, float]:
        """Windowed mean idle active-unit count per pipeline (lend window)."""
        self._trim(tau)
        return {p: self._util_idle[p] / self._util_n[p]
                for p in self._util_n if self._util_n[p] > 0}

    def rate_history(self, tau: float, pipelines,
                     last: Optional[int] = None) -> List[
            Tuple[float, Dict[str, float]]]:
        """Completed forecast bins as ``(bin-center time, {pipeline:
        demand rate in chip-seconds/s})``, zero-filled for bins with no
        arrivals (no traffic *is* a rate observation — the forecaster must
        see the valleys, not just the peaks).  The bin ``tau`` falls in is
        still filling and is excluded, so the same ``tau`` always yields
        the same history in both clock modes.  ``last`` restricts the
        answer to the newest ``last`` completed bins (the predictive
        scheduler's fresh-rate confirmation needs 3, not the whole
        window).  Empty unless ``enable_rate_history`` was called."""
        if not self._rh_bin:
            return []
        cur = int(tau // self._rh_bin)
        first = max(0, cur - self._rh_keep)
        if last is not None:
            first = max(first, cur - last)
        out: List[Tuple[float, Dict[str, float]]] = []
        for b in range(first, cur):
            d = self._rh.get(b, {})
            out.append(((b + 0.5) * self._rh_bin,
                        {p: d.get(p, 0.0) / self._rh_bin for p in pipelines}))
        return out

    def class_rate_history(self, tau: float, classes,
                           last: Optional[int] = None) -> List[
            Tuple[float, Dict[str, float]]]:
        """``rate_history``'s per-placement-class twin: completed bins of
        ``{placement class: demand rate}``, zero-filled, current bin
        excluded.  Empty unless ``enable_class_history`` was called."""
        if not self._ch_bin:
            return []
        cur = int(tau // self._ch_bin)
        first = max(0, cur - self._ch_keep)
        if last is not None:
            first = max(first, cur - last)
        out: List[Tuple[float, Dict[str, float]]] = []
        for b in range(first, cur):
            d = self._ch.get(b, {})
            out.append(((b + 0.5) * self._ch_bin,
                        {c: d.get(c, 0.0) / self._ch_bin for c in classes}))
        return out

    def next_window_boundary(self) -> Optional[float]:
        return next_boundary((self._arrivals, self.t_win),
                             (self._fin, self.t_win),
                             (self._util, self.lend_win))

    def mix_shift(self, tau: float, basis: Optional[Dict[str, float]],
                  threshold: float = 0.10, cooldown: float = 120.0,
                  min_arrivals: int = 32) -> bool:
        """Has the traffic mix moved away from ``basis`` (the demand shares
        underlying the current partition) by at least ``threshold`` (total
        variation distance), past the cooldown, on enough evidence?"""
        if tau - self.last_repartition < cooldown:
            return False
        if len(self._arrivals) < min_arrivals or basis is None:
            return False
        shares = self.demand_shares(tau)
        if not shares:
            return False
        # sorted: the total-variation sum is order-sensitive in the last
        # ulp and str-set iteration follows PYTHONHASHSEED; a threshold
        # comparison must not flip run-to-run
        keys = sorted(set(shares) | set(basis))
        dist = 0.5 * sum(abs(shares.get(k, 0.0) - basis.get(k, 0.0))
                         for k in keys)
        return dist >= threshold
