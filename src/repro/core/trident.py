"""TridentServe scheduler: Orchestrator + Dispatcher + Monitor glued per
Algorithm 1 (bootstrap placement -> online dispatch -> adaptive re-placement
via Adjust-on-Dispatch)."""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

from repro.core.dispatcher import DispatchDecision, Dispatcher
from repro.core.orchestrator import Orchestrator
from repro.core.placement import PlacementPlan, PRIMARY_PLACEMENTS
from repro.core.profiler import Profiler
from repro.core.request import Request
from repro.core.simulator import Scheduler, SimConfig, Simulator
from repro.core.workloads import T_WIN


class TridentScheduler(Scheduler):
    name = "trident"

    def __init__(self, prof: Profiler, sim_cfg: SimConfig,
                 trace: Sequence[Request], *, enable_switch: bool = True,
                 stage_aware: bool = True, use_ilp: bool = True,
                 enable_batching: bool = True, aggregate_ilp: bool = False,
                 cross_lane_batching: bool = False,
                 incremental_ilp: bool = False):
        super().__init__(prof, sim_cfg, trace)
        self.orch = Orchestrator(prof, num_chips=sim_cfg.num_chips)
        # aggregate_ilp: multiplicity-aware solver aggregation (identical
        # pending requests enter once with a count); default off so the
        # single-pipeline path keeps its exact pre-aggregation behavior.
        # incremental_ilp: persist the dispatch model across wake-ups and
        # skip the solve when it is unchanged (docs/architecture.md).
        self.disp = Dispatcher(prof, aggregate=aggregate_ilp,
                               incremental=incremental_ilp)
        # array-backed lane state (SimConfig.array_state): deadline ordering
        # comes from PendingSet's flat deadline array instead of a Python
        # key sort — bit-identical order, vectorized argsort
        self._array_state = getattr(sim_cfg, "array_state", False)
        self.enable_switch = enable_switch      # wo-switch ablation
        self.stage_aware = stage_aware          # wo-stageAware ablation
        self.use_ilp = use_ilp                  # wo-scheduler ablation
        self.enable_batching = enable_batching  # App. E.1 dynamic batching
        # fleet cross-lane batching: when on, tick() annotates decisions
        # whose auxiliary E/C runs are fusable across lanes (dec.xl_candidate)
        # for the fleet's CrossLaneBatcher; off leaves decisions untouched
        self.cross_lane_batching = cross_lane_batching
        self.t_win = T_WIN.get(prof.cfg.name, 300.0)
        self.solver_time = 0.0
        self.solver_calls = 0
        self._recent: List[Request] = []
        self._recent_ids: set = set()

    # -- Algorithm 1, lines 1-3 -----------------------------------------------

    def initial_placement(self) -> Optional[PlacementPlan]:
        sample = list(self.trace[:64])
        return self.orch.generate(sample)

    # -- Algorithm 1, lines 6-8 (adaptive re-placement) -------------------------

    def maybe_replace(self, sim: Simulator, tau: float) -> Optional[PlacementPlan]:
        if not self.enable_switch:
            return None
        sim.monitor.t_win = self.t_win
        if not sim.monitor.pattern_change(tau, cooldown=self.t_win / 2):
            return None
        recent = [r for r in self._recent if r.arrival > tau - self.t_win]
        if len(recent) < 8:
            return None
        measured = sim.monitor.placement_rates(tau, sim.engine.plan.type_histogram())
        new_plan = self.orch.generate(recent, measured_rates=measured)
        if new_plan is None:   # no feasible re-placement: keep the current plan
            return None
        if new_plan.type_histogram() == sim.engine.plan.type_histogram():
            return None
        return new_plan

    def next_wake(self, sim: Simulator, tau: float) -> Optional[float]:
        """Event-source plug-in (opt-in via
        ``SimConfig.scheduler_wake_hooks``): the pattern-change trigger is
        gated on a cooldown after the last switch and a warm-up of half a
        window — the earliest future time it can *newly* fire is the later
        of those two crossings.  Window contents themselves only change on
        completions and boundary wake-ups the clock already visits."""
        if not self.enable_switch:
            return None
        gate = max(sim.monitor.last_switch + self.t_win / 2, self.t_win / 2)
        return gate if gate > tau else None

    # -- Algorithm 1, lines 9-10 (dispatch) --------------------------------------

    def tick(self, sim: Simulator, tau: float) -> List[DispatchDecision]:
        # the simulator exposes the batch admitted since the last step, so
        # recent-arrival bookkeeping is O(new) instead of O(pending) per tick
        new = getattr(sim, "new_arrivals", None)
        for r in (sim.pending if new is None else new):
            if r.rid not in self._recent_ids:
                self._recent.append(r)
                self._recent_ids.add(r.rid)
        if len(self._recent) > 4096:
            drop = self._recent[:-4096]
            self._recent = self._recent[-4096:]
            self._recent_ids -= {r.rid for r in drop}
        # live engine view (read-only contract, ServingEngine.idle_units):
        # held across dispatch but never mutated, and consumed before the
        # decisions are applied back to the engine
        idle = sim.engine.idle_units(tau)
        idle_primary = len(idle & sim.engine.plan.primary_units)
        sim.monitor.record_backlog(tau, len(sim.pending), idle_primary)
        if not sim.pending or idle_primary == 0:
            return []
        if not self.stage_aware:
            return self._dispatch_pipeline_level(sim, tau, idle)
        if not self.use_ilp:
            return self._dispatch_greedy_srtf(sim, tau, idle)
        t0 = time.perf_counter()  # detlint: ignore[DET002] wall-clock metrics only (solver_time); no control flow
        # App. E.1: form batches at the Diffuse stage's optimal batch size.
        # Same-class pending requests are chunked into batch-sized slices;
        # each slice's head enters the ILP and its tail rides along.
        pending = sim.pending
        chunk_of = {}
        if self.enable_batching:
            groups = {}
            ordered = (pending.by_deadline()
                       if self._array_state and hasattr(pending, "by_deadline")
                       else sorted(pending, key=lambda r: r.deadline))
            for r in ordered:
                groups.setdefault(r.key(), []).append(r)
            pending = []
            for key, pool in groups.items():
                bs0 = self.prof.optimal_batch(
                    pool[0], "D",
                    self.prof.optimal_degree(pool[0], "D") * self.prof.k_min)
                for i in range(0, len(pool), bs0):
                    chunk = pool[i:i + bs0]
                    pending.append(chunk[0])
                    chunk_of[chunk[0].rid] = chunk
        # fleet unit lending: a Lane carries borrowed foreign E/C units
        # (core/lending.py); the plain Simulator never sets the attribute
        reuses0 = self.disp.solve_reuses
        out = self.disp.dispatch(pending, sim.engine.plan, idle,
                                 sim.engine.free_at(), tau,
                                 borrowed=getattr(sim, "borrowed_units", None),
                                 draining=getattr(sim, "draining_units",
                                                  None) or None)
        if self.disp.solve_reuses != reuses0:
            # credit persisted-model solve skips to the engine serving this
            # lane (banked across fleet re-partitions like every EngineStats
            # counter); the default path never increments, so the stats
            # surface is unchanged when incremental_ilp is off
            sim.engine.stats.ilp_reuses += self.disp.solve_reuses - reuses0
        if self.enable_batching:
            for dec in out:
                chunk = chunk_of.get(dec.request.rid, [dec.request])
                bs = min(len(chunk), self.prof.optimal_batch(
                    dec.request, "D", dec.degree * self.prof.k_min))
                dec.corequests = tuple(chunk[1:bs])
        self.solver_time += time.perf_counter() - t0  # detlint: ignore[DET002] wall-clock metrics only (solver_time); no control flow
        self.solver_calls += 1
        if self.cross_lane_batching:
            # mark auxiliary stage runs the fleet batcher may fuse across
            # lanes: E when it is NOT merged into the primary launch, C when
            # it runs on units outside the decode set.  Co-resident stages
            # stay native — fusing them would break the merged-launch model.
            free_at = sim.engine.free_at()
            for dec in out:
                prim = PRIMARY_PLACEMENTS[dec.vr_type]
                stages = []
                if "E" not in prim and dec.e_units:
                    stages.append("E")
                if dec.c_units and not set(dec.c_units) <= set(dec.d_units):
                    stages.append("C")
                if stages:
                    dec.xl_candidate = tuple(stages)
                # E-hold: when the auxiliary encode unit is already
                # backlogged past one solo run, dispatching natively would
                # pin primary units against a queued encode.  The decision
                # is marked held — the fleet batcher still sees it as a
                # fusion candidate this tick, but if no cross-lane fusion
                # takes it the lane skips execution and the request stays
                # in the pending pool (clock.Lane.execute_decisions), so
                # the backlog queues where fusion can pack it instead of
                # invisibly on the unit's free_at.  Once the backlog
                # drains (wait <= one run) requests dispatch natively, so
                # holding never idles the unit; requests out of deadline
                # slack always dispatch (no starvation under overload).
                if "E" in stages:
                    wait = (max(free_at.get(g, tau) for g in dec.e_units)
                            - tau)
                    solo = self.prof.stage_time(
                        dec.request, "E", len(dec.e_units) * self.prof.k_min)
                    if wait > solo and tau + wait <= dec.request.deadline:
                        dec.xl_hold = True
        return out

    # -- ablation variants ---------------------------------------------------------

    def _dispatch_pipeline_level(self, sim, tau, idle) -> List[DispatchDecision]:
        """wo-stageAware: all stages take the Diffuse stage's unit set."""
        out = []
        avail = set(idle)
        for req in sorted(sim.pending, key=lambda r: r.deadline):
            k = self.prof.optimal_degree(req, "D")
            units = None
            for vr, ptype in enumerate(PRIMARY_PLACEMENTS):
                if not self.prof.fits(req, ptype, k):
                    continue
                units = Dispatcher.select_units(sim.engine.plan, ptype, k, avail)
                if units:
                    break
            if not units:
                continue
            avail -= set(units)
            out.append(DispatchDecision(request=req, vr_type=vr, degree=k,
                                        d_units=units, e_units=units,
                                        c_units=units))
        return out

    def _dispatch_greedy_srtf(self, sim, tau, idle) -> List[DispatchDecision]:
        """wo-scheduler: greedy SRTF replaces the ILP; stages still use
        profiled-optimal parallelism."""
        out = []
        avail = set(idle)
        free_at = sim.engine.free_at()

        def t_rem(r):
            k = self.prof.optimal_degree(r, "D") * self.prof.k_min
            return self.prof.stage_time(r, "D", k)

        for req in sorted(sim.pending, key=t_rem):
            k = self.prof.optimal_degree(req, "D")
            dec = None
            for vr, ptype in enumerate(PRIMARY_PLACEMENTS):
                if not self.prof.fits(req, ptype, k):
                    continue
                units = Dispatcher.select_units(sim.engine.plan, ptype, k, avail)
                if not units:
                    continue
                e_units = units if "E" in ptype else self.disp._aux_units(
                    sim.engine.plan, "E", self.prof.optimal_degree(req, "E"),
                    avail, free_at, tau)
                kc = self.prof.optimal_degree(req, "C")
                c_units = (units[:max(1, min(kc, len(units)))] if "C" in ptype
                           else self.disp._aux_units(sim.engine.plan, "C", kc,
                                                     avail, free_at, tau))
                if e_units and c_units:
                    dec = DispatchDecision(request=req, vr_type=vr, degree=k,
                                           d_units=units, e_units=tuple(e_units),
                                           c_units=tuple(c_units))
                    break
            if dec:
                avail -= set(dec.d_units)
                out.append(dec)
        return out
