"""0/1 ILP solver for the dispatch problem (in-repo replacement for PuLP).

Problem shape (paper §6.2 OBJ, C0–C4 after feasibility filtering):
  * each request has a set of *options* (i, k) with reward c = W_r - Q_{r,i}
    and resource usage k on budget dimension i;
  * pick at most one option per request;
  * per-dimension usage must not exceed the budget B_i;
  * maximize total reward.

Solved exactly by depth-first branch-and-bound with an admissible bound
(sum of per-request best remaining rewards) and a greedy incumbent.  A node
cap keeps per-tick latency bounded (the incumbent is returned if hit, making
the solver anytime) — matching the paper's sub-100 ms per-tick budget
(Table 4).  Cross-checked against brute force in tests/test_ilp.py.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Option:
    """One (type i, degree k) choice for a request."""
    dim: int          # budget dimension (primary type index)
    usage: int        # units consumed (degree k)
    reward: float


@dataclasses.dataclass
class Solution:
    choices: Dict[int, Option]     # request index -> chosen option
    reward: float
    nodes: int
    optimal: bool


def _greedy(options: Sequence[Sequence[Option]], budgets: List[int]) -> Tuple[Dict[int, Option], float]:
    """Initial incumbent: requests by best reward desc, best feasible option."""
    order = sorted(range(len(options)),
                   key=lambda r: -max((o.reward for o in options[r]), default=0.0))
    rem = list(budgets)
    chosen: Dict[int, Option] = {}
    total = 0.0
    for r in order:
        best = None
        for o in sorted(options[r], key=lambda o: (-o.reward, o.usage)):
            if o.reward > 0 and o.usage <= rem[o.dim]:
                best = o
                break
        if best is not None:
            chosen[r] = best
            rem[best.dim] -= best.usage
            total += best.reward
    return chosen, total


def solve(options: Sequence[Sequence[Option]], budgets: Sequence[int],
          node_cap: int = 200_000, time_cap: float = 0.2) -> Solution:
    """Maximize total reward.  ``options[r]`` lists request r's choices."""
    n = len(options)
    budgets = list(budgets)

    # Pareto-prune per request: drop options dominated in (reward, usage)
    pruned: List[List[Option]] = []
    for opts in options:
        keep: List[Option] = []
        for o in sorted(opts, key=lambda o: (o.usage, -o.reward)):
            if o.reward <= 0:
                continue
            if any(p.dim == o.dim and p.reward >= o.reward and p.usage <= o.usage
                   for p in keep):
                continue
            keep.append(o)
        pruned.append(keep)

    # order: largest best-reward first (tightens the additive bound quickly)
    best_reward = [max((o.reward for o in opts), default=0.0) for opts in pruned]
    order = sorted(range(n), key=lambda r: -best_reward[r])
    # suffix bound: best achievable from request position j onward
    suffix = [0.0] * (n + 1)
    for j in range(n - 1, -1, -1):
        suffix[j] = suffix[j + 1] + best_reward[order[j]]

    incumbent, inc_reward = _greedy(pruned, budgets)
    state = {"best": inc_reward, "choices": dict(incumbent), "nodes": 0,
             "t0": time.perf_counter(), "capped": False}

    def dfs(j: int, rem: List[int], cur: float, chosen: Dict[int, Option]):
        if state["capped"]:
            return
        state["nodes"] += 1
        if state["nodes"] >= node_cap or (state["nodes"] % 4096 == 0 and
                                          time.perf_counter() - state["t0"] > time_cap):
            state["capped"] = True
            return
        if cur > state["best"]:
            state["best"] = cur
            state["choices"] = dict(chosen)
        if j >= n or cur + suffix[j] <= state["best"] + 1e-12:
            return
        r = order[j]
        # try options best-first, then the skip branch
        for o in sorted(pruned[r], key=lambda o: -o.reward):
            if o.usage <= rem[o.dim]:
                rem[o.dim] -= o.usage
                chosen[r] = o
                dfs(j + 1, rem, cur + o.reward, chosen)
                del chosen[r]
                rem[o.dim] += o.usage
        dfs(j + 1, rem, cur, chosen)

    dfs(0, list(budgets), 0.0, {})
    return Solution(choices=state["choices"], reward=state["best"],
                    nodes=state["nodes"], optimal=not state["capped"])


def brute_force(options: Sequence[Sequence[Option]], budgets: Sequence[int]) -> float:
    """Exhaustive reference for tests (tiny instances only)."""
    n = len(options)
    best = 0.0
    choice_lists = [list(opts) + [None] for opts in options]
    for combo in itertools.product(*choice_lists):
        rem = list(budgets)
        total = 0.0
        ok = True
        for o in combo:
            if o is None:
                continue
            if o.reward <= 0:
                continue
            rem[o.dim] -= o.usage
            if rem[o.dim] < 0:
                ok = False
                break
            total += o.reward
        if ok:
            best = max(best, total)
    return best
