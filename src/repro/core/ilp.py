"""0/1 ILP solver for the dispatch problem (in-repo replacement for PuLP).

Problem shape (paper §6.2 OBJ, C0–C4 after feasibility filtering):
  * each request has a set of *options* (i, k) with reward c = W_r - Q_{r,i}
    and resource usage k on budget dimension i;
  * pick at most one option per request;
  * per-dimension usage must not exceed the budget B_i;
  * maximize total reward.

Solved exactly by depth-first branch-and-bound with an admissible bound
(sum of per-request best remaining rewards) and a greedy incumbent.  A node
cap keeps per-tick latency bounded (the incumbent is returned if hit, making
the solver anytime) — matching the paper's sub-100 ms per-tick budget
(Table 4).  Cross-checked against brute force in tests/test_ilp.py.

The anytime cap is **deterministic**: ``time_cap`` is translated into a
node budget at a fixed calibration rate (``NODES_PER_SECOND``) instead of
reading the wall clock.  The old wall-clock check stopped the DFS at a
machine-load-dependent node, so two runs of the same trace could dispatch
differently whenever an instance was big enough to hit the cap — which
silently broke the byte-for-byte BENCH reproduction contract on flood
scenarios (caught by tests/test_determinism.py).

Hot-path refinements (all exactness-preserving):
  * options whose usage exceeds their dimension's budget are dropped up
    front, which also tightens the additive suffix bound;
  * cross-dimension dominance: an option on a *slack* dimension (one whose
    budget covers every request's largest option there, so it can never be
    binding) prunes any option of the same request with no more reward —
    swapping into a slack dimension can never break feasibility;
  * ``warm`` re-seeds the incumbent from the previous tick's surviving
    (dim, usage) choices, so the branch-and-bound starts near last tick's
    optimum and prunes far more aggressively under steady load.

Multi-dimensional options (cross-lane batching): ``Option.dim``/``usage``
may also be *parallel tuples*, one (dim, usage) pair per budget dimension
the option consumes — the column shape the fleet's cross-lane batcher
needs, where joining a fused launch consumes both the launch's shared
batch-size budget and the member lane's own batch-curve cap.  Classic
single-``int`` options are unchanged (and single-dim instances take the
exact same code path bit-for-bit); the two kinds may mix freely in one
instance.  ``solve_grouped`` therefore expands cross-lane groups the same
way it expands within-lane multiplicity: one column with a count, capacity-
bounded by the *total* usage of the cheapest option.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

try:                        # dense-DP fast path (``dp=True`` instances)
    import numpy as np
except ImportError:         # pragma: no cover - numpy ships with jax
    np = None

# deterministic time->node translation for the anytime cap: calibrated on a
# flood instance (~1.3M nodes/s on the baseline box), so the node budget
# sits where the old wall-clock cap effectively was there — verified to
# reproduce the committed shared-cluster trajectory byte-for-byte across
# the whole [1.0M, 1.6M] band (tests/test_determinism.py pins it); a 50 ms
# dispatch budget is a 65k-node budget everywhere
NODES_PER_SECOND = 1_300_000


@dataclasses.dataclass(frozen=True)
class Option:
    """One (type i, degree k) choice for a request.

    ``dim``/``usage`` are plain ints for the classic dispatch column; a
    multi-dimensional option (cross-lane batching) carries parallel tuples
    instead — ``dim[j]``'s budget is charged ``usage[j]`` units."""
    dim: object       # budget dimension (int) | parallel dims (Tuple[int, ...])
    usage: object     # units consumed (int) | per-dim usages (Tuple[int, ...])
    reward: float


def _spans(o: Option) -> Tuple[Tuple[int, int], ...]:
    """Normalized ((dim, usage), ...) consumption pairs of one option."""
    if isinstance(o.dim, tuple):
        return tuple(zip(o.dim, o.usage))
    return ((o.dim, o.usage),)


def _usage_total(o: Option) -> int:
    """Total units consumed across all of an option's budget dimensions."""
    if isinstance(o.usage, tuple):
        return sum(o.usage)
    return o.usage


@dataclasses.dataclass
class Solution:
    choices: Dict[int, Option]     # request index -> chosen option
    reward: float
    nodes: int
    optimal: bool


@dataclasses.dataclass
class GroupedSolution:
    """Result of ``solve_grouped``: per-group granted options."""
    alloc: Dict[int, List[Option]]   # group index -> one Option per copy
    reward: float
    nodes: int
    optimal: bool
    n_slots: int                     # expanded instance size actually solved


def solve_grouped(options: Sequence[Sequence[Option]],
                  budgets: Sequence[int], counts: Sequence[int],
                  node_cap: int = 200_000, time_cap: float = 0.2,
                  warm: Optional[Dict[int, Sequence[Tuple[int, int]]]] = None,
                  dp: bool = False) -> GroupedSolution:
    """Multiplicity-aware dispatch ILP: group g enters once with a count.

    ``options[g]`` is the option list shared by ``counts[g]`` identical
    requests; up to ``counts[g]`` copies of group g may be granted (each
    copy independently picks one option and consumes its usage).  Instead
    of materializing every member — a dense same-class flood puts thousands
    of identical rows in front of the solver — each group is expanded only
    up to its *capacity bound*: every option consumes at least one unit, so
    no solution grants more copies than ``sum(budgets) // min_usage``.  The
    truncated members are interchangeable with the kept ones, so the
    optimum is unchanged; the expanded instance then reuses ``solve`` (whose
    identical-row symmetry breaking collapses the remaining copies).

    Cross-lane group expansion (fleet dynamic batching): the same
    machinery extends the grouping key *across lanes* — the fleet batcher
    keys groups by (lane, batch size) and hands each group multi-
    dimensional options (see ``Option``) whose parallel dims charge both
    the fused launch's shared batch budget and the member lane's own
    batch-curve cap.  Nothing here is lane-aware: a cross-lane group is
    just a group whose option spans more than one budget dimension, and
    the capacity bound uses the option's *total* usage.

    ``warm`` maps group index -> (dim, usage) pairs granted to the group on
    a previous solve; they seed the incumbent exactly like ``solve``'s warm
    starts.
    """
    total_budget = int(sum(budgets))
    slot_group: List[int] = []           # expanded slot -> group index
    slot_opts: List[Sequence[Option]] = []
    warm_slots: Dict[int, Tuple[int, int]] = {}
    for g, (opts, m) in enumerate(zip(options, counts)):
        if not opts or m <= 0:
            continue
        min_use = max(1, min(_usage_total(o) for o in opts))
        cap = min(int(m), total_budget // min_use)
        seeds = list((warm or {}).get(g, ()))
        for i in range(cap):
            if i < len(seeds):
                warm_slots[len(slot_group)] = tuple(seeds[i])
            slot_group.append(g)
            slot_opts.append(opts)
    sol = solve(slot_opts, budgets, node_cap=node_cap, time_cap=time_cap,
                warm=warm_slots or None, dp=dp)
    alloc: Dict[int, List[Option]] = {}
    for si, o in sol.choices.items():
        alloc.setdefault(slot_group[si], []).append(o)
    for granted in alloc.values():
        granted.sort(key=lambda o: (-o.reward, _usage_total(o)))
    return GroupedSolution(alloc=alloc, reward=sol.reward, nodes=sol.nodes,
                           optimal=sol.optimal, n_slots=len(slot_group))


def _greedy(options: Sequence[Sequence[Option]], budgets: List[int],
            seed: Optional[Dict[int, Option]] = None
            ) -> Tuple[Dict[int, Option], float]:
    """Incumbent: honor ``seed`` choices first (feasibility-checked), then
    fill the rest by best reward desc, best feasible option."""
    rem = list(budgets)
    chosen: Dict[int, Option] = {}
    total = 0.0
    if seed:
        for r, o in seed.items():  # detlint: ignore[DET001] warm-start dict is solver-insertion-ordered; admission order is the algorithm
            if all(u <= rem[d] for d, u in _spans(o)):
                chosen[r] = o
                for d, u in _spans(o):
                    rem[d] -= u
                total += o.reward
    order = sorted((r for r in range(len(options)) if r not in chosen),
                   key=lambda r: -max((o.reward for o in options[r]), default=0.0))
    for r in order:
        best = None
        for o in sorted(options[r], key=lambda o: (-o.reward, _usage_total(o))):
            if o.reward > 0 and all(u <= rem[d] for d, u in _spans(o)):
                best = o
                break
        if best is not None:
            chosen[r] = best
            for d, u in _spans(best):
                rem[d] -= u
            total += best.reward
    return chosen, total


def _solve_dp_single_dim(pruned: Sequence[Sequence[Option]], dim: int,
                         cap: int) -> Tuple[Dict[int, Option], float]:
    """Exact multiple-choice knapsack DP for *effectively one-dimensional*
    instances (every surviving option charges the same single budget
    dimension).  These are precisely the instances where the branch-and-
    bound's additive suffix bound degrades — a saturated fleet lane whose
    backlog all competes for one placement type routinely burned the whole
    deterministic node cap (and returned a sub-optimal incumbent) on what
    is a textbook 0/1 knapsack.  The dense DP is O(requests * cap * options)
    cells, exact, and cap-free.

    Determinism: iteration order is fixed (requests in index order, options
    in list order), updates replace only on *strictly* better reward, and
    reconstruction walks a parent-choice table — no hash-order, no clock.
    """
    n = len(pruned)
    # capacities beyond what every request's largest option could jointly
    # consume are unreachable — clamping shrinks the table on lanes whose
    # budget far exceeds the backlog (val is monotone, so val[cap_eff] is
    # still the optimum)
    cap = min(cap, sum(max((o.usage for o in opts), default=0)
                       for opts in pruned))
    val = np.zeros(cap + 1, dtype=np.float64)      # best reward at capacity <= c
    take = np.full((n, cap + 1), -1, dtype=np.int32)
    for r, opts in enumerate(pruned):
        if not opts:
            continue
        best = val.copy()                          # skip branch
        choice = take[r]
        for oi, o in enumerate(opts):
            u = o.usage
            if u > cap:
                continue
            cand = val[:cap + 1 - u] + o.reward
            seg = best[u:]
            upd = cand > seg
            seg[upd] = cand[upd]
            choice[u:][upd] = oi
        val = best
    c = cap
    picks: List[Tuple[int, Option]] = []
    for r in range(n - 1, -1, -1):
        oi = int(take[r, c])
        if oi >= 0:
            o = pruned[r][oi]
            picks.append((r, o))
            c -= o.usage
    picks.reverse()
    return dict(picks), float(val[cap])


def solve(options: Sequence[Sequence[Option]], budgets: Sequence[int],
          node_cap: int = 200_000, time_cap: float = 0.2,
          warm: Optional[Dict[int, Tuple[int, int]]] = None,
          dp: bool = False) -> Solution:
    """Maximize total reward.  ``options[r]`` lists request r's choices.

    ``warm`` maps request index -> (dim, usage) chosen on a previous solve
    of a similar instance; it only seeds the incumbent (rewards are re-read
    from the current options), so optimality claims are unaffected.

    ``time_cap`` is a *latency budget*, enforced deterministically: it is
    converted to a node budget at ``NODES_PER_SECOND``, so a capped solve
    stops at the same node on every machine and every run.

    ``dp`` permits the exact dense-DP fast path on effectively one-
    dimensional instances (``_solve_dp_single_dim``).  It is opt-in —
    flag-gated behind ``incremental_ilp`` at the dispatcher layer — because
    on instances big enough to hit the node cap the DFS returns a capped
    *incumbent* while the DP returns the true optimum: better grants, but a
    different trajectory than the committed BENCH baselines pin.
    """
    n = len(options)
    budgets = list(budgets)
    if time_cap is not None:
        node_cap = min(node_cap, max(1, int(time_cap * NODES_PER_SECOND)))

    # fused all-scalar fast path (opt-in with ``dp``, like the all-slack
    # early return below — this IS that return, with the feasibility
    # filter and the slack analysis folded into one pass that never
    # materializes spans).  At fleet scale almost every dispatch instance
    # is scalar-dim and fully slack, and the per-option ``_spans`` tuple
    # construction dominated solve preprocessing.  Bails to the generic
    # path (identical behavior) on the first tuple-dim option or any
    # non-slack dimension.
    if dp:
        nb = len(budgets)
        max_use_f = [0] * nb
        fast_best: List[Optional[Option]] = []
        scalar = True
        for opts in options:
            best = None
            per_dim: Dict[int, int] = {}
            for o in opts:
                if o.reward <= 0:
                    continue
                d = o.dim
                if isinstance(d, tuple):
                    scalar = False
                    break
                u = o.usage
                if u > budgets[d]:
                    continue
                if u > per_dim.get(d, 0):
                    per_dim[d] = u
                if best is None or o.reward > best.reward:
                    best = o
            if not scalar:
                break
            for d, u in per_dim.items():
                max_use_f[d] += u
            fast_best.append(best)
        if scalar and all(max_use_f[d] <= budgets[d] for d in range(nb)):
            choices: Dict[int, Option] = {}
            reward = 0.0
            for r, best in enumerate(fast_best):
                if best is not None:
                    choices[r] = best
                    reward += best.reward
            return Solution(choices=choices, reward=reward, nodes=0,
                            optimal=True)

    # feasibility filter: an option can never fit if its usage alone
    # exceeds its dimension's budget (checked per consumed dimension).
    # Spans are derived once per option here and threaded through the
    # slack analysis, the prune, and the DFS prep — ``_spans`` tuple
    # construction was a measurable share of solve preprocessing at
    # fleet scale.
    feasible: List[List[Option]] = []
    fspans: List[List[Tuple[Tuple[int, int], ...]]] = []
    for opts in options:
        keep_o: List[Option] = []
        keep_s: List[Tuple[Tuple[int, int], ...]] = []
        for o in opts:
            if o.reward <= 0:
                continue
            sp = _spans(o)
            for d, u in sp:
                if u > budgets[d]:
                    break
            else:
                keep_o.append(o)
                keep_s.append(sp)
        feasible.append(keep_o)
        fspans.append(keep_s)

    # slack dimensions: budget covers every request's largest option there,
    # so the dimension can never be binding in any solution
    max_use = [0] * len(budgets)
    for sps in fspans:
        per_dim: Dict[int, int] = {}
        for sp in sps:
            for d, u in sp:
                if u > per_dim.get(d, 0):
                    per_dim[d] = u
        for d, u in per_dim.items():
            max_use[d] += u
    slack = [max_use[d] <= budgets[d] for d in range(len(budgets))]

    # fully slack instance -> unconstrained: even if every request takes its
    # largest option in every dimension it touches, no budget binds, so the
    # optimum is each request's first-listed max-reward option.  Opt-in for
    # the same reason as the DP below: a node-capped DFS may have returned a
    # different (worse) incumbent, so always-on would change committed
    # trajectories.  At fleet scale most dispatch instances are slack —
    # this skips the dominance prune, ordering, and search entirely.
    if dp and all(slack):
        choices: Dict[int, Option] = {}
        reward = 0.0
        for r, opts in enumerate(feasible):
            best = None
            for o in opts:
                if best is None or o.reward > best.reward:
                    best = o
            if best is not None:
                choices[r] = best
                reward += best.reward
        return Solution(choices=choices, reward=reward, nodes=0,
                        optimal=True)

    # dominance prune per request:
    #   * same dims: dominated in (reward, per-dim usage) — classic Pareto;
    #   * cross dim: any option entirely on slack dimensions dominates
    #     options with no more reward (swapping to it can never break
    #     feasibility).
    pruned: List[List[Option]] = []
    pspans: List[List[Tuple[Tuple[int, int], ...]]] = []
    for opts, sps in zip(feasible, fspans):
        slack_best = None
        for o, sp in zip(opts, sps):
            for d, _ in sp:
                if not slack[d]:
                    break
            else:
                if slack_best is None or o.reward > slack_best:
                    slack_best = o.reward
        keep: List[Tuple[Option, Tuple[Tuple[int, int], ...],
                         Dict[int, int]]] = []
        for o, sp in sorted(zip(opts, sps),
                            key=lambda t: (_usage_total(t[0]), -t[0].reward)):
            o_use = dict(sp)
            if slack_best is not None and o.reward < slack_best:
                allslack = True
                for d in o_use:
                    if not slack[d]:
                        allslack = False
                        break
                if not allslack:
                    continue
            dominated = False
            for p, psp, p_use in keep:
                if p.reward >= o.reward and p_use.keys() == o_use.keys():
                    for d, u in psp:
                        if u > o_use[d]:
                            break
                    else:
                        dominated = True
                        break
            if not dominated:
                keep.append((o, sp, o_use))
        pruned.append([t[0] for t in keep])
        pspans.append([t[1] for t in keep])

    # per-dimension decomposable instance -> exact dense DP (opt-in).
    # When every surviving option charges one scalar dimension and each
    # request's options are confined to one dimension, requests partition
    # by dimension into independent multiple-choice knapsacks (budgets are
    # per-dim, rewards add across requests) — the effectively-1D case the
    # suffix bound degrades on, generalized to several dims at once.
    if dp and np is not None:
        decomposable = True
        req_dim: Dict[int, object] = {}
        for r, opts in enumerate(pruned):
            dims_r = {o.dim for o in opts}
            if len(dims_r) > 1 or any(isinstance(d, tuple) for d in dims_r):
                decomposable = False
                break
            if dims_r:
                req_dim[r] = next(iter(dims_r))
        if decomposable and req_dim:
            choices = {}
            reward = 0.0
            for d in sorted(set(req_dim.values())):
                rs = [r for r in range(n) if req_dim.get(r) == d]
                sub_choices, sub_reward = _solve_dp_single_dim(
                    [pruned[r] for r in rs], d, int(budgets[d]))
                for i, o in sub_choices.items():
                    choices[rs[i]] = o
                reward += sub_reward
            return Solution(choices=choices, reward=reward, nodes=0,
                            optimal=True)

    # order: largest best-reward first (tightens the additive bound quickly);
    # requests with *identical* option lists sort adjacently so the DFS can
    # break their symmetry (steady traffic yields many same-class requests
    # with bit-identical rewards)
    best_reward = [max((o.reward for o in opts), default=0.0) for opts in pruned]
    sig = [tuple(sorted((sp, o.reward) for o, sp in zip(opts, sps)))
           for opts, sps in zip(pruned, pspans)]
    order = sorted(range(n), key=lambda r: (-best_reward[r], sig[r]))
    # suffix bound: best achievable from request position j onward
    suffix = [0.0] * (n + 1)
    for j in range(n - 1, -1, -1):
        suffix[j] = suffix[j + 1] + best_reward[order[j]]
    # symmetry: skipping request j entirely makes every identical following
    # request interchangeable with it, so the skip branch may jump the group
    skip_to = list(range(1, n + 1))
    for j in range(n - 2, -1, -1):
        if sig[order[j]] == sig[order[j + 1]]:
            skip_to[j] = skip_to[j + 1]
    # full multiplicity symmetry break (opt-in with ``dp``): within a run of
    # identical requests, restrict assignments to the canonical form whose
    # option indices are non-decreasing along the run.  Any assignment
    # permutes into it with the same total reward, so the optimum value is
    # untouched, but a group of m identical requests with c options costs
    # C(m+c, c) states instead of (c+1)^m — the difference between a
    # steady-traffic dispatch flood proving optimality and burning the
    # node cap.  Opt-in because the canonical optimum can map options onto
    # members differently than the unconstrained first-found optimum
    # (equal-reward tie reordering; same contract as the DP fast path).
    same_as_next = [j + 1 < n and sig[order[j]] == sig[order[j + 1]]
                    for j in range(n)] if dp else [False] * n

    seed: Dict[int, Option] = {}
    if warm:
        for r, (dim, usage) in warm.items():
            if 0 <= r < n:
                for o in pruned[r]:
                    if o.dim == dim and o.usage == usage:
                        seed[r] = o
                        break
    incumbent, inc_reward = _greedy(pruned, budgets)
    if seed:
        warm_inc, warm_reward = _greedy(pruned, budgets, seed=seed)
        if warm_reward > inc_reward:
            incumbent, inc_reward = warm_inc, warm_reward
    best_reward_found = inc_reward
    best_choices = dict(incumbent)
    nodes = 0
    capped = False

    # pre-sort each request's options best-reward-first once (the DFS used
    # to re-sort at every node on the hot path), and pre-normalize each
    # option's (dim, usage) spans so the hot loop never re-derives them
    by = [sorted(zip(opts, sps), key=lambda t: -t[0].reward)
          for opts, sps in zip(pruned, pspans)]
    by_reward = [[o for o, _ in lst] for lst in by]
    by_spans = [[(sp, _usage_total(o)) for o, sp in lst] for lst in by]

    def dfs(j: int, rem: List[int], cap_rem: int, cur: float,
            chosen: Dict[int, Option], min_opt: int = 0):
        nonlocal best_reward_found, best_choices, nodes, capped
        if capped:
            return
        nodes += 1
        if nodes >= node_cap:
            capped = True
            return
        if cur > best_reward_found:
            best_reward_found = cur
            best_choices = dict(chosen)
        if j >= n:
            return
        # capacity-aware admissible bound: every option consumes >= 1 unit,
        # so at most cap_rem more requests can be served; ``order`` is
        # reward-descending, so their best case is the next cap_rem entries
        # of the suffix array.  This is what lets backlog >> capacity
        # instances (the dispatch flood case) prove optimality quickly
        # instead of burning the node cap.
        stop = j + cap_rem
        bound = suffix[j] - suffix[stop if stop < n else n]
        if cur + bound <= best_reward_found + 1e-12:
            return
        r = order[j]
        opts_r = by_reward[r]
        spans_r = by_spans[r]
        chain = same_as_next[j]
        # try options best-first, then the skip branch; ``min_opt`` (always
        # 0 unless ``dp``) is the canonical-form floor within a run of
        # identical requests
        for i in range(min_opt, len(opts_r)):
            sp, use = spans_r[i]
            for d, u in sp:
                if u > rem[d]:
                    break
            else:
                o = opts_r[i]
                for d, u in sp:
                    rem[d] -= u
                chosen[r] = o
                dfs(j + 1, rem, cap_rem - use, cur + o.reward, chosen,
                    i if chain else 0)
                del chosen[r]
                for d, u in sp:
                    rem[d] += u
        dfs(skip_to[j], rem, cap_rem, cur, chosen)

    dfs(0, list(budgets), sum(budgets), 0.0, {})
    return Solution(choices=best_choices, reward=best_reward_found,
                    nodes=nodes, optimal=not capped)


def brute_force(options: Sequence[Sequence[Option]], budgets: Sequence[int]) -> float:
    """Exhaustive reference for tests (tiny instances only)."""
    best = 0.0
    choice_lists = [list(opts) + [None] for opts in options]
    for combo in itertools.product(*choice_lists):
        rem = list(budgets)
        total = 0.0
        ok = True
        for o in combo:
            if o is None:
                continue
            if o.reward <= 0:
                continue
            for d, u in _spans(o):
                rem[d] -= u
            if any(rem[d] < 0 for d, _ in _spans(o)):
                ok = False
                break
            total += o.reward
        if ok:
            best = max(best, total)
    return best
