"""0/1 ILP solver for the dispatch problem (in-repo replacement for PuLP).

Problem shape (paper §6.2 OBJ, C0–C4 after feasibility filtering):
  * each request has a set of *options* (i, k) with reward c = W_r - Q_{r,i}
    and resource usage k on budget dimension i;
  * pick at most one option per request;
  * per-dimension usage must not exceed the budget B_i;
  * maximize total reward.

Solved exactly by depth-first branch-and-bound with an admissible bound
(sum of per-request best remaining rewards) and a greedy incumbent.  A node
cap keeps per-tick latency bounded (the incumbent is returned if hit, making
the solver anytime) — matching the paper's sub-100 ms per-tick budget
(Table 4).  Cross-checked against brute force in tests/test_ilp.py.

The anytime cap is **deterministic**: ``time_cap`` is translated into a
node budget at a fixed calibration rate (``NODES_PER_SECOND``) instead of
reading the wall clock.  The old wall-clock check stopped the DFS at a
machine-load-dependent node, so two runs of the same trace could dispatch
differently whenever an instance was big enough to hit the cap — which
silently broke the byte-for-byte BENCH reproduction contract on flood
scenarios (caught by tests/test_determinism.py).

Hot-path refinements (all exactness-preserving):
  * options whose usage exceeds their dimension's budget are dropped up
    front, which also tightens the additive suffix bound;
  * cross-dimension dominance: an option on a *slack* dimension (one whose
    budget covers every request's largest option there, so it can never be
    binding) prunes any option of the same request with no more reward —
    swapping into a slack dimension can never break feasibility;
  * ``warm`` re-seeds the incumbent from the previous tick's surviving
    (dim, usage) choices, so the branch-and-bound starts near last tick's
    optimum and prunes far more aggressively under steady load.

Multi-dimensional options (cross-lane batching): ``Option.dim``/``usage``
may also be *parallel tuples*, one (dim, usage) pair per budget dimension
the option consumes — the column shape the fleet's cross-lane batcher
needs, where joining a fused launch consumes both the launch's shared
batch-size budget and the member lane's own batch-curve cap.  Classic
single-``int`` options are unchanged (and single-dim instances take the
exact same code path bit-for-bit); the two kinds may mix freely in one
instance.  ``solve_grouped`` therefore expands cross-lane groups the same
way it expands within-lane multiplicity: one column with a count, capacity-
bounded by the *total* usage of the cheapest option.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

# deterministic time->node translation for the anytime cap: calibrated on a
# flood instance (~1.3M nodes/s on the baseline box), so the node budget
# sits where the old wall-clock cap effectively was there — verified to
# reproduce the committed shared-cluster trajectory byte-for-byte across
# the whole [1.0M, 1.6M] band (tests/test_determinism.py pins it); a 50 ms
# dispatch budget is a 65k-node budget everywhere
NODES_PER_SECOND = 1_300_000


@dataclasses.dataclass(frozen=True)
class Option:
    """One (type i, degree k) choice for a request.

    ``dim``/``usage`` are plain ints for the classic dispatch column; a
    multi-dimensional option (cross-lane batching) carries parallel tuples
    instead — ``dim[j]``'s budget is charged ``usage[j]`` units."""
    dim: object       # budget dimension (int) | parallel dims (Tuple[int, ...])
    usage: object     # units consumed (int) | per-dim usages (Tuple[int, ...])
    reward: float


def _spans(o: Option) -> Tuple[Tuple[int, int], ...]:
    """Normalized ((dim, usage), ...) consumption pairs of one option."""
    if isinstance(o.dim, tuple):
        return tuple(zip(o.dim, o.usage))
    return ((o.dim, o.usage),)


def _usage_total(o: Option) -> int:
    """Total units consumed across all of an option's budget dimensions."""
    if isinstance(o.usage, tuple):
        return sum(o.usage)
    return o.usage


@dataclasses.dataclass
class Solution:
    choices: Dict[int, Option]     # request index -> chosen option
    reward: float
    nodes: int
    optimal: bool


@dataclasses.dataclass
class GroupedSolution:
    """Result of ``solve_grouped``: per-group granted options."""
    alloc: Dict[int, List[Option]]   # group index -> one Option per copy
    reward: float
    nodes: int
    optimal: bool
    n_slots: int                     # expanded instance size actually solved


def solve_grouped(options: Sequence[Sequence[Option]],
                  budgets: Sequence[int], counts: Sequence[int],
                  node_cap: int = 200_000, time_cap: float = 0.2,
                  warm: Optional[Dict[int, Sequence[Tuple[int, int]]]] = None
                  ) -> GroupedSolution:
    """Multiplicity-aware dispatch ILP: group g enters once with a count.

    ``options[g]`` is the option list shared by ``counts[g]`` identical
    requests; up to ``counts[g]`` copies of group g may be granted (each
    copy independently picks one option and consumes its usage).  Instead
    of materializing every member — a dense same-class flood puts thousands
    of identical rows in front of the solver — each group is expanded only
    up to its *capacity bound*: every option consumes at least one unit, so
    no solution grants more copies than ``sum(budgets) // min_usage``.  The
    truncated members are interchangeable with the kept ones, so the
    optimum is unchanged; the expanded instance then reuses ``solve`` (whose
    identical-row symmetry breaking collapses the remaining copies).

    Cross-lane group expansion (fleet dynamic batching): the same
    machinery extends the grouping key *across lanes* — the fleet batcher
    keys groups by (lane, batch size) and hands each group multi-
    dimensional options (see ``Option``) whose parallel dims charge both
    the fused launch's shared batch budget and the member lane's own
    batch-curve cap.  Nothing here is lane-aware: a cross-lane group is
    just a group whose option spans more than one budget dimension, and
    the capacity bound uses the option's *total* usage.

    ``warm`` maps group index -> (dim, usage) pairs granted to the group on
    a previous solve; they seed the incumbent exactly like ``solve``'s warm
    starts.
    """
    total_budget = int(sum(budgets))
    slot_group: List[int] = []           # expanded slot -> group index
    slot_opts: List[Sequence[Option]] = []
    warm_slots: Dict[int, Tuple[int, int]] = {}
    for g, (opts, m) in enumerate(zip(options, counts)):
        if not opts or m <= 0:
            continue
        min_use = max(1, min(_usage_total(o) for o in opts))
        cap = min(int(m), total_budget // min_use)
        seeds = list((warm or {}).get(g, ()))
        for i in range(cap):
            if i < len(seeds):
                warm_slots[len(slot_group)] = tuple(seeds[i])
            slot_group.append(g)
            slot_opts.append(opts)
    sol = solve(slot_opts, budgets, node_cap=node_cap, time_cap=time_cap,
                warm=warm_slots or None)
    alloc: Dict[int, List[Option]] = {}
    for si, o in sol.choices.items():
        alloc.setdefault(slot_group[si], []).append(o)
    for granted in alloc.values():
        granted.sort(key=lambda o: (-o.reward, _usage_total(o)))
    return GroupedSolution(alloc=alloc, reward=sol.reward, nodes=sol.nodes,
                           optimal=sol.optimal, n_slots=len(slot_group))


def _greedy(options: Sequence[Sequence[Option]], budgets: List[int],
            seed: Optional[Dict[int, Option]] = None
            ) -> Tuple[Dict[int, Option], float]:
    """Incumbent: honor ``seed`` choices first (feasibility-checked), then
    fill the rest by best reward desc, best feasible option."""
    rem = list(budgets)
    chosen: Dict[int, Option] = {}
    total = 0.0
    if seed:
        for r, o in seed.items():  # detlint: ignore[DET001] warm-start dict is solver-insertion-ordered; admission order is the algorithm
            if all(u <= rem[d] for d, u in _spans(o)):
                chosen[r] = o
                for d, u in _spans(o):
                    rem[d] -= u
                total += o.reward
    order = sorted((r for r in range(len(options)) if r not in chosen),
                   key=lambda r: -max((o.reward for o in options[r]), default=0.0))
    for r in order:
        best = None
        for o in sorted(options[r], key=lambda o: (-o.reward, _usage_total(o))):
            if o.reward > 0 and all(u <= rem[d] for d, u in _spans(o)):
                best = o
                break
        if best is not None:
            chosen[r] = best
            for d, u in _spans(best):
                rem[d] -= u
            total += best.reward
    return chosen, total


def solve(options: Sequence[Sequence[Option]], budgets: Sequence[int],
          node_cap: int = 200_000, time_cap: float = 0.2,
          warm: Optional[Dict[int, Tuple[int, int]]] = None) -> Solution:
    """Maximize total reward.  ``options[r]`` lists request r's choices.

    ``warm`` maps request index -> (dim, usage) chosen on a previous solve
    of a similar instance; it only seeds the incumbent (rewards are re-read
    from the current options), so optimality claims are unaffected.

    ``time_cap`` is a *latency budget*, enforced deterministically: it is
    converted to a node budget at ``NODES_PER_SECOND``, so a capped solve
    stops at the same node on every machine and every run.
    """
    n = len(options)
    budgets = list(budgets)
    if time_cap is not None:
        node_cap = min(node_cap, max(1, int(time_cap * NODES_PER_SECOND)))

    # feasibility filter: an option can never fit if its usage alone
    # exceeds its dimension's budget (checked per consumed dimension)
    feasible: List[List[Option]] = [
        [o for o in opts if o.reward > 0
         and all(u <= budgets[d] for d, u in _spans(o))]
        for opts in options]

    # slack dimensions: budget covers every request's largest option there,
    # so the dimension can never be binding in any solution
    max_use = [0] * len(budgets)
    for opts in feasible:
        per_dim: Dict[int, int] = {}
        for o in opts:
            for d, u in _spans(o):
                per_dim[d] = max(per_dim.get(d, 0), u)
        for d, u in per_dim.items():
            max_use[d] += u
    slack = [max_use[d] <= budgets[d] for d in range(len(budgets))]

    # dominance prune per request:
    #   * same dims: dominated in (reward, per-dim usage) — classic Pareto;
    #   * cross dim: any option entirely on slack dimensions dominates
    #     options with no more reward (swapping to it can never break
    #     feasibility).
    pruned: List[List[Option]] = []
    for opts in feasible:
        slack_best = max((o.reward for o in opts
                          if all(slack[d] for d, _ in _spans(o))),
                         default=None)
        keep: List[Option] = []
        for o in sorted(opts, key=lambda o: (_usage_total(o), -o.reward)):
            o_use = dict(_spans(o))
            if (slack_best is not None and o.reward < slack_best
                    and not all(slack[d] for d in o_use)):
                continue
            if any(p.reward >= o.reward
                   and set(dict(_spans(p))) == set(o_use)
                   and all(u <= o_use[d] for d, u in _spans(p))
                   for p in keep):
                continue
            keep.append(o)
        pruned.append(keep)

    # order: largest best-reward first (tightens the additive bound quickly);
    # requests with *identical* option lists sort adjacently so the DFS can
    # break their symmetry (steady traffic yields many same-class requests
    # with bit-identical rewards)
    best_reward = [max((o.reward for o in opts), default=0.0) for opts in pruned]
    sig = [tuple(sorted((_spans(o), o.reward) for o in opts))
           for opts in pruned]
    order = sorted(range(n), key=lambda r: (-best_reward[r], sig[r]))
    # suffix bound: best achievable from request position j onward
    suffix = [0.0] * (n + 1)
    for j in range(n - 1, -1, -1):
        suffix[j] = suffix[j + 1] + best_reward[order[j]]
    # symmetry: skipping request j entirely makes every identical following
    # request interchangeable with it, so the skip branch may jump the group
    skip_to = list(range(1, n + 1))
    for j in range(n - 2, -1, -1):
        if sig[order[j]] == sig[order[j + 1]]:
            skip_to[j] = skip_to[j + 1]

    seed: Dict[int, Option] = {}
    if warm:
        for r, (dim, usage) in warm.items():
            if 0 <= r < n:
                for o in pruned[r]:
                    if o.dim == dim and o.usage == usage:
                        seed[r] = o
                        break
    incumbent, inc_reward = _greedy(pruned, budgets)
    if seed:
        warm_inc, warm_reward = _greedy(pruned, budgets, seed=seed)
        if warm_reward > inc_reward:
            incumbent, inc_reward = warm_inc, warm_reward
    state = {"best": inc_reward, "choices": dict(incumbent), "nodes": 0,
             "capped": False}

    # pre-sort each request's options best-reward-first once (the DFS used
    # to re-sort at every node on the hot path), and pre-normalize each
    # option's (dim, usage) spans so the hot loop never re-derives them
    by_reward = [sorted(opts, key=lambda o: -o.reward) for opts in pruned]
    by_spans = [[(_spans(o), _usage_total(o)) for o in opts]
                for opts in by_reward]

    def dfs(j: int, rem: List[int], cap_rem: int, cur: float,
            chosen: Dict[int, Option]):
        if state["capped"]:
            return
        state["nodes"] += 1
        if state["nodes"] >= node_cap:
            state["capped"] = True
            return
        if cur > state["best"]:
            state["best"] = cur
            state["choices"] = dict(chosen)
        if j >= n:
            return
        # capacity-aware admissible bound: every option consumes >= 1 unit,
        # so at most cap_rem more requests can be served; ``order`` is
        # reward-descending, so their best case is the next cap_rem entries
        # of the suffix array.  This is what lets backlog >> capacity
        # instances (the dispatch flood case) prove optimality quickly
        # instead of burning the node cap.
        bound = suffix[j] - suffix[min(n, j + cap_rem)]
        if cur + bound <= state["best"] + 1e-12:
            return
        r = order[j]
        # try options best-first, then the skip branch
        for o, (sp, use) in zip(by_reward[r], by_spans[r]):
            if all(u <= rem[d] for d, u in sp):
                for d, u in sp:
                    rem[d] -= u
                chosen[r] = o
                dfs(j + 1, rem, cap_rem - use, cur + o.reward, chosen)
                del chosen[r]
                for d, u in sp:
                    rem[d] += u
        dfs(skip_to[j], rem, cap_rem, cur, chosen)

    dfs(0, list(budgets), sum(budgets), 0.0, {})
    return Solution(choices=state["choices"], reward=state["best"],
                    nodes=state["nodes"], optimal=not state["capped"])


def brute_force(options: Sequence[Sequence[Option]], budgets: Sequence[int]) -> float:
    """Exhaustive reference for tests (tiny instances only)."""
    best = 0.0
    choice_lists = [list(opts) + [None] for opts in options]
    for combo in itertools.product(*choice_lists):
        rem = list(budgets)
        total = 0.0
        ok = True
        for o in combo:
            if o is None:
                continue
            if o.reward <= 0:
                continue
            for d, u in _spans(o):
                rem[d] -= u
            if any(rem[d] < 0 for d, _ in _spans(o)):
                ok = False
                break
            total += o.reward
        if ok:
            best = max(best, total)
    return best
