"""Placement types, Virtual Replicas (Table 3), and placement plans.

π_g ∈ {⟨EDC⟩, ⟨DC⟩, ⟨ED⟩, ⟨D⟩, ⟨E⟩, ⟨C⟩}; ⟨EC⟩ is omitted per the paper
(footnote 3: D dominates the critical path, so E+C co-location without D
neither improves throughput nor reduces D-bound traffic).

Virtual Replicas V0..V3 map one-to-one to the *Primary Placements* (those
containing D); their inter-stage communication grows monotonically with the
index: 0, Q_ED, Q_DC, Q_ED+Q_DC — and since l_proc^C > l_proc^E implies
Q_DC > Q_ED, the preference order is V0 ≺ V1 ≺ V2 ≺ V3.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Tuple

# placement types (stage sets, order-normalized)
EDC, DC, ED, D, E, C = "EDC", "DC", "ED", "D", "E", "C"
PLACEMENT_TYPES = (EDC, DC, ED, D, E, C)
PRIMARY_PLACEMENTS = (EDC, DC, ED, D)      # contain D
AUXILIARY_PLACEMENTS = (E, C)

# Virtual Replica table (paper Table 3)
#   index -> (primary placement, auxiliary placements, comm stages crossed)
VIRTUAL_REPLICAS: Dict[int, Tuple[str, Tuple[str, ...], Tuple[str, ...]]] = {
    0: (EDC, (), ()),                      # V0: no inter-stage comm
    1: (DC, (E,), ("ED",)),                # V1: Q_ED
    2: (ED, (C,), ("DC",)),                # V2: Q_DC
    3: (D, (E, C), ("ED", "DC")),          # V3: Q_ED + Q_DC
}
VR_TYPES = tuple(VIRTUAL_REPLICAS)


def stages_of(ptype: str) -> FrozenSet[str]:
    return frozenset(ptype)


def primary_of_vr(vr: int) -> str:
    return VIRTUAL_REPLICAS[vr][0]


def vr_of_primary(ptype: str) -> int:
    for vr, (prim, _, _) in VIRTUAL_REPLICAS.items():
        if prim == ptype:
            return vr
    raise KeyError(ptype)


@dataclasses.dataclass
class PlacementPlan:
    """P = {π_g}: placement type per scheduling unit (k_min chips).

    ``pipeline`` tags the owning pipeline when the plan is one slice of a
    shared-cluster fleet plan (core/fleet.py): each scheduling unit then
    carries ``(pipeline, placement_type)``.  Single-tenant plans leave it
    empty — the 1-pipeline special case.
    """
    placements: List[str]                 # index = unit id
    unit_size: int = 1                    # chips per unit (App. E.2 MP fold)
    units_per_node: int = 8               # 8-chip nodes / unit_size
    pipeline: str = ""                    # owning pipeline in a fleet plan

    def __post_init__(self):
        assert all(p in PLACEMENT_TYPES for p in self.placements)

    def tagged(self, unit: int) -> Tuple[str, str]:
        """(pipeline, placement_type) of one scheduling unit."""
        return (self.pipeline, self.placements[unit])

    @property
    def num_units(self) -> int:
        return len(self.placements)

    def node_of(self, unit: int) -> int:
        return unit // self.units_per_node

    def _index(self):
        """Lazy unit indices (plans are immutable after construction except
        for the fleet's unit-lending overlay, which invalidates the cache):
        these lookups run on every scheduler wake-up."""
        idx = self.__dict__.get("_idx")
        if idx is None:
            inactive = self.__dict__.get("_inactive") or ()
            decomm = self.__dict__.get("_decommissioned") or ()
            by_type: Dict[str, List[int]] = {}
            with_stage: Dict[str, List[int]] = {}
            for g, p in enumerate(self.placements):
                if g in inactive or g in decomm:
                    continue
                by_type.setdefault(p, []).append(g)
                for s in p:
                    with_stage.setdefault(s, []).append(g)
            primary = frozenset(g for g, p in enumerate(self.placements)
                                if p in PRIMARY_PLACEMENTS
                                and g not in inactive and g not in decomm)
            tsets = {p: frozenset(gs) for p, gs in by_type.items()}
            idx = self.__dict__["_idx"] = (by_type, with_stage, primary,
                                           tsets)
        return idx

    def type_set(self, ptype: str) -> FrozenSet[int]:
        """``units_of_type`` as a frozenset — for C-speed intersections
        with the engine's idle set on the dispatch hot path (same active
        view, same cache invalidation)."""
        return self._index()[3].get(ptype, frozenset())

    # -- fleet unit-lending overlay (core/lending.py) -------------------------

    def extend(self, ptype: str) -> int:
        """Append one scheduling unit (a borrowed foreign unit hosting E/C
        work for this plan's pipeline); returns its unit id.  Only the fleet
        lending broker calls this — single-tenant plans never grow.
        Extended units are an *overlay*: dispatch indices see them while
        active, but ``type_histogram``/``count_of_type`` never count them
        (they describe the plan's own layout, e.g. for ``maybe_replace``'s
        no-op comparison against a freshly generated plan)."""
        assert ptype in PLACEMENT_TYPES
        self.placements.append(ptype)
        self.__dict__.setdefault("_extended", set()).add(len(self.placements) - 1)
        self.__dict__.pop("_idx", None)
        return len(self.placements) - 1

    def set_active(self, unit: int, active: bool) -> None:
        """(De)activate one unit in the dispatch indices.  A lender's unit
        disappears from its own plan while on loan; a borrower's loan slot
        disappears once the unit is returned.  ``placements[unit]`` stays
        valid either way, so engine bookkeeping keeps working."""
        inactive = self.__dict__.setdefault("_inactive", set())
        if active:
            inactive.discard(unit)
        else:
            inactive.add(unit)
        self.__dict__.pop("_idx", None)

    def is_active(self, unit: int) -> bool:
        return unit not in (self.__dict__.get("_inactive") or ())

    def is_extended(self, unit: int) -> bool:
        """True for loan-slot overlay units (not part of the own layout)."""
        return unit in (self.__dict__.get("_extended") or ())

    # -- elastic capacity overlay (core/elastic.py) ---------------------------

    def decommission(self, unit: int) -> None:
        """Remove one unit from the dispatch indices without touching the
        plan's own layout: a doomed unit draining ahead of a preemption
        notice, or a quarantined slow-failing unit.  Unlike ``set_active``
        — the lending overlay, which loan close/revive freely toggles — a
        decommissioned unit stays out until ``commission``:
        ``set_active(unit, True)`` cannot resurrect it.  Counted by
        ``count_of_type``/``type_histogram`` like an inactive unit (the
        layout still owns the chips until a re-partition reassigns them),
        so ``maybe_replace``'s no-op comparison does not churn."""
        self.__dict__.setdefault("_decommissioned", set()).add(unit)
        self.__dict__.pop("_idx", None)

    def commission(self, unit: int) -> None:
        """Undo ``decommission`` (a quarantined unit recovering)."""
        decomm = self.__dict__.get("_decommissioned")
        if decomm is not None:
            decomm.discard(unit)
        self.__dict__.pop("_idx", None)

    def is_decommissioned(self, unit: int) -> bool:
        return unit in (self.__dict__.get("_decommissioned") or ())

    def units_with(self, stage: str) -> List[int]:
        return self._index()[1].get(stage, [])

    def units_of_type(self, ptype: str) -> List[int]:
        return self._index()[0].get(ptype, [])

    @property
    def primary_units(self) -> FrozenSet[int]:
        """Units whose placement carries the D stage."""
        return self._index()[2]

    def retype(self, unit: int, ptype: str) -> None:
        """Change one unit's placement type (loan-slot reuse)."""
        assert ptype in PLACEMENT_TYPES
        self.placements[unit] = ptype
        self.__dict__.pop("_idx", None)

    def count_of_type(self, ptype: str) -> int:
        """Count over the plan's *own* layout: loan-slot overlay units are
        excluded, and a lent-out (inactive) unit still counts — the layout
        owns it even while its chips are on loan.  Dispatch-time candidate
        sets use ``units_of_type`` instead, which is the active view."""
        ext = self.__dict__.get("_extended") or ()
        return sum(1 for g, p in enumerate(self.placements)
                   if p == ptype and g not in ext)

    def type_histogram(self) -> Dict[str, int]:
        return {t: self.count_of_type(t) for t in PLACEMENT_TYPES
                if self.count_of_type(t)}

    def copy(self) -> "PlacementPlan":
        return PlacementPlan(list(self.placements), self.unit_size,
                             self.units_per_node, self.pipeline)
