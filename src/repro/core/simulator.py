"""Discrete-event cluster simulator driving the real planner + engine code.

The simulator owns the clock and the arrival trace; *all* scheduling logic
(Orchestrator, Dispatcher, Monitor, Adjust-on-Dispatch, the baselines) is
the production code from this package — only stage execution latencies come
from the Profiler's cost model instead of wall-clock TPU runs.  This is the
substrate behind every paper figure reproduction (Fig. 10-15, Table 4).

Two clock modes share one per-step body (admit arrivals -> drain completion
events -> maybe re-place -> dispatch):

* ``tick`` — the original fixed-step loop: the scheduler runs every
  ``SimConfig.tick`` seconds across the whole horizon, O(horizon/tick).
* ``event`` (default) — an event-heap-driven clock: the scheduler only
  wakes when state can change — the next arrival, the next stage
  completion (which is also when units cross their ``free_at``), the next
  Monitor-window boundary, or a ``max_idle_gap`` cap that preserves
  periodic re-placement/aging checks while requests are pending.  Wake-ups
  are quantized *up* to the same tick grid, so on traces where the skipped
  ticks are no-ops the two modes produce bit-identical results
  (tests/test_event_sim.py) at O(events) cost.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import repro.configs as configs
from repro.core.monitor import Monitor
from repro.core.placement import PlacementPlan
from repro.core.profiler import HBM_BYTES, Profiler
from repro.core.request import Request
from repro.core.runtime import RuntimeEngine
from repro.core.dispatcher import DispatchDecision


@dataclasses.dataclass
class SimConfig:
    num_chips: int = 128
    tick: float = 0.25
    horizon_slack: float = 600.0      # grace period after the last arrival
    proactive_push: bool = True
    adjust_on_dispatch: bool = True
    downtime_adjust: bool = False     # Fig. 13 ablation
    seed: int = 0
    mode: str = "event"               # "event" (O(events)) | "tick" (legacy)
    max_idle_gap: float = 1.0         # event mode: max clock jump while
                                      # requests are pending (keeps periodic
                                      # re-placement/aging checks alive)
    adaptive_idle_gap: bool = False   # profile-guided heartbeat: double the
                                      # gap while no pending request crosses
                                      # its deadline (no aging flips), reset
                                      # to max_idle_gap when one does
    idle_gap_max: float = 16.0        # ceiling for the adaptive gap (s)
    idle_window_wakeups: bool = False # event mode: keep Monitor-window
                                      # boundary wake-ups scheduled even
                                      # while nothing is pending/in-flight,
                                      # so a pattern change during an idle
                                      # gap is seen before the window drains
                                      # below MIN_SAMPLES (stale-window fix;
                                      # opt-in, used by the fleet clock)


@dataclasses.dataclass
class SimResult:
    scheduler: str
    pipeline: str
    workload: str
    oom: bool
    n_requests: int
    n_finished: int
    n_request_oom: int
    slo_attainment: float
    mean_latency: float
    p95_latency: float
    throughput_timeline: List[Tuple[float, int]]
    placement_switches: List[Tuple[float, Dict[str, int]]]
    vr_histogram: Dict[int, int]
    engine_stats: Dict[str, float]
    solver_ms: float = 0.0
    sched_wakeups: int = 0            # scheduler invocations (event vs tick)

    def summary(self) -> str:
        if self.oom:
            return (f"{self.scheduler:10s} {self.pipeline:12s} {self.workload:11s} "
                    f"OOM (colocated placement exceeds HBM)")
        return (f"{self.scheduler:10s} {self.pipeline:12s} {self.workload:11s} "
                f"SLO={self.slo_attainment * 100:5.1f}%  "
                f"mean={self.mean_latency:7.2f}s  p95={self.p95_latency:7.2f}s  "
                f"fin={self.n_finished}/{self.n_requests}")


class PendingSet:
    """Arrival-ordered, rid-indexed set of pending requests.

    Backed by an insertion-ordered dict so dispatch bookkeeping is O(1) per
    removal instead of the O(n) ``list.remove`` scans the tick loop did;
    iteration yields requests in arrival (admission) order.
    """

    __slots__ = ("_by_rid",)

    def __init__(self, reqs: Sequence[Request] = ()):
        self._by_rid: Dict[int, Request] = {r.rid: r for r in reqs}

    def add(self, req: Request) -> None:
        self._by_rid[req.rid] = req

    append = add   # drop-in for the old list-based field

    def remove(self, req: Request) -> None:
        del self._by_rid[req.rid]

    def discard(self, req: Request) -> None:
        self._by_rid.pop(req.rid, None)

    def has_rid(self, rid: int) -> bool:
        return rid in self._by_rid

    def __contains__(self, req: Request) -> bool:
        return req.rid in self._by_rid

    def __iter__(self) -> Iterator[Request]:
        return iter(self._by_rid.values())

    def __len__(self) -> int:
        return len(self._by_rid)

    def __bool__(self) -> bool:
        return bool(self._by_rid)


class Scheduler:
    """Interface implemented by TridentServe and the B1-B6 baselines."""

    name = "base"

    def __init__(self, prof: Profiler, sim_cfg: SimConfig, trace: Sequence[Request]):
        self.prof = prof
        self.sim_cfg = sim_cfg
        self.trace = trace

    def initial_placement(self) -> Optional[PlacementPlan]:
        raise NotImplementedError

    def tick(self, sim: "Simulator", tau: float) -> List[DispatchDecision]:
        raise NotImplementedError

    def maybe_replace(self, sim: "Simulator", tau: float) -> Optional[PlacementPlan]:
        return None


# completion event: (finish, seq, stage, placement type, duration, request)
Event = Tuple[float, int, str, str, float, Request]


class Simulator:
    def __init__(self, pipeline_id: str, scheduler: Scheduler,
                 trace: Sequence[Request], sim_cfg: SimConfig):
        self.pipeline_id = pipeline_id
        self.scheduler = scheduler
        self.trace = sorted(trace, key=lambda r: r.arrival)
        self.cfg = sim_cfg
        self.prof = scheduler.prof
        self.pending = PendingSet()          # arrived, not yet dispatched
        self.new_arrivals: List[Request] = []  # admitted since the last step
        self.engine: Optional[RuntimeEngine] = None
        self.monitor = Monitor()
        self._events: List[Event] = []       # stage-completion heap
        self._eseq = 0
        self.vr_histogram: Dict[int, int] = {}
        self.placement_log: List[Tuple[float, Dict[str, int]]] = []
        self.throughput: Dict[int, int] = {}
        self.request_oom: List[Request] = []
        self.sched_wakeups = 0
        # profile-guided heartbeat: deadlines of pending requests, drained
        # as the clock passes them to observe aging flips (adaptive mode)
        self._track_flips = (sim_cfg.mode == "event"
                             and sim_cfg.adaptive_idle_gap)
        self._dl_heap: List[Tuple[float, int]] = []
        # monitor-window wake-ups only matter to schedulers that re-place
        self._replace_capable = (type(scheduler).maybe_replace
                                 is not Scheduler.maybe_replace)

    # ---------------------------------------------------------------- helpers

    def record_decision(self, dec: DispatchDecision,
                        times: Dict[str, Tuple[float, float]]):
        members = (dec.request,) + tuple(getattr(dec, "corequests", ()))
        for s, (start, fin) in times.items():
            for req in members:
                req.stage_done[s] = fin
            ptype = self.engine.plan.placements[
                (dec.d_units if s == "D" else
                 dec.e_units if s == "E" else dec.c_units)[0]]
            heapq.heappush(self._events,
                           (fin, self._eseq, s, ptype, fin - start, dec.request))
            self._eseq += 1
        self.vr_histogram[dec.vr_type] = (self.vr_histogram.get(dec.vr_type, 0)
                                          + len(members))

    def fail_request_oom(self, req: Request):
        self.request_oom.append(req)

    # ---------------------------------------------------------------- main loop

    def run(self) -> SimResult:
        plan = self.scheduler.initial_placement()
        if plan is None:   # no feasible placement (e.g. colocated OOM)
            return self._oom_result()
        self.engine = RuntimeEngine(
            self.prof, plan, proactive_push=self.cfg.proactive_push,
            adjust_on_dispatch=self.cfg.adjust_on_dispatch)
        self.placement_log.append((0.0, plan.type_histogram()))
        if self.cfg.mode == "tick":
            self._run_tick()
        else:
            self._run_event()
        return self._result()

    # -- one scheduler step (shared by both clock modes) ----------------------

    def _admit(self, tau: float, ai: int) -> int:
        new: List[Request] = []
        trace = self.trace
        while ai < len(trace) and trace[ai].arrival <= tau:
            self.pending.add(trace[ai])
            new.append(trace[ai])
            if self._track_flips:
                heapq.heappush(self._dl_heap, (trace[ai].deadline,
                                               trace[ai].rid))
            ai += 1
        self.new_arrivals = new
        return ai

    def _aging_flips(self, tau: float) -> int:
        """Deadlines crossed up to ``tau`` among still-pending requests —
        the events that change dispatch rewards while nothing else moves.
        The observed flip rate steers the heartbeat gap (profile-guided
        ``max_idle_gap``): no flips -> the gap doubles, a flip -> reset."""
        flips = 0
        heap = self._dl_heap
        while heap and heap[0][0] <= tau:
            _, rid = heapq.heappop(heap)
            if self.pending.has_rid(rid):
                flips += 1
        return flips

    def _drain_events(self, tau: float) -> None:
        """Feed completion events up to ``tau`` into the Monitor."""
        while self._events and self._events[0][0] <= tau:
            t, _, s, ptype, dur, req = heapq.heappop(self._events)
            self.monitor.record_stage(t, s, ptype, dur)
            if s == "C":
                self.throughput[int(t // 60)] = self.throughput.get(int(t // 60), 0) + 1

    def _step(self, tau: float) -> None:
        """Placement switch check + dispatch at time ``tau``."""
        self.sched_wakeups += 1
        new_plan = self.scheduler.maybe_replace(self, tau)
        if new_plan is not None:
            self.engine.apply_placement(new_plan, tau,
                                        downtime_adjust=self.cfg.downtime_adjust)
            self.placement_log.append((tau, new_plan.type_histogram()))
        for dec in self.scheduler.tick(self, tau):
            times = self.engine.execute(dec, tau)
            self.record_decision(dec, times)
            self.pending.remove(dec.request)
            for co in getattr(dec, "corequests", ()):
                self.pending.remove(co)

    def _horizon(self) -> float:
        trace_end = self.trace[-1].arrival if self.trace else 0.0
        return trace_end + self.cfg.horizon_slack

    def _done(self, ai: int) -> bool:
        return ai >= len(self.trace) and not self.pending and not self._events

    # -- legacy fixed-tick clock (reference for the equivalence tests) --------

    def _run_tick(self) -> None:
        tick = self.cfg.tick
        horizon = self._horizon()
        ai = 0
        i = 0
        while i * tick <= horizon:
            tau = i * tick
            ai = self._admit(tau, ai)
            self._drain_events(tau)
            self._step(tau)
            if self._done(ai):
                break
            i += 1

    # -- event-heap-driven clock ----------------------------------------------

    def _run_event(self) -> None:
        """Jump the clock between the times state can actually change.

        Wake-up candidates: next arrival, next stage-completion event (unit
        ``free_at`` crossings always coincide with one), the next
        Monitor-window boundary, and — only while requests are pending, since
        dispatch rewards/aging depend on tau — a ``max_idle_gap`` heartbeat.
        Each wake-up is quantized up to the tick grid so dispatch timestamps
        land exactly where the tick clock would have placed them.
        """
        tick = self.cfg.tick
        horizon = self._horizon()
        gap_base = max(self.cfg.max_idle_gap, tick)
        gap_max = max(self.cfg.idle_gap_max, gap_base)
        gap = gap_base
        ai = 0
        i = 0
        while i * tick <= horizon:
            tau = i * tick
            ai = self._admit(tau, ai)
            self._drain_events(tau)
            self._step(tau)
            if self._done(ai):
                break
            if self._track_flips:
                gap = (gap_base if self._aging_flips(tau)
                       else min(gap * 2.0, gap_max))
            t_next = math.inf
            if ai < len(self.trace):
                t_next = self.trace[ai].arrival
            if self._events:
                t_next = min(t_next, self._events[0][0])
            if self._replace_capable and (self.pending or self._events
                                          or self.cfg.idle_window_wakeups):
                boundary = self.monitor.next_window_boundary()
                if boundary is not None and boundary > tau:
                    t_next = min(t_next, boundary)
            if self.pending:
                t_next = min(t_next, tau + gap)
            if t_next is math.inf:
                break   # nothing can ever change state again
            # quantize up to the tick grid; always advance at least one tick
            i = max(i + 1, int(math.ceil(t_next / tick - 1e-9)))

    # ---------------------------------------------------------------- results

    def _oom_result(self) -> SimResult:
        return SimResult(
            scheduler=self.scheduler.name, pipeline=self.pipeline_id,
            workload="", oom=True, n_requests=len(self.trace), n_finished=0,
            n_request_oom=len(self.trace), slo_attainment=0.0,
            mean_latency=float("inf"), p95_latency=float("inf"),
            throughput_timeline=[], placement_switches=[], vr_histogram={},
            engine_stats={})

    def _result(self) -> SimResult:
        lat = []
        on_time = 0
        finished = 0
        oom_ids = {r.rid for r in self.request_oom}
        horizon_lat = (self.trace[-1].arrival + self.cfg.horizon_slack
                       if self.trace else 0.0)
        for r in self.trace:
            if r.rid in oom_ids:
                lat.append(horizon_lat)
                continue
            if r.finished:
                finished += 1
                lat.append(r.latency)
                on_time += int(r.on_time)
            else:
                lat.append(horizon_lat - r.arrival)  # censored
        lat_sorted = sorted(lat)
        n = len(lat_sorted)
        stats = dataclasses.asdict(self.engine.stats) if self.engine else {}
        return SimResult(
            scheduler=self.scheduler.name, pipeline=self.pipeline_id,
            workload="", oom=False, n_requests=n, n_finished=finished,
            n_request_oom=len(self.request_oom),
            slo_attainment=on_time / max(1, n),
            mean_latency=sum(lat) / max(1, n),
            p95_latency=lat_sorted[int(0.95 * (n - 1))] if n else 0.0,
            throughput_timeline=sorted((60.0 * b, c) for b, c in self.throughput.items()),
            placement_switches=self.placement_log,
            vr_histogram=dict(self.vr_histogram),
            engine_stats=stats,
            sched_wakeups=self.sched_wakeups)


def run_sim(pipeline_id: str, scheduler_cls, workload: str, duration: float,
            sim_cfg: Optional[SimConfig] = None, seed: int = 0,
            rate: Optional[float] = None, slo_scale: Optional[float] = None,
            cross_node_sp: bool = False, **sched_kw) -> SimResult:
    """Convenience: build profiler + trace + scheduler and run."""
    from repro.core import workloads
    sim_cfg = sim_cfg or SimConfig(seed=seed)
    pcfg = configs.get(pipeline_id)
    prof = Profiler(pcfg, force_k_min=getattr(scheduler_cls, "FORCE_KMIN", None),
                    cross_node_sp=cross_node_sp)
    kw = {} if slo_scale is None else {"slo_scale": slo_scale}
    trace = workloads.make_trace(pipeline_id, workload, duration, prof,
                                 seed=seed, rate=rate, **kw)
    sched = scheduler_cls(prof, sim_cfg, trace, **sched_kw)
    sim = Simulator(pipeline_id, sched, trace, sim_cfg)
    res = sim.run()
    res.workload = workload
    return res
