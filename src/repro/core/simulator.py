"""Discrete-event cluster simulator driving the real planner + engine code.

The simulator owns the arrival trace; *all* scheduling logic (Orchestrator,
Dispatcher, Monitor, Adjust-on-Dispatch, the baselines) is the production
code from this package — only stage execution latencies come from the
Profiler's cost model instead of wall-clock TPU runs.  This is the
substrate behind every paper figure reproduction (Fig. 10-15, Table 4).

The clock itself lives in ``repro.core.clock``: ``Simulator`` is a thin
one-lane ``ClockDriver`` over the shared ``EventClock`` kernel (the same
kernel ``FleetSimulator`` drives with many lanes).  Two clock modes share
one per-step body (admit arrivals -> drain completion events -> maybe
re-place -> dispatch):

* ``tick`` — the original fixed-step loop: the scheduler runs every
  ``SimConfig.tick`` seconds across the whole horizon, O(horizon/tick).
* ``event`` (default) — the event-heap-driven clock: the scheduler only
  wakes when state can change — the next arrival, the next stage
  completion (which is also when units cross their ``free_at``), the next
  Monitor-window boundary, or a ``max_idle_gap`` cap that preserves
  periodic re-placement/aging checks while requests are pending.  Wake-ups
  are quantized *up* to the same tick grid, so on traces where the skipped
  ticks are no-ops the two modes produce bit-identical results
  (tests/test_event_sim.py) at O(events) cost.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import repro.configs as configs
# PendingSet and Scheduler live in the kernel module now; re-exported here
# because every scheduler and half the test suite imports them from this
# module's original home.
from repro.core.clock import (ClockConfig, EventClock, Lane, PendingSet,
                              Scheduler, monitor_boundary_source,
                              replace_capable)
from repro.core.profiler import Profiler
from repro.core.request import Request
from repro.core.runtime import RuntimeEngine
from repro.core.dispatcher import DispatchDecision

__all__ = ["SimConfig", "SimResult", "PendingSet", "Scheduler", "Simulator",
           "run_sim"]


@dataclasses.dataclass
class SimConfig:
    num_chips: int = 128
    tick: float = 0.25
    horizon_slack: float = 600.0      # grace period after the last arrival
    proactive_push: bool = True
    adjust_on_dispatch: bool = True
    downtime_adjust: bool = False     # Fig. 13 ablation
    seed: int = 0
    mode: str = "event"               # "event" (O(events)) | "tick" (legacy)
    max_idle_gap: float = 1.0         # event mode: max clock jump while
                                      # requests are pending (keeps periodic
                                      # re-placement/aging checks alive)
    adaptive_idle_gap: bool = False   # profile-guided heartbeat: double the
                                      # gap while no pending request crosses
                                      # its deadline (no aging flips), reset
                                      # to max_idle_gap when one does
    idle_gap_max: float = 16.0        # ceiling for the adaptive gap (s)
    idle_window_wakeups: bool = False # event mode: keep Monitor-window
                                      # boundary wake-ups scheduled even
                                      # while nothing is pending/in-flight,
                                      # so a pattern change during an idle
                                      # gap is seen before the window drains
                                      # below MIN_SAMPLES (stale-window fix;
                                      # opt-in, used by the fleet clock)
    scheduler_wake_hooks: bool = False # event mode: register the scheduler's
                                      # ``next_wake`` trigger-crossing hook
                                      # as a kernel wake source.  Opt-in:
                                      # extra wake-ups (even no-op ones)
                                      # shift heartbeat phase, so the
                                      # default keeps committed traces
                                      # bit-exact.
    array_state: bool = False         # array-backed lane state: flat numpy
                                      # deadline/window columns behind
                                      # PendingSet/Monitor instead of
                                      # per-request Python object walks.
                                      # Bit-identical trajectories by
                                      # construction (stable argsort +
                                      # same-order incremental sums);
                                      # pinned by tests/test_scale_parity.py.

    def clock_cfg(self, horizon: float) -> ClockConfig:
        return ClockConfig(tick=self.tick, horizon=horizon, mode=self.mode,
                           max_idle_gap=self.max_idle_gap,
                           adaptive_idle_gap=self.adaptive_idle_gap,
                           idle_gap_max=self.idle_gap_max)


@dataclasses.dataclass
class SimResult:
    scheduler: str
    pipeline: str
    workload: str
    oom: bool
    n_requests: int
    n_finished: int
    n_request_oom: int
    slo_attainment: float
    mean_latency: float
    p95_latency: float
    throughput_timeline: List[Tuple[float, int]]
    placement_switches: List[Tuple[float, Dict[str, int]]]
    vr_histogram: Dict[int, int]
    engine_stats: Dict[str, float]
    solver_ms: float = 0.0
    sched_wakeups: int = 0            # scheduler invocations (event vs tick)

    def summary(self) -> str:
        if self.oom:
            return (f"{self.scheduler:10s} {self.pipeline:12s} {self.workload:11s} "
                    f"OOM (colocated placement exceeds HBM)")
        return (f"{self.scheduler:10s} {self.pipeline:12s} {self.workload:11s} "
                f"SLO={self.slo_attainment * 100:5.1f}%  "
                f"mean={self.mean_latency:7.2f}s  p95={self.p95_latency:7.2f}s  "
                f"fin={self.n_finished}/{self.n_requests}")


class Simulator(Lane):
    """One-lane driver over the shared event-clock kernel.

    ``Simulator`` *is* its own Lane (the scheduler sees ``sim.pending`` /
    ``sim.engine`` / ``sim.monitor`` exactly as before) and implements the
    ``ClockDriver`` protocol; all loop mechanics — the completion heap,
    tick-grid quantization, heartbeat and adaptive idle gap — live in
    ``repro.core.clock.EventClock``.
    """

    def __init__(self, pipeline_id: str, scheduler: Scheduler,
                 trace: Sequence[Request], sim_cfg: SimConfig):
        super().__init__(pipeline_id, scheduler.prof, scheduler,
                         array_state=sim_cfg.array_state)
        self.pipeline_id = pipeline_id
        self.scheduler = scheduler     # alias of ``self.sched``
        self.trace = sorted(trace, key=lambda r: r.arrival)
        self.cfg = sim_cfg
        self.clock = EventClock(sim_cfg.clock_cfg(self._horizon()))
        self._ai = 0                   # arrival cursor into the trace
        self._track_flips = (sim_cfg.mode == "event"
                             and sim_cfg.adaptive_idle_gap)
        self.clock.add_source(self._next_arrival)
        # monitor-window wake-ups only matter to schedulers that re-place
        if replace_capable(scheduler):
            self.clock.add_source(monitor_boundary_source(
                self.monitor,
                lambda: bool(self.pending or self.clock.completions
                             or self.cfg.idle_window_wakeups)))
        if sim_cfg.scheduler_wake_hooks:
            self.clock.add_source(lambda tau: scheduler.next_wake(self, tau))

    # ---------------------------------------------------------------- helpers

    @property
    def _events(self):
        """The kernel's completion heap (kept for tests/introspection)."""
        return self.clock.completions

    @property
    def sched_wakeups(self) -> int:
        return self.clock.wakeups

    def record_decision(self, dec: DispatchDecision,
                        times: Dict[str, Tuple[float, float]]):
        self.record(dec, times, self.clock)

    def _horizon(self) -> float:
        trace_end = self.trace[-1].arrival if self.trace else 0.0
        return trace_end + self.cfg.horizon_slack

    def _next_arrival(self, tau: float) -> Optional[float]:
        if self._ai < len(self.trace):
            return self.trace[self._ai].arrival
        return None

    # ---------------------------------------------------------------- driver

    def advance(self, tau: float) -> None:
        """Admit arrivals, drain completions, run one scheduler step."""
        self.new_arrivals = []
        trace = self.trace
        n = len(trace)
        ai = self._ai
        clock = self.clock if self._track_flips else None
        while ai < n and trace[ai].arrival <= tau:
            self.admit(trace[ai], clock)
            ai += 1
        self._ai = ai
        for t, _, _, s, ptype, dur, _, _ in self.clock.pop_due(tau):
            self.on_completion(t, s, ptype, dur)
        self.step(tau, self.clock, self._apply_replacement)

    def _apply_replacement(self, new_plan, tau: float) -> None:
        self.engine.apply_placement(new_plan, tau,
                                    downtime_adjust=self.cfg.downtime_adjust)

    def done(self) -> bool:
        return (self._ai >= len(self.trace) and not self.pending
                and not self.clock.completions)

    def heartbeat_pending(self) -> bool:
        return bool(self.pending)

    def still_pending(self, lane: str, rid: int) -> bool:
        return self.pending.has_rid(rid)

    # ---------------------------------------------------------------- main

    def run(self) -> SimResult:
        # single-run objects: the arrival cursor, wake sources, and the
        # trace's Request objects all carry state a second run would
        # silently corrupt — fail loudly instead
        assert self.clock.wakeups == 0, "Simulator instances are single-run"
        plan = self.scheduler.initial_placement()
        if plan is None:   # no feasible placement (e.g. colocated OOM)
            return self._oom_result()
        self.engine = RuntimeEngine(
            self.prof, plan, proactive_push=self.cfg.proactive_push,
            adjust_on_dispatch=self.cfg.adjust_on_dispatch)
        self.placement_log.append((0.0, plan.type_histogram()))
        self.clock.run(self)
        return self._result()

    # ---------------------------------------------------------------- results

    def _oom_result(self) -> SimResult:
        return SimResult(
            scheduler=self.scheduler.name, pipeline=self.pipeline_id,
            workload="", oom=True, n_requests=len(self.trace), n_finished=0,
            n_request_oom=len(self.trace), slo_attainment=0.0,
            mean_latency=float("inf"), p95_latency=float("inf"),
            throughput_timeline=[], placement_switches=[], vr_histogram={},
            engine_stats={})

    def _result(self) -> SimResult:
        lat = []
        on_time = 0
        finished = 0
        oom_ids = {r.rid for r in self.request_oom}
        horizon_lat = (self.trace[-1].arrival + self.cfg.horizon_slack
                       if self.trace else 0.0)
        for r in self.trace:
            if r.rid in oom_ids:
                lat.append(horizon_lat)
                continue
            if r.finished:
                finished += 1
                lat.append(r.latency)
                on_time += int(r.on_time)
            else:
                lat.append(horizon_lat - r.arrival)  # censored
        lat_sorted = sorted(lat)
        n = len(lat_sorted)
        stats = dataclasses.asdict(self.engine.stats) if self.engine else {}
        return SimResult(
            scheduler=self.scheduler.name, pipeline=self.pipeline_id,
            workload="", oom=False, n_requests=n, n_finished=finished,
            n_request_oom=len(self.request_oom),
            slo_attainment=on_time / max(1, n),
            mean_latency=sum(lat) / max(1, n),
            p95_latency=lat_sorted[int(0.95 * (n - 1))] if n else 0.0,
            throughput_timeline=sorted((60.0 * b, c) for b, c in self.throughput.items()),
            placement_switches=self.placement_log,
            vr_histogram=dict(self.vr_histogram),
            engine_stats=stats,
            sched_wakeups=self.clock.wakeups)


def run_sim(pipeline_id: str, scheduler_cls, workload: str, duration: float,
            sim_cfg: Optional[SimConfig] = None, seed: int = 0,
            rate: Optional[float] = None, slo_scale: Optional[float] = None,
            cross_node_sp: bool = False, **sched_kw) -> SimResult:
    """Convenience: build profiler + trace + scheduler and run."""
    from repro.core import workloads
    sim_cfg = sim_cfg or SimConfig(seed=seed)
    pcfg = configs.get(pipeline_id)
    prof = Profiler(pcfg, force_k_min=getattr(scheduler_cls, "FORCE_KMIN", None),
                    cross_node_sp=cross_node_sp)
    kw = {} if slo_scale is None else {"slo_scale": slo_scale}
    trace = workloads.make_trace(pipeline_id, workload, duration, prof,
                                 seed=seed, rate=rate, **kw)
    sched = scheduler_cls(prof, sim_cfg, trace, **sched_kw)
    sim = Simulator(pipeline_id, sched, trace, sim_cfg)
    res = sim.run()
    res.workload = workload
    return res
