"""Offline Profiler (§5.1): latency/memory statistics per stage × degree.

On real hardware this measures; here it *derives* the tables from a
roofline-style analytic model over the actual JAX model configs (param
bytes come from ``jax.eval_shape`` over the real ``init`` functions, so
they are exact) with TPU v5e constants.  The same model backs the
discrete-event simulator, so planner decisions and "measured" outcomes are
consistent — which is precisely the paper's strong-predictability premise
[§5.1: "Leveraging the strong predictability of execution time and memory
footprint in GVT workloads"].

Calibration targets (validated in tests/test_profiler.py):
  * Diffuse scales well with SP at high resolution, poorly at low (Fig. 3);
  * Decode is memory/ICI-bound and scales poorly (Fig. 3);
  * Encode barely benefits from parallelism (§3);
  * Diffuse dominates end-to-end time (> 70%, §2.1/Fig. 8).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax

from repro.core.request import Request
from repro.models import diffusion, pipeline as pipe_lib, transformer

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
DCN_BW = 25e9                # bytes/s per host (inter-pod)
HOST_BW = 10e9               # host<->device staging path
HBM_BYTES = 16 * 2 ** 30     # 16 GiB
MEM_RESERVE = 512 * 2 ** 20  # per-chip runtime reserve (compiler scratch etc.)
MFU = 0.5                    # sustained matmul efficiency (long sequences)
MFU_CONV = 0.12              # conv stacks (<=128ch) utilize the MXU poorly
SEQ_MFU_KNEE = 384           # per-chip tokens below which MFU degrades
DISPATCH_OVERHEAD = 0.004    # s, per-dispatch CPU scheduling cost
COMM_GROUP_INIT = 0.05       # s, lazy (non-hot-set) communicator build


def _seq_mfu(l_per_chip: float) -> float:
    """MFU falls off when the per-chip sequence shard is small — sliced
    matmuls stop saturating the MXU.  This is what makes low-resolution
    requests prefer small SP degrees (Fig. 3's crossing curves)."""
    return MFU * l_per_chip / (l_per_chip + SEQ_MFU_KNEE)

PARALLEL_DEGREES = (1, 2, 4, 8, 16, 32)  # >8 reachable only with cross-node SP
EFFICIENCY_THRESHOLD = 0.8   # paper footnote 4/5


def _count_bytes(shapes) -> int:
    return sum(int(x.size) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(shapes))


@dataclasses.dataclass(frozen=True)
class StageModelInfo:
    params: int          # parameter count
    bytes: int           # parameter bytes
    num_layers: int
    d_model: int


class Profiler:
    """Cost/memory model for one diffusion pipeline."""

    def __init__(self, cfg: pipe_lib.PipelineConfig,
                 force_k_min: Optional[int] = None,
                 cross_node_sp: bool = False):
        self.cfg = cfg
        self.info = self._stage_infos(cfg)
        # force_k_min=1 models baselines that do not use the App.-E.2 MP fold
        self.k_min = force_k_min if force_k_min else self._compute_k_min()
        # SP instances are intra-node in the paper (§6.2, a PCIe-box
        # constraint); on a TPU pod ICI spans every chip, so cross-node SP
        # is viable (beyond-paper; measured in EXPERIMENTS.md §Perf) —
        # degrees then extend to 32 units, still filtered by efficiency
        self.cross_node_sp = cross_node_sp
        base = max(1, 8 // self.k_min)
        self.max_degree_units = 32 // self.k_min if cross_node_sp else base
        # memo tables keyed by request class — request mixes repeat heavily,
        # exactly the paper's "pre-profiled candidate resolutions" (§5.1)
        self._time_memo: Dict[Tuple, float] = {}
        self._deg_memo: Dict[Tuple, int] = {}
        self._fits_memo: Dict[Tuple, bool] = {}
        self._batch_memo: Dict[Tuple, float] = {}

    @staticmethod
    def _class_key(req: Request) -> Tuple:
        """Workload-class memo key: (pipeline, resolution, seconds) + prompt."""
        return req.key() + (req.cond_len,)

    # -- static model facts --------------------------------------------------

    @staticmethod
    @functools.lru_cache(maxsize=None)
    def _stage_infos_cached(cfg: pipe_lib.PipelineConfig):
        key = jax.random.PRNGKey(0)
        enc = jax.eval_shape(lambda k: transformer.init(cfg.encoder, k), key)
        dit = jax.eval_shape(lambda k: diffusion.init(cfg.dit, k), key)
        dec = jax.eval_shape(lambda k: diffusion.init_decoder(cfg.decoder, k), key)
        mk = lambda tree, nl, dm: StageModelInfo(
            params=sum(int(x.size) for x in jax.tree_util.tree_leaves(tree)),
            bytes=_count_bytes(tree), num_layers=nl, d_model=dm)
        return {
            "E": mk(enc, cfg.encoder.num_layers, cfg.encoder.d_model),
            "D": mk(dit, cfg.dit.num_layers, cfg.dit.d_model),
            "C": mk(dec, cfg.decoder.num_upsamples, cfg.decoder.base_channels),
        }

    def _stage_infos(self, cfg):
        return self._stage_infos_cached(cfg)

    def _compute_k_min(self) -> int:
        """Smallest power-of-two chips/unit so the Diffusion model's MP shard
        fits one chip with headroom (App. E.2)."""
        need = self.info["D"].bytes * 1.25
        k = 1
        while need / k > HBM_BYTES * 0.9 and k < 8:
            k *= 2
        return k

    # -- workload geometry ----------------------------------------------------

    def proc_len(self, req: Request, stage: str) -> int:
        return pipe_lib.stage_proc_len(self.cfg, stage, req.resolution,
                                       req.seconds, req.cond_len)

    def latent_tokens(self, req: Request) -> int:
        return self.cfg.latent_tokens(req.resolution, req.seconds)

    # -- FLOPs / bytes per stage ----------------------------------------------

    def stage_flops(self, req: Request, stage: str) -> float:
        if stage == "E":
            i = self.info["E"]
            l = req.cond_len
            return 2.0 * i.params * l + 4.0 * i.num_layers * l * l * i.d_model
        if stage == "D":
            i = self.info["D"]
            l = self.latent_tokens(req) + req.cond_len
            per_step = 2.0 * i.params * l + 4.0 * i.num_layers * l * l * i.d_model
            return per_step * self.cfg.num_steps
        flops, _, _ = self._decoder_cost(req)
        return flops

    def _decoder_cost(self, req: Request) -> Tuple[float, float, float]:
        """(flops, activation_bytes, hbm_traffic) for the AE decoder.

        Models the *real* AE-KL decoder cost: residual conv blocks per level,
        3D (27-point) kernels + temporal upsampling for video — the JAX
        reference decoder is 2D-per-frame, but the serving planner must see
        the production decoder's cost profile (DESIGN.md §assumptions).
        """
        dec = self.cfg.decoder
        f_lat, h, w = self.cfg.latent_grid(req.resolution, req.seconds)
        side = 2 * h                       # after un-patchify
        kernel = 18 if self.cfg.is_video else 9  # video AEs use factorized 2+1D convs
        convs = 1 + 2 * dec.res_blocks     # per level (res blocks = 2 convs)
        flops = act = 0.0
        for lvl in range(dec.num_upsamples + 1):
            spatial = (side * (2 ** lvl)) ** 2
            frames = (f_lat * (2 ** min(lvl, 2))) if self.cfg.is_video else 1
            cc = max(dec.base_channels // (2 ** lvl), 128)
            flops += spatial * frames * cc * cc * kernel * 2 * convs
            act += spatial * frames * cc * 2 * convs
        return flops, act, self.info["C"].bytes + act * 2

    def stage_act_bytes(self, req: Request, stage: str) -> float:
        """Peak activation bytes at degree 1 (shards ~1/k with SP)."""
        if stage == "E":
            return req.cond_len * self.info["E"].d_model * 2 * 12
        if stage == "D":
            l = self.latent_tokens(req) + req.cond_len
            return l * self.info["D"].d_model * 2 * 24
        _, act, _ = self._decoder_cost(req)
        return act

    def stage_hbm_bytes(self, req: Request, stage: str) -> float:
        """Total HBM traffic (params re-read per step + activations)."""
        if stage == "E":
            return self.info["E"].bytes + self.stage_act_bytes(req, "E") * 2
        if stage == "D":
            return (self.info["D"].bytes + self.stage_act_bytes(req, "D") * 4
                    ) * self.cfg.num_steps
        _, _, hbm = self._decoder_cost(req)
        return hbm

    # -- latency model ---------------------------------------------------------

    def stage_time(self, req: Request, stage: str, k_chips: int) -> float:
        """Wall-clock estimate of stage ``stage`` at SP degree ``k_chips``."""
        key = (req.pipeline, req.resolution, req.seconds, req.cond_len,
               stage, k_chips)
        hit = self._time_memo.get(key)
        if hit is not None:
            return hit
        t = self._stage_time_impl(req, stage, k_chips)
        self._time_memo[key] = t
        return t

    def _stage_time_impl(self, req: Request, stage: str, k_chips: int) -> float:
        flops = self.stage_flops(req, stage)
        hbm = self.stage_hbm_bytes(req, stage)
        if stage == "E":
            # batching-friendly, parallelism-averse: capped speedup
            speed = min(k_chips, 1.3)
            return (max(flops / (PEAK_FLOPS * MFU), hbm / HBM_BW) / speed
                    + (k_chips - 1) * 2e-3 + DISPATCH_OVERHEAD)
        if stage == "D":
            i = self.info["D"]
            l = self.latent_tokens(req) + req.cond_len
            compute = flops / (k_chips * PEAK_FLOPS * _seq_mfu(l / k_chips))
            mem = hbm / (k_chips * HBM_BW)
            # Ulysses: 2 all-to-alls per layer per step; (k-1)/k^2 wire factor
            a2a = l * i.d_model * 2
            comm = (self.cfg.num_steps * i.num_layers * 2 * a2a
                    * (k_chips - 1) / (k_chips ** 2) / ICI_BW) if k_chips > 1 else 0.0
            return max(compute, mem) + comm + DISPATCH_OVERHEAD
        # Decode: conv pyramid; halo exchange + per-chip launch overhead make
        # spatial sharding scale poorly (paper Fig. 3 right)
        mem = hbm / (k_chips * HBM_BW)
        compute = flops / (k_chips * PEAK_FLOPS * MFU_CONV)
        comm = ((self.stage_act_bytes(req, "C") * 0.3 * (k_chips - 1)
                 / k_chips / ICI_BW) + (k_chips - 1) * 2e-3) if k_chips > 1 else 0.0
        return max(mem, compute) + comm + DISPATCH_OVERHEAD

    def batched_stage_time(self, req: Request, stage: str, k_chips: int,
                           batch: int) -> float:
        """Latency of serving ``batch`` identical requests in one run
        (App. E.1): compute-bound work amortizes per-item; activation
        traffic scales linearly."""
        if batch <= 1:
            return self.stage_time(req, stage, k_chips)
        key = (req.pipeline, req.resolution, req.seconds, req.cond_len,
               stage, k_chips, batch)
        hit = self._batch_memo.get(key)
        if hit is not None:
            return hit
        flops = self.stage_flops(req, stage) * batch
        hbm = (self.stage_hbm_bytes(req, stage)
               + (batch - 1) * self.stage_act_bytes(req, stage) * 3)
        base = self.stage_time(req, stage, k_chips)
        mfu = MFU_CONV if stage == "C" else MFU
        t = max(flops / (k_chips * PEAK_FLOPS * mfu),
                hbm / (k_chips * HBM_BW)) + DISPATCH_OVERHEAD
        t = max(base, t)
        self._batch_memo[key] = t
        return t

    def optimal_batch(self, req: Request, stage: str, k_chips: int,
                      cap: int = 8) -> int:
        """Largest batch whose latency stays within 1.2x single (E.1)."""
        key = (req.pipeline, req.resolution, req.seconds, req.cond_len,
               stage, k_chips, "bs")
        hit = self._deg_memo.get(key)
        if hit is not None:
            return hit
        t1 = self.stage_time(req, stage, k_chips)
        best = 1
        bs = 2
        while bs <= cap:
            if self.batched_stage_time(req, stage, k_chips, bs) <= 1.2 * t1:
                best = bs
            bs *= 2
        self._deg_memo[key] = best
        return best

    def speedup(self, req: Request, stage: str, k_chips: int) -> float:
        return self.stage_time(req, stage, 1) / self.stage_time(req, stage, k_chips)

    def efficiency(self, req: Request, stage: str, k_chips: int) -> float:
        return self.speedup(req, stage, k_chips) / k_chips

    def optimal_degree(self, req: Request, stage: str) -> int:
        """Paper's *optimal parallelism strategy*: highest degree with
        efficiency > 0.8 (footnote 4). In scheduling *units*."""
        key = (req.pipeline, req.resolution, req.seconds, req.cond_len,
               stage)
        hit = self._deg_memo.get(key)
        if hit is not None:
            return hit
        best = 1
        for k in PARALLEL_DEGREES:
            if k > self.max_degree_units:
                break
            if self.efficiency(req, stage, k * self.k_min) > EFFICIENCY_THRESHOLD:
                best = k
        self._deg_memo[key] = best
        return best

    def pipeline_time(self, req: Request, k_chips: Optional[int] = None) -> float:
        """End-to-end time at per-stage optimal (used for SLO = 2.5x this)."""
        total = 0.0
        for s in ("E", "D", "C"):
            k = k_chips or self.optimal_degree(req, s) * self.k_min
            total += self.stage_time(req, s, k)
        return total

    # -- memory feasibility ------------------------------------------------------

    def unit_param_bytes(self, ptype: str) -> float:
        """Per-chip parameter bytes for a placement type (MP folds /k_min)."""
        return sum(self.info[s].bytes for s in ptype) / self.k_min

    def peak_mem(self, req: Request, ptype: str, k_units: int) -> float:
        """Per-chip peak bytes running the heaviest stage of ``ptype`` for
        ``req`` at degree ``k_units`` (SP shards activations, not params).

        Decode activations are capped at the tiled-decode working set (VAE
        tiling is standard practice; the *time* model still pays the full
        HBM traffic)."""
        k_chips = k_units * self.k_min

        def act(s):
            a = self.stage_act_bytes(req, s) / k_chips
            return min(a, 4 * 2 ** 30) if s == "C" else a

        peak = max(act(s) for s in ptype)
        return self.unit_param_bytes(ptype) + peak + MEM_RESERVE

    def fits(self, req: Request, ptype: str, k_units: int) -> bool:
        """Memory-feasibility filter F_{r,i,k} — memoized: it sits on the
        dispatch hot path (called per pending request x VR type x degree,
        every scheduler wake-up)."""
        key = (req.pipeline, req.resolution, req.seconds, req.cond_len,
               ptype, k_units)
        hit = self._fits_memo.get(key)
        if hit is None:
            hit = self.peak_mem(req, ptype, k_units) <= HBM_BYTES
            self._fits_memo[key] = hit
        return hit

    # -- inter-stage communication -------------------------------------------------

    def comm_bytes(self, req: Request, edge: str) -> float:
        """Q_ED / Q_DC tensor volumes (bf16)."""
        if edge == "ED":
            return req.cond_len * self.info["E"].d_model * 2.0
        if edge == "DC":
            return self.latent_tokens(req) * self.cfg.dit.latent_dim * 2.0
        raise KeyError(edge)

    def transfer_time(self, nbytes: float, intra_node: bool) -> float:
        return nbytes / (ICI_BW if intra_node else DCN_BW) + 2e-4

    def stage_load_time(self, stage: str, via_host: bool) -> float:
        """Adjust-on-Dispatch replica load (P2P peer vs pinned-host path)."""
        per_chip = self.info[stage].bytes / self.k_min
        return per_chip / (HOST_BW if via_host else ICI_BW) + 1e-3
