"""Dynamic Orchestrator (§6.1, Algorithm 2 + Appendix C.1).

Generates placement plans:
  1. OptVR(r) per request: first feasible Virtual-Replica type in the order
     V0 ≺ V1 ≺ V2 ≺ V3 (minimal inter-stage communication).
  2. Provision VR-type counts proportionally to the OptVR distribution.
  3. Split() each type's budget into primary/auxiliary replicas inversely
     proportional to monitored service rates.
  4. PackPerMachine(): pad D-carrying primaries to whole nodes (so SP
     degrees up to a full node stay selectable) and pack homogeneous blocks.
"""
from __future__ import annotations

import math
from collections import Counter
from typing import Dict, List, Optional, Sequence

from repro.core.placement import (C, D, DC, E, ED, EDC, PRIMARY_PLACEMENTS,
                                  PlacementPlan, primary_of_vr)
from repro.core.profiler import HBM_BYTES, MEM_RESERVE, Profiler
from repro.core.request import Request


class Orchestrator:
    def __init__(self, profiler: Profiler, num_chips: int = 128,
                 chips_per_node: int = 8, alpha_mode: str = "demand"):
        """alpha_mode: how VR-type provisioning proportions are computed.
        "count" is Algorithm 2 as written (α_t = request-count fraction);
        "demand" weights each request by its unit-time footprint, which
        prevents starvation of heavy classes whose per-request resource
        consumption dwarfs the light ones — a beyond-paper refinement kept
        switchable so EXPERIMENTS.md can compare both."""
        self.prof = profiler
        self.num_units = num_chips // profiler.k_min
        self.units_per_node = max(1, chips_per_node // profiler.k_min)
        self.alpha_mode = alpha_mode

    def resize(self, num_chips: int) -> None:
        """Re-target the orchestrator at a new chip budget.  Used by the
        fleet layer (core/fleet.py) when the shared-cluster partition moves
        chips between pipelines; subsequent ``generate`` calls plan within
        the new budget."""
        self.num_units = num_chips // self.prof.k_min

    # -- Algorithm 2, lines 1-2 ----------------------------------------------

    def opt_vr(self, req: Request) -> int:
        k = self.prof.optimal_degree(req, "D")
        for vr in range(4):
            prim = primary_of_vr(vr)
            if self.prof.fits(req, prim, k):
                return vr
        return 3  # ⟨D⟩ with max degree as last resort

    # -- service rates (v_pi) ---------------------------------------------------

    def _service_rates(self, reqs: Sequence[Request], vr: int,
                       measured: Optional[Dict[str, float]] = None
                       ) -> Dict[str, float]:
        """Requests/s per replica for the primary and auxiliaries of type vr.
        Measured Monitor rates take precedence; the profiler seeds bootstrap."""
        prim = primary_of_vr(vr)
        sample = [r for r in reqs] or [Request(self.prof.cfg.name, 512)]

        def avg_time(stage_set: str) -> float:
            tot = 0.0
            for r in sample:
                k = self.prof.optimal_degree(r, "D") * self.prof.k_min
                for s in stage_set:
                    ks = (k if s == "D" else
                          self.prof.optimal_degree(r, s) * self.prof.k_min)
                    tot += self.prof.stage_time(r, s, ks)
            return tot / len(sample)

        rates = {
            "prim": 1.0 / max(avg_time(prim), 1e-9),
            "auxE": 1.0 / max(avg_time("E"), 1e-9),
            "auxC": 1.0 / max(avg_time("C"), 1e-9),
        }
        if measured:
            for key, pi in (("prim", prim), ("auxE", E), ("auxC", C)):
                if measured.get(pi, 0.0) > 0.0:
                    rates[key] = measured[pi]
        return rates

    # -- Appendix C.1: Split() -----------------------------------------------------

    @staticmethod
    def split(n_t: int, vr: int, rates: Dict[str, float]) -> Dict[str, int]:
        """(n_prim, n_auxE, n_auxC) summing to n_t with aux capacity >= prim."""
        prim = primary_of_vr(vr)
        v_p, v_e, v_c = rates["prim"], rates["auxE"], rates["auxC"]
        if vr == 0:                                   # EDC: trivial
            return {prim: n_t}
        if vr == 1:                                   # DC + auxE
            rho = v_p / v_e
            n_p = max(1, math.floor(n_t / (1 + rho))) if n_t > 1 else n_t
            return {prim: n_p, E: n_t - n_p}
        if vr == 2:                                   # ED + auxC
            rho = v_p / v_c
            n_p = max(1, math.floor(n_t / (1 + rho))) if n_t > 1 else n_t
            return {prim: n_p, C: n_t - n_p}
        # V3: D + auxE + auxC, proportional to (1, a, b)
        a, b = v_p / v_e, v_p / v_c
        tot = 1 + a + b
        n_p = max(1, round(n_t / tot)) if n_t > 2 else max(1, n_t - 2)
        n_e = max(1 if n_t >= 3 else 0, round(n_t * a / tot))
        n_c = n_t - n_p - n_e
        if n_c < (1 if n_t >= 3 else 0):
            n_c = max(0, n_c)
            n_p = n_t - n_e - n_c
        # degenerate guard (n_t <= 2 with extreme rates): the rounding above
        # can let the aux buckets swallow the whole budget; shrink the larger
        # aux until the primary keeps at least one unit
        while n_p < 1 and (n_e > 0 or n_c > 0):
            if n_e >= n_c:
                n_e -= 1
            else:
                n_c -= 1
            n_p += 1
        # feasibility: aux capacity must cover the primary's service rate
        while n_p > 1 and (n_e * v_e < n_p * v_p or n_c * v_c < n_p * v_p):
            n_p -= 1
            if n_e * v_e < n_p * v_p + v_p:
                n_e += 1
            else:
                n_c += 1
        return {primary_of_vr(3): n_p, E: n_e, C: n_c}

    # -- Appendix C.1: PackPerMachine() -----------------------------------------------

    def pack_per_machine(self, counts: Dict[str, int]) -> PlacementPlan:
        """Pad D-carrying primaries to node multiples (borrowing from their
        auxiliaries), then pack homogeneous whole nodes, then first-fit."""
        counts = dict(counts)
        upn = self.units_per_node
        total = self.num_units
        # normalize: drop zero/negative
        counts = {t: c for t, c in counts.items() if c > 0}
        # pad primaries up to multiples of upn by borrowing from auxiliaries
        for prim in (EDC, ED, DC, D):
            c = counts.get(prim, 0)
            if c == 0 or c % upn == 0:
                continue
            want = min(total, (c + upn - 1) // upn * upn)
            need = want - c
            borrowable = counts.get(E, 0) + counts.get(C, 0)
            if need <= borrowable - 2 * (1 if borrowable else 0):
                for aux in (E, C):
                    take = min(need, max(0, counts.get(aux, 0) - 1))
                    counts[aux] = counts.get(aux, 0) - take
                    need -= take
                    if need == 0:
                        break
                counts[prim] = want - need
        # fix total
        drift = total - sum(counts.values())  # detlint: ignore[DET001] int unit counts: exact
        if drift > 0:
            # surplus units go to the largest bucket
            t = max(counts, key=lambda t: counts[t])  # detlint: ignore[DET004] counts is split-ordered; tie winner is BENCH-byte-frozen
            counts[t] += drift
        elif drift < 0:
            # shed units largest-bucket-first.  A single lump subtraction
            # could silently zero the largest bucket — including the only
            # D-carrying one, leaving a plan that can never run Diffuse —
            # so shed one unit at a time and never take a primary bucket's
            # last unit while it is the only primary left.
            for _ in range(-drift):
                pick = None
                n_prim = sum(c for t, c in counts.items()  # detlint: ignore[DET001] int unit counts: exact
                             if t in PRIMARY_PLACEMENTS)
                for t in sorted(counts, key=lambda t: -counts[t]):  # detlint: ignore[DET004] equal-count shed order = insertion order; BENCH-byte-frozen
                    if counts[t] <= 0:
                        continue
                    if t in PRIMARY_PLACEMENTS and n_prim <= 1:
                        continue
                    pick = t
                    break
                if pick is None:   # only a lone primary unit remains
                    break
                counts[pick] -= 1
            counts = {t: c for t, c in counts.items() if c > 0}
        # pack: homogeneous blocks node by node, primaries first
        order = [t for t in (EDC, DC, ED, D, E, C) if counts.get(t, 0) > 0]
        placements: List[str] = []
        for t in order:
            placements.extend([t] * counts[t])
        placements = placements[:total]
        while len(placements) < total:
            placements.append(order[0] if order else EDC)
        return PlacementPlan(placements, unit_size=self.prof.k_min,
                             units_per_node=upn)

    # -- Algorithm 2 main -----------------------------------------------------------

    def feasible(self) -> bool:
        """A plan exists iff there is at least one unit and every stage's
        MP-folded parameters fit a single unit (V3 disaggregates fully, so
        per-stage fit is both necessary and sufficient)."""
        if self.num_units < 1:
            return False
        return all(self.prof.unit_param_bytes(s) + MEM_RESERVE <= HBM_BYTES
                   for s in "EDC")

    def generate(self, reqs: Sequence[Request],
                 measured_rates: Optional[Dict[str, float]] = None
                 ) -> Optional[PlacementPlan]:
        """Algorithm 2.  Returns ``None`` when no feasible placement exists —
        the same contract ``Scheduler.initial_placement`` exposes, so both
        bootstrap and re-placement callers handle infeasibility uniformly
        (the simulator reports OOM; ``maybe_replace`` keeps the old plan)."""
        if not self.feasible():
            return None
        sample = list(reqs)
        if not sample:
            # bootstrap with a nominal mid-size request
            sample = [Request(self.prof.cfg.name, 1024,
                              4.0 if self.prof.cfg.is_video else 0.0)]
        if self.alpha_mode == "demand":
            opt: Counter = Counter()
            for r in sample:
                k = self.prof.optimal_degree(r, "D")
                w = self.prof.stage_time(r, "D", k * self.prof.k_min) * k
                opt[self.opt_vr(r)] += w
        else:
            opt = Counter(self.opt_vr(r) for r in sample)
        total = sum(opt.values())  # detlint: ignore[DET001] Counter keyed in sample order: insertion-ordered
        counts: Dict[str, int] = Counter()
        # lines 3-4: N_t proportional to OptVR distribution
        n_assigned = 0
        n_by_vr = {}
        for vr in range(4):
            n_by_vr[vr] = int(opt.get(vr, 0) / total * self.num_units)
            n_assigned += n_by_vr[vr]
        # leftover units go to the most demanded type
        if total:
            best = max(range(4), key=lambda v: opt.get(v, 0))
            n_by_vr[best] += self.num_units - n_assigned
        # lines 5-6: Split each N_t
        for vr in range(4):
            if n_by_vr[vr] <= 0:
                continue
            rates = self._service_rates(sample, vr, measured_rates)
            for ptype, c in self.split(n_by_vr[vr], vr, rates).items():
                counts[ptype] += c
        # line 7
        return self.pack_per_machine(counts)
