"""Generic decoder/encoder transformer over heterogeneous layer segments.

One implementation serves every assigned architecture: the config's
``layer_pattern`` is tiled and merged into homogeneous *segments*, each
executed with a single ``lax.scan`` over stacked per-layer params — this
keeps HLO size O(#segments), not O(#layers), which bounds both compile time
and the SPMD partitioner's work on the 512-device dry-run mesh.

Three entry modes share the layer code:
  * ``forward``  — training / encoder pass, no cache.
  * ``prefill``  — full-sequence pass that fills a KV/state cache.
  * ``decode``   — single-token step against the cache (``serve_step``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import common, moe as moe_lib, ssm as ssm_lib
from repro.models.common import (ATTN, ATTN_BIDIR, ATTN_CHUNKED, ATTN_KINDS,
                                 ATTN_LOCAL, FFN_MOE, MAMBA2, RWKV6, Array,
                                 ModelConfig, dense_init, embed_init)

PyTree = Any

FFN_NONE = "none"

# Optional PartitionSpec for the residual stream during training
# (Megatron-style sequence sharding; set by the launcher before lowering).
# Saved scan-carry residuals then shard over seq x batch instead of batch
# only, cutting the dominant peak-memory term by the model-axis size.
_ACTIVATION_SPEC = None


def set_activation_sharding(spec) -> None:
    global _ACTIVATION_SPEC
    _ACTIVATION_SPEC = spec


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _init_attn_layer(cfg: ModelConfig, key: Array) -> dict:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    ks = common.split_keys(key, 4)
    scale_o = 1.0 / max(1, cfg.num_layers) ** 0.5
    p = {
        "ln1": jnp.zeros((d,), jnp.float32),
        "wq": dense_init(ks[0], (d, cfg.num_heads * dh), cfg.dtype),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads * dh), cfg.dtype),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads * dh), cfg.dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * dh, d), cfg.dtype, scale=scale_o),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    return p


def _init_ffn(cfg: ModelConfig, ffn: str, key: Array) -> dict:
    if ffn == FFN_NONE:
        return {}
    d = cfg.d_model
    if ffn == FFN_MOE:
        return {"ln2": jnp.zeros((d,), jnp.float32), "moe": moe_lib.init_moe(cfg, key)}
    ks = common.split_keys(key, 3)
    scale_o = 1.0 / max(1, cfg.num_layers) ** 0.5
    return {
        "ln2": jnp.zeros((d,), jnp.float32),
        "w_gate": dense_init(ks[0], (d, cfg.d_ff), cfg.dtype),
        "w_up": dense_init(ks[1], (d, cfg.d_ff), cfg.dtype),
        "w_down": dense_init(ks[2], (cfg.d_ff, d), cfg.dtype, scale=scale_o),
    }


def _init_layer(cfg: ModelConfig, kind: Tuple[str, str], key: Array) -> dict:
    mixer, ffn = kind
    k1, k2 = jax.random.split(key)
    if mixer in ATTN_KINDS:
        p = _init_attn_layer(cfg, k1)
    elif mixer == MAMBA2:
        p = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
             "mamba": ssm_lib.init_mamba2(cfg, k1)}
    elif mixer == RWKV6:
        p = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
             "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
             "rwkv": ssm_lib.init_rwkv6(cfg, k1)}
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if mixer != RWKV6:  # rwkv6 carries its own channel-mix as the ffn
        p.update(_init_ffn(cfg, ffn, k2))
    return p


def init(cfg: ModelConfig, key: Array) -> dict:
    """Build the full parameter pytree.

    ``params["blocks"][bi][pi]`` holds the stacked (repeat, ...) params of
    pattern position ``pi`` in scan-plan block ``bi`` (see
    ``ModelConfig.scan_plan``).
    """
    plan = cfg.scan_plan()
    keys = common.split_keys(key, 4 + len(plan))
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size), cfg.dtype)
    if cfg.modality == "vision":
        params["vision_proj"] = dense_init(
            keys[2], (cfg.vision_embed_dim, cfg.d_model), cfg.dtype)
    if cfg.modality == "audio_codec":
        params["codebook_embed"] = embed_init(
            keys[2], (cfg.num_codebooks, cfg.vocab_size, cfg.d_model), cfg.dtype)
        params["codebook_head"] = dense_init(
            keys[3], (cfg.num_codebooks, cfg.d_model, cfg.vocab_size), cfg.dtype)
    blocks = []
    for (cycle, repeat), k in zip(plan, keys[4:]):
        pkeys = common.split_keys(k, len(cycle))
        block = []
        for kind, pk in zip(cycle, pkeys):
            lkeys = jnp.stack(common.split_keys(pk, repeat))
            block.append(jax.vmap(lambda kk, _kind=kind: _init_layer(cfg, _kind, kk))(lkeys))
        blocks.append(block)
    params["blocks"] = blocks
    return params


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------

def cache_capacity(cfg: ModelConfig, mixer: str, max_len: int) -> int:
    if mixer == ATTN:
        return max_len
    if mixer == ATTN_LOCAL:
        return min(cfg.window_size, max_len)
    if mixer == ATTN_CHUNKED:
        return min(cfg.chunk_size, max_len)
    return 0


def _cache_entry(cfg: ModelConfig, mixer: str, count: int, batch: int,
                 max_len: int):
    dh = cfg.resolved_head_dim
    if mixer in (ATTN, ATTN_LOCAL, ATTN_CHUNKED):
        cap = cache_capacity(cfg, mixer, max_len)
        return {
            "k": jnp.zeros((count, batch, cap, cfg.num_kv_heads, dh), cfg.dtype),
            "v": jnp.zeros((count, batch, cap, cfg.num_kv_heads, dh), cfg.dtype),
            # absolute position held in each slot; -1 = empty
            "pos": jnp.full((count, batch, cap), -1, jnp.int32),
        }
    if mixer == ATTN_BIDIR:
        raise ValueError("encoder segments have no decode cache")
    if mixer == MAMBA2:
        st = ssm_lib.init_mamba2_state(cfg, batch, cfg.dtype)
    elif mixer == RWKV6:
        st = ssm_lib.init_rwkv6_state(cfg, batch, cfg.dtype)
    else:
        raise ValueError(mixer)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (count,) + x.shape), st)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> list:
    """Per scan-plan block/position stacked caches: caches[bi][pi]."""
    return [
        [_cache_entry(cfg, mixer, repeat, batch, max_len)
         for (mixer, _ffn) in cycle]
        for cycle, repeat in cfg.scan_plan()
    ]


# ---------------------------------------------------------------------------
# Attention sublayer
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, x, positions):
    b, l, _ = x.shape
    dh = cfg.resolved_head_dim
    q = jnp.einsum("bld,de->ble", x, p["wq"]).reshape(b, l, cfg.num_heads, dh)
    k = jnp.einsum("bld,de->ble", x, p["wk"]).reshape(b, l, cfg.num_kv_heads, dh)
    v = jnp.einsum("bld,de->ble", x, p["wv"]).reshape(b, l, cfg.num_kv_heads, dh)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_nocache(cfg, p, x, mixer, positions):
    """Training / prefill attention over the in-flight sequence only."""
    b, l, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    n_rep = cfg.num_heads // cfg.num_kv_heads
    kr = common.repeat_kv(k, n_rep)
    vr = common.repeat_kv(v, n_rep)
    pos = positions[0] if positions.ndim > 1 else positions
    window = cfg.window_size if mixer == ATTN_LOCAL else 0
    if cfg.use_flash and mixer != ATTN_BIDIR:
        out = kops.flash_attention(q, kr, vr, causal=True, window=window,
                                   softcap=cfg.attn_softcap, use_kernel=True)
    elif (l >= cfg.attn_block_threshold
          and l % cfg.attn_block_size == 0):
        # long sequences: online-softmax blocked attention (never builds
        # the (L, L) score matrix — required to fit HBM at 4k-500k tokens)
        out = common.attention_blocked(q, kr, vr, pos, pos, mixer,
                                       cfg.window_size, cfg.chunk_size,
                                       cfg.attn_softcap, cfg.attn_block_size)
    else:
        mask = common.make_attention_mask(pos, pos, mixer, cfg.window_size,
                                          cfg.chunk_size)
        out = common.attention(q, kr, vr, mask, cfg.attn_softcap)
    out = out.reshape(b, l, cfg.num_heads * cfg.resolved_head_dim)
    return jnp.einsum("ble,ed->bld", out, p["wo"]), (k, v)


def _attn_decode(cfg, p, x, mixer, offset, cache):
    """Single-token attention against the ring cache.

    cache: {"k","v": (B, S, Hkv, Dh), "pos": (B, S)}; offset: scalar int32 =
    number of tokens already processed (the new token's position).
    """
    b, l, _ = x.shape  # l == 1
    positions = jnp.full((b, l), offset, jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    cap = cache["k"].shape[1]
    slot = offset % cap
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.full((b, 1), offset, jnp.int32), (0, slot))

    n_rep = cfg.num_heads // cfg.num_kv_heads
    valid = (pos >= 0) & (pos <= offset)
    if mixer == ATTN_LOCAL:
        valid &= pos > offset - cfg.window_size
    elif mixer == ATTN_CHUNKED:
        valid &= (pos // cfg.chunk_size) == (offset // cfg.chunk_size)

    scale = 1.0 / (cfg.resolved_head_dim ** 0.5)
    if cfg.gqa_grouped_decode:
        # grouped form: never materializes the n_rep-expanded KV (reads the
        # cache once instead of n_rep times — decode is cache-bandwidth
        # bound, so this is a direct memory-term win)
        dh = cfg.resolved_head_dim
        qg = q.reshape(b, l, cfg.num_kv_heads, n_rep, dh)
        scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                            preferred_element_type=jnp.float32) * scale
        scores = common.softcap(scores, cfg.attn_softcap)
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bgrqk,bkgd->bqgrd", probs.astype(v.dtype), v)
        out = out.reshape(b, l, cfg.num_heads * dh)
    else:
        kr = common.repeat_kv(k, n_rep)
        vr = common.repeat_kv(v, n_rep)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                            preferred_element_type=jnp.float32) * scale
        scores = common.softcap(scores, cfg.attn_softcap)
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(vr.dtype), vr)
        out = out.reshape(b, l, cfg.num_heads * cfg.resolved_head_dim)
    return jnp.einsum("ble,ed->bld", out, p["wo"]), {"k": k, "v": v, "pos": pos}


def _fill_cache_from_prefill(cfg, mixer, k, v, positions, cap):
    """Write the last ``cap`` tokens of prefill K/V into a fresh ring cache."""
    b, l = k.shape[0], k.shape[1]
    take = min(cap, l)
    ks = k[:, l - take:, :, :]
    vs = v[:, l - take:, :, :]
    ps = jnp.broadcast_to(positions[:, l - take:], (b, take))
    if take < cap:
        pad = cap - take
        ks = jnp.pad(ks, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ps = jnp.pad(ps, ((0, 0), (0, pad)), constant_values=-1)
        return {"k": ks, "v": vs, "pos": ps}
    # ring layout: token at absolute position p sits in slot p % cap
    slots = ps[0] % cap
    inv = jnp.zeros((cap,), jnp.int32).at[slots].set(jnp.arange(cap))
    return {"k": ks[:, inv], "v": vs[:, inv], "pos": ps[:, inv]}


# ---------------------------------------------------------------------------
# Layer forward (one layer; used inside the per-segment scan)
# ---------------------------------------------------------------------------

def _ffn_apply(cfg, ffn_kind, p, x):
    if ffn_kind == FFN_NONE:
        return x, 0.0
    h = common.rms_norm(x, p["ln2"], cfg.norm_eps)
    if ffn_kind == FFN_MOE:
        out, aux = moe_lib.moe_ffn(cfg, p["moe"], h)
        return x + out, aux
    return x + common.swiglu(h, p["w_gate"], p["w_up"], p["w_down"]), 0.0


def _layer_fwd(cfg, kind, p, x, positions, cache, mode, offset):
    """Returns (x, new_cache, aux)."""
    mixer, ffn_kind = kind
    aux = 0.0
    if mixer in ATTN_KINDS:
        h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
        if mode == "decode":
            out, new_cache = _attn_decode(cfg, p, h, mixer, offset, cache)
        else:
            out, (k, v) = _attn_nocache(cfg, p, h, mixer, positions)
            new_cache = None
            if mode == "prefill":
                cap = cache["k"].shape[1]
                new_cache = _fill_cache_from_prefill(cfg, mixer, k, v, positions, cap)
        x = x + out
        x, aux = _ffn_apply(cfg, ffn_kind, p, x)
    elif mixer == MAMBA2:
        h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
        if mode == "decode":
            out, new_cache = ssm_lib.mamba2_decode(cfg, p["mamba"], h, cache)
        else:
            out, new_cache = ssm_lib.mamba2_forward(
                cfg, p["mamba"], h, cache if mode == "prefill" else None)
            if mode != "prefill":
                new_cache = None
        x = x + out
        x, aux = _ffn_apply(cfg, ffn_kind, p, x)
    elif mixer == RWKV6:
        h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
        st = cache if mode != "train" else None
        out, s_new, shift_tm = ssm_lib.rwkv6_timemix(
            cfg, p["rwkv"], h, st, decode=(mode == "decode"))
        x = x + out
        h2 = common.rms_norm(x, p["ln2"], cfg.norm_eps)
        out2, shift_cm = ssm_lib.rwkv6_channelmix(cfg, p["rwkv"], h2, st)
        x = x + out2
        new_cache = (None if mode == "train" else
                     {"ssm": s_new, "shift_tm": shift_tm.astype(cfg.dtype),
                      "shift_cm": shift_cm.astype(cfg.dtype)})
    else:
        raise ValueError(mixer)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Segment runners
# ---------------------------------------------------------------------------

def _run_segments(cfg, params, x, positions, caches, mode, offset):
    """Run every scan-plan block; each block is one lax.scan whose body
    applies the whole pattern cycle once."""
    new_caches = []
    total_aux = jnp.float32(0.0)
    for bi, (cycle, repeat) in enumerate(cfg.scan_plan()):
        p_blk = params["blocks"][bi]
        c_blk = caches[bi] if caches is not None else None

        def body(carry, xs, _cycle=cycle, _has_cache=c_blk is not None):
            xc, auxc = carry
            if _ACTIVATION_SPEC is not None and mode == "train":
                xc = jax.lax.with_sharding_constraint(xc, _ACTIVATION_SPEC)
            if _has_cache:
                p_cyc, c_cyc = xs
            else:
                p_cyc, c_cyc = xs, [None] * len(_cycle)
            ncs = []
            for kind, p_l, c_l in zip(_cycle, p_cyc, c_cyc):
                xc, nc, aux = _layer_fwd(cfg, kind, p_l, xc, positions, c_l,
                                         mode, offset)
                auxc = auxc + aux
                ncs.append(nc if nc is not None else 0)
            return (xc, auxc), ncs

        if mode == "train" and cfg.remat:
            body = jax.checkpoint(body)  # recompute in bwd; no stacked stash
        xs = (p_blk, c_blk) if c_blk is not None else p_blk
        (x, total_aux), ys = jax.lax.scan(body, (x, total_aux), xs)
        new_caches.append(ys if c_blk is not None else None)
    return x, new_caches, total_aux


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: dict, tokens: Array,
                 prefix_embeds: Optional[Array] = None) -> Array:
    """tokens: (B, L) int32 — or (B, K, L) for audio_codec.
    prefix_embeds: (B, Tv, Dv) vision/audio stub embeddings, projected and
    prepended (the modality-frontend carve-out)."""
    if cfg.modality == "audio_codec" and tokens.ndim == 3:
        # sum the K codebook embeddings per frame [arXiv:2306.05284]
        x = jnp.sum(jax.vmap(
            lambda emb, tok: emb[tok], in_axes=(0, 1), out_axes=1
        )(params["codebook_embed"], tokens), axis=1)
    else:
        x = params["embed"][tokens]
    if cfg.name and getattr(cfg, "embed_scale", False):
        x = x * (cfg.d_model ** 0.5)
    if prefix_embeds is not None:
        proj = params.get("vision_proj")
        pe = (jnp.einsum("btv,vd->btd", prefix_embeds.astype(cfg.dtype), proj)
              if proj is not None else prefix_embeds.astype(cfg.dtype))
        x = jnp.concatenate([pe, x], axis=1)
    return x


def lm_logits(cfg: ModelConfig, params: dict, x: Array) -> Array:
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.modality == "audio_codec":
        logits = jnp.einsum("bld,kdv->blkv", x, params["codebook_head"])
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bld,vd->blv", x, params["embed"])
    else:
        logits = jnp.einsum("bld,dv->blv", x, params["lm_head"])
    return common.softcap(logits.astype(jnp.float32), cfg.logit_softcap)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, tokens: Array,
            prefix_embeds: Optional[Array] = None) -> Tuple[Array, Array]:
    """Training/encoder pass: (logits (B, L', Vf32), aux_loss)."""
    x = embed_tokens(cfg, params, tokens, prefix_embeds)
    b, l, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))
    x, _, aux = _run_segments(cfg, params, x, positions, None, "train", 0)
    return lm_logits(cfg, params, x), aux


def prefill(cfg: ModelConfig, params: dict, tokens: Array, max_len: int,
            prefix_embeds: Optional[Array] = None) -> Tuple[Array, list, Array]:
    """Returns (last-token logits, cache, offset). Cache sized for max_len."""
    x = embed_tokens(cfg, params, tokens, prefix_embeds)
    b, l, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))
    caches = init_cache(cfg, b, max_len)
    x, new_caches, _ = _run_segments(cfg, params, x, positions, caches, "prefill", 0)
    logits = lm_logits(cfg, params, x[:, -1:, :])
    return logits, new_caches, jnp.int32(l)


def decode_step(cfg: ModelConfig, params: dict, tokens: Array, caches: list,
                offset: Array) -> Tuple[Array, list]:
    """serve_step: ONE new token (B, 1) [or (B, K, 1) audio] against the cache."""
    x = embed_tokens(cfg, params, tokens)
    # decode positions derive from offset inside the layers
    b = x.shape[0]
    pos = jnp.full((b, 1), offset, jnp.int32)
    x, new_caches, _ = _run_segments(cfg, params, x, pos, caches, "decode", offset)
    return lm_logits(cfg, params, x), new_caches
