"""Model zoo: transformer families used by the serving system and dry-runs.

All models are pure-JAX (no flax): ``init(cfg, key) -> params`` pytrees and
``apply``-style functions that are jit/pjit friendly.  Layer stacks use
``lax.scan`` over stacked per-layer params (grouped into homogeneous
segments) to bound HLO size and compile time.
"""
from repro.models.common import ModelConfig
from repro.models import transformer

__all__ = ["ModelConfig", "transformer"]
