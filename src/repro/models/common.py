"""Shared building blocks: config, norms, RoPE, attention math, MLP.

Everything here is shape-polymorphic pure JAX.  Attention supports the mask
variants needed by the assigned architectures: causal, sliding-window
(gemma2/starcoder2), chunked-local (llama4 iRoPE-style), and bidirectional
(T5-style encoder used by the diffusion pipelines).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

# mixer kinds
ATTN = "attn"                # full causal attention
ATTN_LOCAL = "attn_local"    # sliding-window causal attention
ATTN_CHUNKED = "attn_chunked"  # chunked local attention (llama4 iRoPE)
ATTN_BIDIR = "attn_bidir"    # bidirectional (encoder)
MAMBA2 = "mamba2"
RWKV6 = "rwkv6"

# ffn kinds
FFN_DENSE = "dense"
FFN_MOE = "moe"

ATTN_KINDS = (ATTN, ATTN_LOCAL, ATTN_CHUNKED, ATTN_BIDIR)
SSM_KINDS = (MAMBA2, RWKV6)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config describes every architecture family in the zoo.

    ``layer_pattern`` is a cycle of ``"<mixer>:<ffn>"`` entries; it is tiled
    to ``num_layers`` and then merged into homogeneous segments which are
    each executed with one ``lax.scan``.
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    layer_pattern: Tuple[str, ...] = ("attn:dense",)

    # attention details
    window_size: int = 4096          # for attn_local
    chunk_size: int = 8192           # for attn_chunked
    logit_softcap: float = 0.0       # final-logit softcap (gemma2: 30)
    attn_softcap: float = 0.0        # attention-score softcap (gemma2: 50)
    rope_theta: float = 10000.0
    qk_norm: bool = False

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (mamba2 / rwkv6)
    ssm_state_dim: int = 64
    ssm_heads: int = 0               # 0 -> num_heads
    ssm_expand: int = 2
    ssm_conv: int = 4

    # modality frontends (stubs per brief)
    modality: str = "text"           # text | vision | audio_codec
    num_codebooks: int = 0           # musicgen
    vision_tokens: int = 0           # number of prefix embedding tokens
    vision_embed_dim: int = 0

    # numerics
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    tie_embeddings: bool = False
    embed_scale: bool = False        # multiply embeddings by sqrt(d_model) (gemma)
    use_flash: bool = False          # route attention through Pallas kernel
    remat: bool = True               # checkpoint layer bodies in training
    attn_block_threshold: int = 4096  # use online-softmax blocked attention
    attn_block_size: int = 512        # ... with this KV block size
    gqa_grouped_decode: bool = False  # decode attention without KV repeat

    # citation for the public config (model card / arXiv)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_ssm_heads(self) -> int:
        return self.ssm_heads or self.num_heads

    def layer_kinds(self) -> Tuple[Tuple[str, str], ...]:
        """Tile layer_pattern to num_layers -> ((mixer, ffn), ...)."""
        out = []
        for i in range(self.num_layers):
            entry = self.layer_pattern[i % len(self.layer_pattern)]
            mixer, _, ffn = entry.partition(":")
            out.append((mixer, ffn or FFN_DENSE))
        return tuple(out)

    def segments(self) -> Tuple[Tuple[Tuple[str, str], int], ...]:
        """Merge consecutive identical layer kinds into (kind, count) runs."""
        kinds = self.layer_kinds()
        segs = []
        for k in kinds:
            if segs and segs[-1][0] == k:
                segs[-1][1] += 1
            else:
                segs.append([k, 1])
        return tuple((k, c) for k, c in segs)

    def scan_plan(self) -> Tuple[Tuple[Tuple[Tuple[str, str], ...], int], ...]:
        """Blocks of (pattern_cycle, repeat) executed as one lax.scan each.

        Keeps HLO size O(pattern length), not O(num_layers):
          * cycling patterns (gemma2 local/global, llama4, zamba2) scan over
            cycle repeats with the whole cycle in the scan body;
          * otherwise homogeneous runs are merged (yi, deepseek-moe's single
            leading dense layer + 27 moe layers -> two scans).
        Remainder layers after the last full cycle become extra run-blocks.
        """
        kinds = self.layer_kinds()
        p = len(self.layer_pattern)
        n = self.num_layers
        blocks = []
        if p > 1 and n // p >= 2:
            g = n // p
            cycle = tuple(kinds[:p])
            blocks.append((cycle, g))
            rest = kinds[g * p:]
        else:
            rest = kinds
        # merge the remainder (or everything) into homogeneous runs
        runs = []
        for k in rest:
            if runs and runs[-1][0] == k:
                runs[-1][1] += 1
            else:
                runs.append([k, 1])
        for k, c in runs:
            blocks.append(((k,), c))
        return tuple(blocks)

    def is_subquadratic(self) -> bool:
        """True when no layer needs an unbounded full-attention KV cache."""
        return all(m not in (ATTN, ATTN_BIDIR) for m, _ in self.layer_kinds())

    def supports_long_context(self) -> bool:
        """long_500k eligibility: every layer either SSM or windowed/chunked,
        OR the architecture natively mixes bounded-local with (rare) global
        layers — gemma2/llama4 style. Pure full-attention stacks return False.
        """
        kinds = [m for m, _ in self.layer_kinds()]
        n_full = sum(1 for m in kinds if m == ATTN)
        n_bounded = sum(1 for m in kinds if m in (ATTN_LOCAL, ATTN_CHUNKED) or m in SSM_KINDS)
        if n_full == 0:
            return True
        # native local/global alternation: at most half the layers global
        return n_bounded > 0 and n_full <= len(kinds) // 2


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key: Array, shape: Sequence[int], dtype, scale: float = 1.0) -> Array:
    """Truncated-normal fan-in init (matches common LLM reference impls)."""
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = scale / math.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: Array, shape: Sequence[int], dtype) -> Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x: Array, weight: Array, bias: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def softcap(x: Array, cap: float) -> Array:
    return cap * jnp.tanh(x / cap) if cap > 0 else x


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., L, H, Dh); positions: broadcastable to (..., L)."""
    freqs = rope_freqs(x.shape[-1], theta)           # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., L, Dh/2)
    angles = angles[..., None, :]                    # (..., L, 1, Dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention math (jnp reference path; kernel path lives in repro.kernels)
# ---------------------------------------------------------------------------

def make_attention_mask(q_pos: Array, kv_pos: Array, kind: str,
                        window: int = 0, chunk: int = 0) -> Array:
    """(Lq, Lkv) boolean mask; True = attend."""
    q = q_pos[:, None]
    k = kv_pos[None, :]
    if kind == ATTN_BIDIR:
        return jnp.ones((q_pos.shape[0], kv_pos.shape[0]), dtype=jnp.bool_)
    causal = k <= q
    if kind == ATTN:
        return causal
    if kind == ATTN_LOCAL:
        return causal & (k > q - window)
    if kind == ATTN_CHUNKED:
        return causal & (k // chunk == q // chunk)
    raise ValueError(f"unknown attention kind {kind!r}")


def repeat_kv(x: Array, n_rep: int) -> Array:
    """(B, L, Hkv, Dh) -> (B, L, Hkv*n_rep, Dh)."""
    if n_rep == 1:
        return x
    b, l, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, l, h, n_rep, d)).reshape(b, l, h * n_rep, d)


def _block_mask(q_pos: Array, k_pos: Array, kind: str, window: int,
                chunk: int) -> Optional[Array]:
    if kind == ATTN_BIDIR:
        return None
    q = q_pos[:, None]
    k = k_pos[None, :]
    m = k <= q
    if kind == ATTN_LOCAL:
        m &= k > q - window
    elif kind == ATTN_CHUNKED:
        m &= (k // chunk) == (q // chunk)
    return m


def attention_blocked(q: Array, k: Array, v: Array, q_pos: Array,
                      kv_pos: Array, kind: str, window: int = 0,
                      chunk: int = 0, attn_softcap_val: float = 0.0,
                      block: int = 512) -> Array:
    """Online-softmax attention blocked over KV (flash-attention algorithm
    in pure XLA, à la MaxText): never materializes the (Lq, Lkv) matrix, so
    long-sequence training/prefill fits HBM without a custom kernel.  The
    Pallas kernel (`repro.kernels.flash_attention`) is the TPU-optimized
    version of the same loop."""
    b, lq, h, d = q.shape
    lkv = k.shape[1]
    assert lkv % block == 0, (lkv, block)
    nb = lkv // block
    scale = 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32)

    kb = jnp.moveaxis(k.reshape(b, nb, block, h, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nb, block, h, d), 1, 0)
    pb = kv_pos.reshape(nb, block)

    def step(carry, inp):
        m, l, acc = carry
        kk, vv, pp = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kk.astype(jnp.float32)) * scale
        s = softcap(s, attn_softcap_val)
        mask = _block_mask(q_pos, pp, kind, window, chunk)
        if mask is not None:
            s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = (acc * alpha[..., None]
                   + jnp.einsum("bhqk,bkhd->bhqd", p, vv.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, h, lq), -1e30, jnp.float32),
            jnp.zeros((b, h, lq), jnp.float32),
            jnp.zeros((b, h, lq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, (kb, vb, pb))
    l = jnp.where(l == 0.0, 1.0, l)
    out = acc / l[..., None]
    return jnp.moveaxis(out, 1, 2).astype(v.dtype)


def attention(q: Array, k: Array, v: Array, mask: Optional[Array],
              attn_softcap_val: float = 0.0) -> Array:
    """q: (B, Lq, H, Dh); k/v: (B, Lkv, H, Dh); mask: (Lq, Lkv) or None.

    Reference jnp implementation.  Reductions stay in f32.  Under pjit a
    sequence-sharded ``k``/``v`` lowers to partial-softmax + all-reduce
    automatically (max and sum reductions over the sharded axis).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, attn_softcap_val)
    if mask is not None:
        scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, w_down)


def gelu_mlp(x: Array, w_up: Array, w_down: Array) -> Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_up).astype(jnp.float32), approximate=True)
    return jnp.einsum("...f,fd->...d", h.astype(x.dtype), w_down)


# ---------------------------------------------------------------------------
# Misc helpers
# ---------------------------------------------------------------------------

def split_keys(key: Array, n: int):
    return list(jax.random.split(key, n))


def count_params(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def param_bytes(params: PyTree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))
