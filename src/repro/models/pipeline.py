"""The three-stage Diffusion Pipeline: Encode -> Diffuse -> Decode.

This is the model object the serving system deploys.  Each stage is an
independent parameter pytree + apply function, so a *placement* can load any
subset of stages onto a worker, and a *dispatch plan* can run a stage on its
own device group — exactly the paper's stage-level abstraction.

Resolution/duration -> latent token geometry follows the 8x-VAE, patch-2
convention (image: (res/16)^2 tokens; video adds frames/4 temporal tokens),
matching Table 2's l_proc ranges.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common, diffusion, transformer
from repro.models.common import Array, ModelConfig


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    name: str
    encoder: ModelConfig              # bidirectional text encoder (stage E)
    dit: diffusion.DiTConfig          # denoiser (stage D)
    decoder: diffusion.DecoderConfig  # AE-KL latent decoder (stage C)
    num_steps: int                    # denoising steps (Table 5)
    max_cond_len: int = 128
    is_video: bool = False
    source: str = ""

    def latent_grid(self, resolution: int, seconds: float = 0.0) -> Tuple[int, int, int]:
        """(frames, h, w) latent geometry. 8x VAE + patch 2 -> /16 per side;
        video: 4x temporal compression at 16 fps."""
        side = max(2, resolution // 16)
        frames = max(1, int(seconds * 16) // 4) if self.is_video else 1
        return frames, side, side

    def latent_tokens(self, resolution: int, seconds: float = 0.0) -> int:
        f, h, w = self.latent_grid(resolution, seconds)
        return f * h * w


def init(cfg: PipelineConfig, key: Array) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "encode": transformer.init(cfg.encoder, k1),
        "diffuse": diffusion.init(cfg.dit, k2),
        "decode": diffusion.init_decoder(cfg.decoder, k3),
    }


# --- Stage apply functions (each independently dispatchable) ---------------

def encode(cfg: PipelineConfig, params: Dict, tokens: Array) -> Array:
    """Stage E: prompt tokens (B, Lc) -> condition embeddings (B, Lc, D_enc)."""
    ecfg = cfg.encoder
    x = transformer.embed_tokens(ecfg, params["encode"], tokens)
    b, l, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))
    x, _, _ = transformer._run_segments(ecfg, params["encode"], x, positions,
                                        None, "train", 0)
    return common.rms_norm(x, params["encode"]["final_norm"], ecfg.norm_eps)


def diffuse(cfg: PipelineConfig, params: Dict, cond: Array, latent_shape,
            key: Array, num_steps: Optional[int] = None) -> Array:
    """Stage D: T-step denoising from Gaussian noise in latent space."""
    steps = num_steps or cfg.num_steps
    noise = jax.random.normal(key, latent_shape, jnp.float32)
    return diffusion.ddim_denoise(cfg.dit, params["diffuse"], noise, cond, steps)


def decode(cfg: PipelineConfig, params: Dict, latents: Array,
           grid: Tuple[int, int, int]) -> Array:
    """Stage C: latent tokens (B, L, C) -> pixel frames (B*F, 8h*2, 8w*2, 3).

    Tokens are un-patchified (patch 2 over an 8x-VAE grid) then decoded.
    """
    f, h, w = grid
    b, l, c = latents.shape
    assert l == f * h * w, (l, grid)
    cl = cfg.decoder.latent_channels
    # (B, F, h, w, patch2*cl) -> (B*F, 2h, 2w, cl)
    z = latents.reshape(b * f, h, w, 2, 2, cl).transpose(0, 1, 3, 2, 4, 5)
    z = z.reshape(b * f, 2 * h, 2 * w, cl)
    return diffusion.decode_latent(cfg.decoder, params["decode"], z)


def generate(cfg: PipelineConfig, params: Dict, tokens: Array, resolution: int,
             seconds: float, key: Array, num_steps: Optional[int] = None) -> Array:
    """End-to-end E->D->C (the co-located ⟨EDC⟩ execution path)."""
    grid = cfg.latent_grid(resolution, seconds)
    ltokens = cfg.latent_tokens(resolution, seconds)
    cond = encode(cfg, params, tokens)
    b = tokens.shape[0]
    lat_dim = cfg.dit.latent_dim
    latents = diffuse(cfg, params, cond, (b, ltokens, lat_dim), key, num_steps)
    return decode(cfg, params, latents, grid)


# --- Workload geometry helpers (used by the profiler & dispatcher) ---------

def stage_proc_len(cfg: PipelineConfig, stage: str, resolution: int,
                   seconds: float, cond_len: int = 77) -> int:
    """The paper's l_proc per stage (Table 2 semantics)."""
    if stage == "E":
        return cond_len
    return cfg.latent_tokens(resolution, seconds) + (cond_len if stage == "D" else 0)
