"""SSM mixers: Mamba2 (Zamba2 backbone) and RWKV6 "Finch".

Both share the gated linear-attention recurrence
    S_t = diag(decay_t) S_{t-1} + k_t (outer) v_t,
served by ``repro.kernels`` (chunked parallel form for prefill, recurrent
single-step form for decode).  RWKV6's signature feature — *data-dependent
decay* through a low-rank projection — is implemented faithfully
[arXiv:2404.05892]; Mamba2 uses the SSD scalar-per-head decay
[arXiv:2405.21060 as used by Zamba2, arXiv:2411.15242].

State layout per layer:
  mamba2: {"conv": (B, conv_w-1, conv_dim), "ssm": (B, H, N, P)}
  rwkv6:  {"shift_tm": (B, D), "shift_cm": (B, D), "ssm": (B, H, K, V)}
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.ssm_scan import MAX_NEG_LOGW
from repro.models import common
from repro.models.common import Array, ModelConfig, dense_init

# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------


def mamba2_dims(cfg: ModelConfig) -> Dict[str, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = cfg.resolved_ssm_heads
    return dict(
        d_inner=d_inner,
        heads=heads,
        head_dim=d_inner // heads,
        state=cfg.ssm_state_dim,
        conv_dim=d_inner + 2 * cfg.ssm_state_dim,  # x, B, C all convolved
    )


def init_mamba2(cfg: ModelConfig, key: Array) -> dict:
    d = mamba2_dims(cfg)
    ks = common.split_keys(key, 5)
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "in_proj": dense_init(ks[0], (cfg.d_model, 2 * d["d_inner"] + 2 * d["state"] + d["heads"]), cfg.dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, d["conv_dim"]), cfg.dtype, scale=1.0),
        "conv_b": jnp.zeros((d["conv_dim"],), cfg.dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, d["heads"], dtype=jnp.float32)),
        "D": jnp.ones((d["heads"],), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((d["heads"],),
                                              0.01, jnp.float32))),  # softplus^-1(0.01)
        "norm_w": jnp.zeros((d["d_inner"],), cfg.dtype),
        "out_proj": dense_init(ks[2], (d["d_inner"], cfg.d_model), cfg.dtype,
                               scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    d = mamba2_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d["conv_dim"]), dtype),
        "ssm": jnp.zeros((batch, d["heads"], d["state"], d["head_dim"]), jnp.float32),
    }


def _mamba2_project(cfg: ModelConfig, params: dict, x: Array):
    d = mamba2_dims(cfg)
    zxbcdt = jnp.einsum("bld,de->ble", x, params["in_proj"])
    z, xbc, dt = jnp.split(
        zxbcdt, [d["d_inner"], d["d_inner"] + d["conv_dim"]], axis=-1)
    return z, xbc, dt, d


def _mamba2_ssm_inputs(cfg, params, xbc_conv, dt, d):
    """Post-conv activations -> (q, k, v, decay) in (B, H, L, ...) layout."""
    b, l, _ = xbc_conv.shape
    xbc_conv = jax.nn.silu(xbc_conv.astype(jnp.float32)).astype(xbc_conv.dtype)
    xs, bs, cs = jnp.split(xbc_conv, [d["d_inner"], d["d_inner"] + d["state"]], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])      # (B, L, H)
    # clamp per-step log-decay to the Pallas kernel's numeric contract
    decay_h = jnp.exp(-jnp.clip(dt * jnp.exp(params["A_log"]),
                                0.0, MAX_NEG_LOGW))                        # (B, L, H)
    xh = xs.reshape(b, l, d["heads"], d["head_dim"])
    # shared B/C across heads (single group)
    q = jnp.broadcast_to(cs[:, :, None, :], (b, l, d["heads"], d["state"]))
    k = jnp.broadcast_to(bs[:, :, None, :], (b, l, d["heads"], d["state"]))
    v = xh * dt[..., None].astype(xh.dtype)                                # dt folds into v
    decay = jnp.broadcast_to(decay_h[..., None], (b, l, d["heads"], d["state"]))
    to_bhl = lambda t: jnp.moveaxis(t, 2, 1)                               # (B,H,L,·)
    return to_bhl(q), to_bhl(k), to_bhl(v), to_bhl(decay), xh


def mamba2_forward(cfg: ModelConfig, params: dict, x: Array,
                   state: Optional[dict] = None) -> Tuple[Array, dict]:
    """Full-sequence (prefill/train) pass. x: (B, L, D)."""
    b, l, _ = x.shape
    z, xbc, dt, d = _mamba2_project(cfg, params, x)
    prev = init_mamba2_state(cfg, b, x.dtype) if state is None else state
    # causal depthwise conv with carried state
    ctx = jnp.concatenate([prev["conv"].astype(xbc.dtype), xbc], axis=1)
    new_conv = ctx[:, -(cfg.ssm_conv - 1):, :]
    xbc_conv = sum(ctx[:, i:i + l, :] * params["conv_w"][i] for i in range(cfg.ssm_conv))
    xbc_conv = xbc_conv + params["conv_b"]
    q, k, v, decay, xh = _mamba2_ssm_inputs(cfg, params, xbc_conv, dt, d)
    out, s_new = kops.linear_scan(q, k, v, decay, bonus=None,
                                  initial_state=prev["ssm"], use_kernel=cfg.use_flash)
    y = jnp.moveaxis(out, 1, 2).astype(x.dtype)            # (B, L, H, P)
    y = y + params["D"].astype(x.dtype)[None, None, :, None] * xh
    y = y.reshape(b, l, d["d_inner"])
    y = common.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                        params["norm_w"], cfg.norm_eps)
    return jnp.einsum("ble,ed->bld", y, params["out_proj"]), {"conv": new_conv, "ssm": s_new}


def mamba2_decode(cfg: ModelConfig, params: dict, x: Array, state: dict) -> Tuple[Array, dict]:
    """Single-token step. x: (B, 1, D)."""
    b = x.shape[0]
    z, xbc, dt, d = _mamba2_project(cfg, params, x)
    ctx = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)  # (B, conv_w, C)
    new_conv = ctx[:, 1:, :]
    xbc_conv = jnp.einsum("bwc,wc->bc", ctx, params["conv_w"])[:, None, :] + params["conv_b"]
    q, k, v, decay, xh = _mamba2_ssm_inputs(cfg, params, xbc_conv, dt, d)
    out, s_new = kops.linear_scan_decode(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                         decay[:, :, 0], state["ssm"], bonus=None)
    y = out.reshape(b, 1, d["heads"], d["head_dim"]).astype(x.dtype)
    y = y + params["D"].astype(x.dtype)[None, None, :, None] * xh
    y = y.reshape(b, 1, d["d_inner"])
    y = common.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                        params["norm_w"], cfg.norm_eps)
    return jnp.einsum("ble,ed->bld", y, params["out_proj"]), {"conv": new_conv, "ssm": s_new}


# ---------------------------------------------------------------------------
# RWKV6 (Finch)
# ---------------------------------------------------------------------------

RWKV_LORA = 64  # low-rank dim of the data-dependent decay projection


def rwkv6_dims(cfg: ModelConfig) -> Dict[str, int]:
    heads = cfg.resolved_ssm_heads or cfg.d_model // 64
    return dict(heads=heads, head_dim=cfg.d_model // heads)


def init_rwkv6(cfg: ModelConfig, key: Array) -> dict:
    d = cfg.d_model
    dd = rwkv6_dims(cfg)
    ks = common.split_keys(key, 12)
    scale_out = 1.0 / max(1, cfg.num_layers) ** 0.5
    return {
        # time-mix interpolation coefficients (static mu per channel)
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(cfg.dtype),  # r,k,v,w,g
        "w_r": dense_init(ks[1], (d, d), cfg.dtype),
        "w_k": dense_init(ks[2], (d, d), cfg.dtype),
        "w_v": dense_init(ks[3], (d, d), cfg.dtype),
        "w_g": dense_init(ks[4], (d, d), cfg.dtype),
        "w_o": dense_init(ks[5], (d, d), cfg.dtype, scale=scale_out),
        # data-dependent decay: w_t = exp(-exp(w0 + (tanh(x A) B)))
        "decay_w0": jnp.full((d,), -2.0, jnp.float32),
        "decay_A": dense_init(ks[6], (d, RWKV_LORA), cfg.dtype),
        "decay_B": dense_init(ks[7], (RWKV_LORA, d), cfg.dtype),
        "bonus_u": dense_init(ks[8], (dd["heads"], dd["head_dim"]), jnp.float32, scale=1.0),
        "ln_w": jnp.ones((d,), jnp.float32),
        "ln_b": jnp.zeros((d,), jnp.float32),
        # channel-mix
        "cm_mu": (jax.random.uniform(ks[9], (2, d), jnp.float32)).astype(cfg.dtype),
        "cm_rk": dense_init(ks[10], (d, d), cfg.dtype),
        "cm_kv": dense_init(ks[11], (d, int(3.5 * d) // 32 * 32), cfg.dtype),
        "cm_vo": dense_init(ks[11], (int(3.5 * d) // 32 * 32, d), cfg.dtype, scale=scale_out),
    }


def init_rwkv6_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    dd = rwkv6_dims(cfg)
    return {
        "shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_cm": jnp.zeros((batch, cfg.d_model), dtype),
        "ssm": jnp.zeros((batch, dd["heads"], dd["head_dim"], dd["head_dim"]), jnp.float32),
    }


def _token_shift(x: Array, prev: Array) -> Array:
    """x: (B, L, D); prev: (B, D) = last token before this block."""
    return jnp.concatenate([prev[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1)


def _rwkv6_timemix_inputs(cfg, params, x, prev_tok):
    b, l, d = x.shape
    dd = rwkv6_dims(cfg)
    xx = _token_shift(x, prev_tok)
    mu = params["mu"].astype(jnp.float32)
    mix = lambda i: (x.astype(jnp.float32) * mu[i] + xx.astype(jnp.float32) * (1 - mu[i])).astype(x.dtype)
    r = jnp.einsum("bld,de->ble", mix(0), params["w_r"])
    k = jnp.einsum("bld,de->ble", mix(1), params["w_k"])
    v = jnp.einsum("bld,de->ble", mix(2), params["w_v"])
    g = jnp.einsum("bld,de->ble", mix(4), params["w_g"])
    # data-dependent decay (the RWKV6 contribution)
    wx = jnp.tanh(jnp.einsum("bld,dr->blr", mix(3), params["decay_A"]).astype(jnp.float32))
    w_log = params["decay_w0"] + jnp.einsum("blr,rd->bld", wx.astype(cfg.dtype),
                                            params["decay_B"]).astype(jnp.float32)
    # clamp per-step log-decay to the Pallas kernel's numeric contract
    decay = jnp.exp(-jnp.clip(jnp.exp(w_log), 0.0, MAX_NEG_LOGW))      # (B, L, D)
    hsplit = lambda t: jnp.moveaxis(t.reshape(b, l, dd["heads"], dd["head_dim"]), 2, 1)
    return hsplit(r), hsplit(k), hsplit(v), hsplit(decay.astype(jnp.float32)), g


def _rwkv6_out(cfg, params, out_bhlv, g, b, l):
    dd = rwkv6_dims(cfg)
    y = jnp.moveaxis(out_bhlv, 1, 2).reshape(b, l, cfg.d_model)
    # per-head groupnorm == layer_norm applied per head; approximate with LN on D
    y = common.layer_norm(y, params["ln_w"], params["ln_b"], cfg.norm_eps)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    return jnp.einsum("bld,de->ble", y, params["w_o"])


def rwkv6_timemix(cfg: ModelConfig, params: dict, x: Array,
                  state: Optional[dict], decode: bool) -> Tuple[Array, Array, Array]:
    """Returns (out, new_ssm_state, new_shift). x: (B, L, D)."""
    b, l, _ = x.shape
    prev_tok = state["shift_tm"] if state is not None else jnp.zeros((b, cfg.d_model), x.dtype)
    r, k, v, decay, g = _rwkv6_timemix_inputs(cfg, params, x, prev_tok)
    s0 = state["ssm"] if state is not None else None
    if decode:
        out, s_new = kops.linear_scan_decode(r[:, :, 0], k[:, :, 0], v[:, :, 0],
                                             decay[:, :, 0], s0, bonus=params["bonus_u"])
        out = out[:, :, None, :]
    else:
        out, s_new = kops.linear_scan(r, k, v, decay, bonus=params["bonus_u"],
                                      initial_state=s0, use_kernel=cfg.use_flash)
    y = _rwkv6_out(cfg, params, out, g, b, l)
    return y, s_new, x[:, -1, :]


def rwkv6_channelmix(cfg: ModelConfig, params: dict, x: Array,
                     state: Optional[dict]) -> Tuple[Array, Array]:
    b, l, _ = x.shape
    prev_tok = state["shift_cm"] if state is not None else jnp.zeros((b, cfg.d_model), x.dtype)
    xx = _token_shift(x, prev_tok)
    mu = params["cm_mu"].astype(jnp.float32)
    mix = lambda i: (x.astype(jnp.float32) * mu[i] + xx.astype(jnp.float32) * (1 - mu[i])).astype(x.dtype)
    rr = jax.nn.sigmoid(jnp.einsum("bld,de->ble", mix(0), params["cm_rk"]).astype(jnp.float32))
    kk = jnp.einsum("bld,de->ble", mix(1), params["cm_kv"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    vv = jnp.einsum("blf,fd->bld", kk, params["cm_vo"])
    return (rr.astype(x.dtype) * vv), x[:, -1, :]
