"""Mixture-of-Experts FFN (DeepSeek-MoE fine-grained / Llama4 style).

Switch-style dispatch with capacity factor: tokens are routed to their top-k
experts through one-hot dispatch/combine einsums, which lower to expert
all-to-alls under GSPMD when experts are sharded over the ``model`` mesh
axis.  Shared experts (DeepSeek) run densely on every token.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Array, ModelConfig, dense_init


def init_moe(cfg: ModelConfig, key: Array) -> dict:
    d, f = cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    e, se = cfg.num_experts, cfg.num_shared_experts
    ks = common.split_keys(key, 7)
    params = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), cfg.dtype),
        "w_up": dense_init(ks[2], (e, d, f), cfg.dtype),
        "w_down": dense_init(ks[3], (e, f, d), cfg.dtype, scale=1.0 / max(1, cfg.num_layers) ** 0.5),
    }
    if se:
        params["shared_gate"] = dense_init(ks[4], (d, se * f), cfg.dtype)
        params["shared_up"] = dense_init(ks[5], (d, se * f), cfg.dtype)
        params["shared_down"] = dense_init(ks[6], (se * f, d), cfg.dtype)
    return params


GROUP_SIZE = 1024   # routing-group length (GShard-style); bounds capacity


def _group_size(t: int) -> int:
    g = min(GROUP_SIZE, t)
    while t % g:
        g -= 1
    return g


def moe_ffn(cfg: ModelConfig, params: dict, x: Array) -> Tuple[Array, Array]:
    """x: (B, L, D) -> (out, aux_loss).

    Dropping MoE with capacity factor, GShard-style *grouped* routing:
    tokens are reshaped into (G, S) groups and each group routes with its
    own capacity C = cf*k*S/E.  The (G, S, E, C) dispatch tensor is linear
    in token count (not quadratic like global capacity) and shards G over
    the data axis while experts shard over the model axis — the g->e
    resharding between the dispatch and expert einsums is exactly the MoE
    all-to-all under GSPMD.
    """
    b, l, d = x.shape
    t = b * l
    k = cfg.experts_per_token
    e = cfg.num_experts
    s = _group_size(t)
    g_n = t // s
    cap = max(4, int(cfg.capacity_factor * k * s / e) + 1)

    xg = x.reshape(g_n, s, d)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                       # (G, S, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # (G, S, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position within each expert's per-group buffer (token order per slot)
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)       # (G, S, k, E)
    flat = onehot.reshape(g_n, s * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(g_n, s, k, e)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)                # (G, S, k)
    keep = pos < cap
    gate_vals = gate_vals * keep

    disp = (onehot.astype(x.dtype)[..., None]
            * jax.nn.one_hot(pos, cap, dtype=x.dtype)[..., None, :]
            * keep[..., None, None].astype(x.dtype))              # (G,S,k,E,C)
    combine = (disp * gate_vals[..., None, None].astype(x.dtype)).sum(axis=2)
    disp = disp.sum(axis=2)                                       # (G, S, E, C)

    xe = jnp.einsum("gsd,gsec->gecd", xg, disp)                   # (G, E, C, D)
    gg = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
    uu = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    h = jax.nn.silu(gg.astype(jnp.float32)).astype(xe.dtype) * uu
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    y = jnp.einsum("gecd,gsec->gsd", ye, combine)

    if cfg.num_shared_experts:
        y = y + common.swiglu(xg, params["shared_gate"], params["shared_up"],
                              params["shared_down"])

    # load-balance auxiliary loss (Switch eq. 4)
    density = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32),
                       axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_proxy) * e * cfg.router_aux_weight
    return y.reshape(b, l, d), aux
