"""VLM support (InternVL2): stubbed vision frontend + LM backbone glue.

Per the brief, the ViT/projector frontend is a STUB — ``vision_stub_embeds``
supplies patch embeddings of the right shape (InternViT-300M: 1024-d patch
embeddings, 256 tokens per 448px tile after pixel-shuffle), and the model
under test is the InternLM2 language backbone that consumes them
[arXiv:2404.16821].
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.common import Array, ModelConfig


def vision_stub_embeds(cfg: ModelConfig, batch: int,
                       key: Optional[Array] = None) -> Array:
    """Precomputed patch embeddings stand-in: (B, vision_tokens, vision_dim)."""
    shape = (batch, cfg.vision_tokens, cfg.vision_embed_dim)
    if key is None:
        return jnp.zeros(shape, jnp.float32)
    return jax.random.normal(key, shape, jnp.float32) * 0.02


def vlm_forward(cfg: ModelConfig, params: dict, tokens: Array,
                patch_embeds: Array) -> Tuple[Array, Array]:
    """Train pass over [vision prefix; text tokens]."""
    return transformer.forward(cfg, params, tokens, prefix_embeds=patch_embeds)


def vlm_prefill(cfg: ModelConfig, params: dict, tokens: Array,
                patch_embeds: Array, max_len: int):
    return transformer.prefill(cfg, params, tokens, max_len,
                               prefix_embeds=patch_embeds)
