"""DiT diffusion transformer with AdaLN-Zero conditioning (Diffuse stage).

Architecture follows Peebles & Xie DiT / SD3-style joint conditioning
simplified to a single stream: latent patches and text-condition tokens are
concatenated into one sequence; per-block modulation (shift/scale/gate x2)
comes from the timestep + pooled-condition embedding.  Layers are
homogeneous, executed with one ``lax.scan``.

The Diffuse stage runs ``num_steps`` denoising iterations of this network —
the compute-dominant, SP-scalable stage the paper's dispatcher reasons
about.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import common
from repro.models.common import Array, dense_init


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    latent_dim: int               # channels per latent token (after patchify)
    cond_dim: int                 # encoder hidden size
    time_embed_dim: int = 256
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    use_flash: bool = False
    use_fused_adaln: bool = False  # route modulated norms through the Pallas kernel
    source: str = ""


def init(cfg: DiTConfig, key: Array) -> dict:
    d = cfg.d_model
    ks = common.split_keys(key, 8)
    scale_o = 1.0 / max(1, cfg.num_layers) ** 0.5

    def layer_init(k):
        kk = common.split_keys(k, 7)
        return {
            "wq": dense_init(kk[0], (d, d), cfg.dtype),
            "wk": dense_init(kk[1], (d, d), cfg.dtype),
            "wv": dense_init(kk[2], (d, d), cfg.dtype),
            "wo": dense_init(kk[3], (d, d), cfg.dtype, scale=scale_o),
            "w_up": dense_init(kk[4], (d, cfg.d_ff), cfg.dtype),
            "w_down": dense_init(kk[5], (cfg.d_ff, d), cfg.dtype, scale=scale_o),
            # AdaLN-Zero: 6 modulation vectors, zero-init so blocks start as identity
            "mod": jnp.zeros((d, 6 * d), cfg.dtype),
        }

    lkeys = jnp.stack(common.split_keys(ks[0], cfg.num_layers))
    return {
        "x_in": dense_init(ks[1], (cfg.latent_dim, d), cfg.dtype),
        "cond_in": dense_init(ks[2], (cfg.cond_dim, d), cfg.dtype),
        "t_mlp1": dense_init(ks[3], (cfg.time_embed_dim, d), cfg.dtype),
        "t_mlp2": dense_init(ks[4], (d, d), cfg.dtype),
        "layers": jax.vmap(layer_init)(lkeys),
        "final_mod": jnp.zeros((d, 2 * d), cfg.dtype),
        "x_out": dense_init(ks[5], (d, cfg.latent_dim), cfg.dtype, scale=0.02),
        "pos_freq": dense_init(ks[6], (2, d // 2), jnp.float32, scale=1.0),
    }


def timestep_embedding(t: Array, dim: int) -> Array:
    """Sinusoidal embedding; t: (B,) float in [0, 1000]."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def _modulated_norm(cfg: DiTConfig, x, scale, shift):
    if cfg.use_fused_adaln:
        return kops.adaln_rmsnorm(x, scale, shift, eps=cfg.norm_eps, use_kernel=True)
    return kops.adaln_rmsnorm(x, scale, shift, eps=cfg.norm_eps, use_kernel=False)


def forward(cfg: DiTConfig, params: dict, latents: Array, t: Array,
            cond: Array, cond_pooled: Optional[Array] = None) -> Array:
    """One denoising network evaluation.

    latents: (B, Lx, latent_dim); t: (B,); cond: (B, Lc, cond_dim).
    Returns predicted noise (B, Lx, latent_dim).
    """
    b, lx, _ = latents.shape
    lc = cond.shape[1]
    h = cfg.num_heads
    dh = cfg.d_model // h

    x = jnp.einsum("blc,cd->bld", latents.astype(cfg.dtype), params["x_in"])
    c = jnp.einsum("blc,cd->bld", cond.astype(cfg.dtype), params["cond_in"])
    x = jnp.concatenate([c, x], axis=1)                       # joint stream
    l = lx + lc

    # absolute 2-channel sin/cos positions (latent grid is 1D-flattened here)
    pos = jnp.arange(l, dtype=jnp.float32)
    pf = params["pos_freq"].astype(jnp.float32)
    pe = jnp.concatenate([jnp.sin(pos[:, None] * pf[0][None]),
                          jnp.cos(pos[:, None] * pf[1][None])], axis=-1)
    x = x + pe[None].astype(cfg.dtype)

    temb = timestep_embedding(t, cfg.time_embed_dim)
    tc = jnp.einsum("be,ed->bd", temb.astype(cfg.dtype), params["t_mlp1"])
    if cond_pooled is not None:
        tc = tc + cond_pooled.astype(cfg.dtype)
    tc = jnp.einsum("bd,de->be", jax.nn.silu(tc.astype(jnp.float32)).astype(cfg.dtype),
                    params["t_mlp2"])

    def block(x, p):
        mod = jnp.einsum("bd,de->be", tc, p["mod"]).reshape(b, 6, cfg.d_model)
        s1, sh1, g1, s2, sh2, g2 = [mod[:, i] for i in range(6)]
        hn = _modulated_norm(cfg, x, s1, sh1)
        q = jnp.einsum("bld,de->ble", hn, p["wq"]).reshape(b, l, h, dh)
        k = jnp.einsum("bld,de->ble", hn, p["wk"]).reshape(b, l, h, dh)
        v = jnp.einsum("bld,de->ble", hn, p["wv"]).reshape(b, l, h, dh)
        if cfg.use_flash:
            a = kops.flash_attention(q, k, v, causal=False, use_kernel=True)
        else:
            a = common.attention(q, k, v, None)
        a = jnp.einsum("ble,ed->bld", a.reshape(b, l, cfg.d_model), p["wo"])
        x = x + g1[:, None, :] * a
        hn = _modulated_norm(cfg, x, s2, sh2)
        f = common.gelu_mlp(hn, p["w_up"], p["w_down"])
        x = x + g2[:, None, :] * f
        return x, 0

    x, _ = jax.lax.scan(block, x, params["layers"])
    fmod = jnp.einsum("bd,de->be", tc, params["final_mod"]).reshape(b, 2, cfg.d_model)
    x = _modulated_norm(cfg, x, fmod[:, 0], fmod[:, 1])
    eps = jnp.einsum("bld,dc->blc", x[:, lc:, :], params["x_out"])
    return eps.astype(jnp.float32)


def ddim_denoise(cfg: DiTConfig, params: dict, noise: Array, cond: Array,
                 num_steps: int, key: Optional[Array] = None) -> Array:
    """Multi-step denoising loop (the Diffuse stage's runtime body).

    DDIM with a linear alpha-bar schedule; deterministic (eta=0).
    """
    betas = jnp.linspace(1e-4, 0.02, 1000, dtype=jnp.float32)
    alpha_bar = jnp.cumprod(1.0 - betas)
    ts = jnp.linspace(999, 0, num_steps).astype(jnp.int32)

    def step(i, x):
        t = ts[i]
        t_next = jnp.where(i + 1 < num_steps, ts[jnp.minimum(i + 1, num_steps - 1)], -1)
        ab_t = alpha_bar[t]
        ab_n = jnp.where(t_next >= 0, alpha_bar[jnp.maximum(t_next, 0)], 1.0)
        tb = jnp.full((x.shape[0],), t, jnp.float32)
        eps = forward(cfg, params, x, tb, cond)
        x0 = (x - jnp.sqrt(1 - ab_t) * eps) / jnp.sqrt(ab_t)
        return jnp.sqrt(ab_n) * x0 + jnp.sqrt(1 - ab_n) * eps

    return jax.lax.fori_loop(0, num_steps, step, noise)


# ---------------------------------------------------------------------------
# AE-KL latent decoder (Decode stage) — conv upsampler, memory-bound
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecoderConfig:
    name: str
    latent_channels: int
    base_channels: int = 512
    num_upsamples: int = 3        # 8x spatial upscale
    res_blocks: int = 2           # residual conv blocks per level
    out_channels: int = 3
    dtype: Any = jnp.bfloat16
    source: str = ""


def init_decoder(cfg: DecoderConfig, key: Array) -> dict:
    nconv = 1 + cfg.num_upsamples * (1 + cfg.res_blocks) + 1
    ks = common.split_keys(key, nconv + 1)
    ch = cfg.base_channels
    params = {"conv_in": dense_init(ks[0], (3, 3, cfg.latent_channels, ch), cfg.dtype)}
    ki = 1
    for i in range(cfg.num_upsamples):
        cin = max(ch // (2 ** i), 32)
        cout = max(ch // (2 ** (i + 1)), 32)
        params[f"up{i}_in"] = dense_init(ks[ki], (3, 3, cin, cout), cfg.dtype); ki += 1
        for r in range(cfg.res_blocks):
            params[f"up{i}_res{r}"] = dense_init(ks[ki], (3, 3, cout, cout), cfg.dtype); ki += 1
    cfin = max(ch // (2 ** cfg.num_upsamples), 32)
    params["conv_out"] = dense_init(ks[ki], (3, 3, cfin, cfg.out_channels), cfg.dtype)
    return params


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def decode_latent(cfg: DecoderConfig, params: dict, z: Array) -> Array:
    """z: (B, h, w, latent_channels) -> pixels (B, 8h, 8w, 3).

    Video pipelines fold frames into the batch dim (the profiler's cost
    model accounts for the heavier 3D-conv + temporal-upsample cost of the
    real AE; see DESIGN.md §assumptions).
    """
    x = _conv(z.astype(cfg.dtype), params["conv_in"])
    for i in range(cfg.num_upsamples):
        b, hh, ww, c = x.shape
        x = jax.nn.silu(x.astype(jnp.float32)).astype(cfg.dtype)
        x = jax.image.resize(x, (b, hh * 2, ww * 2, c), "nearest")
        x = _conv(x, params[f"up{i}_in"])
        for r in range(cfg.res_blocks):
            h = jax.nn.silu(x.astype(jnp.float32)).astype(cfg.dtype)
            x = x + _conv(h, params[f"up{i}_res{r}"])
    x = jax.nn.silu(x.astype(jnp.float32)).astype(cfg.dtype)
    return jnp.tanh(_conv(x, params["conv_out"]).astype(jnp.float32))
