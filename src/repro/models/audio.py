"""MusicGen support: codebook-interleaved decoder over EnCodec tokens.

Per the brief, the EnCodec conv codec is a STUB — inputs are precomputed
frame tokens (B, K, T) over K=4 codebooks with 2048 entries each; the model
under test is the decoder-only transformer with per-codebook embeddings and
heads and the *delay pattern* interleaving [arXiv:2306.05284].
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.common import Array, ModelConfig


def codec_stub_tokens(cfg: ModelConfig, batch: int, frames: int,
                      key: Optional[Array] = None) -> Array:
    """EnCodec tokens stand-in: (B, K, T) int32."""
    if key is None:
        return jnp.zeros((batch, cfg.num_codebooks, frames), jnp.int32)
    return jax.random.randint(key, (batch, cfg.num_codebooks, frames),
                              0, cfg.vocab_size)


def apply_delay_pattern(tokens: Array, pad_id: int = 0) -> Array:
    """MusicGen delay interleave: codebook k is shifted right by k frames so
    one decode step predicts one frame across all codebooks causally."""
    b, k, t = tokens.shape
    out = jnp.full((b, k, t), pad_id, tokens.dtype)
    for i in range(k):
        out = out.at[:, i, i:].set(tokens[:, i, : t - i])
    return out


def undo_delay_pattern(tokens: Array) -> Array:
    b, k, t = tokens.shape
    out = jnp.zeros_like(tokens)
    for i in range(k):
        out = out.at[:, i, : t - i].set(tokens[:, i, i:])
    return out


def audio_forward(cfg: ModelConfig, params: dict, tokens: Array) -> Tuple[Array, Array]:
    """tokens: (B, K, T) delayed codec tokens -> logits (B, T, K, V)."""
    return transformer.forward(cfg, params, tokens)


def audio_prefill(cfg: ModelConfig, params: dict, tokens: Array, max_len: int):
    return transformer.prefill(cfg, params, tokens, max_len)
