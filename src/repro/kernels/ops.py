"""Public jit'd entry points for the Pallas kernels, with jnp fallbacks.

Every op takes ``use_kernel``: False routes to the pure-jnp oracle in
``ref.py`` (the CPU-correct path used by smoke tests and the serving
examples); True routes to the Pallas TPU kernel (validated on CPU with
``interpret=True`` in the test suite; compiled for real on TPU).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref

Array = jax.Array

_INTERPRET = jax.default_backend() == "cpu"  # interpret Pallas on CPU


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    use_kernel: bool = False, interpret: Optional[bool] = None) -> Array:
    """q: (B, Lq, H, D); k/v: (B, Lkv, H, D). GQA must be expanded upstream."""
    if not use_kernel:
        lq, lkv = q.shape[1], k.shape[1]
        mask = None
        if causal or window:
            qpos = jnp.arange(lq) + (lkv - lq)
            kpos = jnp.arange(lkv)
            mask = kpos[None, :] <= qpos[:, None]
            if window:
                mask &= kpos[None, :] > qpos[:, None] - window
        return ref.attention_ref(q, k, v, mask, softcap)
    from repro.kernels import flash_attention as fa
    return fa.flash_attention(q, k, v, causal=causal, window=window, softcap=softcap,
                              interpret=_INTERPRET if interpret is None else interpret)


# ---------------------------------------------------------------------------
# Gated linear-attention scan (Mamba2 / RWKV6)
# ---------------------------------------------------------------------------

def linear_scan(q: Array, k: Array, v: Array, decay: Array, *,
                bonus: Optional[Array] = None, initial_state: Optional[Array] = None,
                use_kernel: bool = False, interpret: Optional[bool] = None,
                chunk: int = 32) -> Tuple[Array, Array]:
    """(B,H,L,K) inputs -> (out (B,H,L,V), final_state (B,H,K,V))."""
    if not use_kernel:
        return ref.chunked_linear_scan_ref(q, k, v, decay, bonus, initial_state, chunk)
    from repro.kernels import ssm_scan
    return ssm_scan.ssm_scan(q, k, v, decay, bonus=bonus, initial_state=initial_state,
                             chunk=chunk,
                             interpret=_INTERPRET if interpret is None else interpret)


def linear_scan_decode(q: Array, k: Array, v: Array, decay: Array, state: Array,
                       *, bonus: Optional[Array] = None) -> Tuple[Array, Array]:
    """Single-token recurrence; always the jnp path (it is a matvec)."""
    return ref.linear_scan_decode_ref(q, k, v, decay, state, bonus)


# ---------------------------------------------------------------------------
# AdaLN-modulated RMSNorm (DiT)
# ---------------------------------------------------------------------------

def adaln_rmsnorm(x: Array, scale: Array, shift: Array, *, eps: float = 1e-6,
                  use_kernel: bool = False, interpret: Optional[bool] = None) -> Array:
    if not use_kernel:
        return ref.adaln_rmsnorm_ref(x, scale, shift, eps)
    from repro.kernels import adaln_rmsnorm as ar
    return ar.adaln_rmsnorm(x, scale, shift, eps=eps,
                            interpret=_INTERPRET if interpret is None else interpret)
