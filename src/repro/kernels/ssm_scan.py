"""Chunked gated linear-attention scan as a Pallas TPU kernel.

Serves both Mamba2 (scalar-per-head decay, inclusive read) and RWKV6
(per-channel data-dependent decay, strict-past read + bonus-u current-token
path).  The sequence is blocked into chunks of ``CHUNK`` tokens; the chunk
axis is the innermost sequential grid dimension carrying the running state
S (K x V) in VMEM scratch, so HBM traffic is O(L) while intra-chunk work is
MXU matmuls.

Numerics: with chunk reference point at the chunk start, the only factor that
grows is exp(-cumlogdecay) <= exp(MAX_NEG_LOGW * CHUNK).  We clamp per-step
log-decay at ``-MAX_NEG_LOGW`` so that bound stays inside f32 range
(5.4 * 16 = 86.4 < log(f32_max) ~ 88.7).  The model code applies the same
clamp, so kernel == oracle semantics (a per-step decay floor of
exp(-5.4) ~ 0.45% — contributions below it are numerically dead anyway).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

CHUNK = 16
MAX_NEG_LOGW = 5.4  # per-step clamp; exp(5.4 * 16) < f32 max


def _scan_kernel(q_ref, k_ref, v_ref, w_ref, bonus_ref, s0_ref,
                 o_ref, sf_ref, s_scr, *, chunk: int, strict: bool):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)                # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)                # (C, V)
    w = w_ref[0].astype(jnp.float32)                # (C, K)

    logw = jnp.maximum(jnp.log(jnp.maximum(w, 1e-30)), -MAX_NEG_LOGW)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri_incl = (cols <= rows).astype(jnp.float32)
    cum = jax.lax.dot_general(tri_incl, logw, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # inclusive cumsum
    ctot = cum[chunk - 1, :]                         # (K,)

    q_in = q * jnp.exp(cum - logw) if strict else q * jnp.exp(cum)
    s = s_scr[...]
    inter = jax.lax.dot_general(q_in, s, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (C, V)

    k_in = k * jnp.exp(-cum)
    a = jax.lax.dot_general(q_in, k_in, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (C, C)
    mask = (cols < rows) if strict else (cols <= rows)
    a = a * mask.astype(jnp.float32)
    intra = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    if strict:
        bonus = bonus_ref[0].astype(jnp.float32)     # (K,)
        cur = jnp.sum(q * bonus[None, :] * k, axis=-1, keepdims=True)
        intra = intra + cur * v

    o_ref[0] = (inter + intra).astype(o_ref.dtype)

    k_out = k * jnp.exp(ctot[None, :] - cum)
    s_new = jnp.exp(ctot)[:, None] * s + jax.lax.dot_general(
        k_out, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scr[...] = s_new
    sf_ref[0] = s_new


def ssm_scan(q: Array, k: Array, v: Array, decay: Array, *,
             bonus: Optional[Array] = None, initial_state: Optional[Array] = None,
             chunk: int = CHUNK, interpret: bool = False) -> Tuple[Array, Array]:
    """q/k/decay: (B, H, L, K); v: (B, H, L, V); bonus: (H, K) or None.

    Returns (out (B, H, L, V) in v.dtype, final_state (B, H, K, V) f32).
    """
    b, h, l, dk = q.shape
    dv = v.shape[-1]
    strict = bonus is not None

    pad = (-l) % chunk
    if pad:
        zk = jnp.zeros((b, h, pad, dk), q.dtype)
        q = jnp.concatenate([q, zk], 2)
        k = jnp.concatenate([k, zk.astype(k.dtype)], 2)
        v = jnp.concatenate([v, jnp.zeros((b, h, pad, dv), v.dtype)], 2)
        decay = jnp.concatenate([decay, jnp.ones((b, h, pad, dk), decay.dtype)], 2)
    lp = l + pad
    n = lp // chunk

    bh = b * h
    qf = q.reshape(bh, lp, dk)
    kf = k.reshape(bh, lp, dk)
    vf = v.reshape(bh, lp, dv)
    wf = decay.reshape(bh, lp, dk)
    bonus_full = (jnp.tile(bonus, (b, 1)) if strict
                  else jnp.zeros((bh, dk), jnp.float32))
    s0 = (initial_state.reshape(bh, dk, dv).astype(jnp.float32) if initial_state is not None
          else jnp.zeros((bh, dk, dv), jnp.float32))

    kernel = functools.partial(_scan_kernel, chunk=chunk, strict=strict)
    out, s_final = pl.pallas_call(
        kernel,
        grid=(bh, n),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, dk), lambda i, ci: (i, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, ci: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, ci: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, lp, dv), v.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, wf, bonus_full, s0)
    return out[:, :l].reshape(b, h, l, dv), s_final.reshape(b, h, dk, dv)
