"""Pallas TPU kernels for the compute hot spots, with pure-jnp oracles.

Kernels (each: <name>.py = pl.pallas_call + BlockSpec; ops.py = jit'd
wrappers; ref.py = oracle):

* ``flash_attention`` — tiled online-softmax attention (causal / sliding-
  window / softcap), the Diffuse-stage hot spot.
* ``ssm_scan`` — chunked gated linear-attention scan shared by Mamba2 and
  RWKV6 (data-dependent decay, bonus-u path).
* ``adaln_rmsnorm`` — AdaLN-Zero modulated RMSNorm fusion (DiT blocks).

Validated against the oracles with ``interpret=True`` on CPU; compiled for
TPU with MXU-aligned (multiple-of-128) tiles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
