"""AdaLN-Zero modulated RMSNorm as a Pallas TPU kernel (DiT hot spot).

DiT blocks apply ``norm(x) * (1 + scale_b) + shift_b`` with per-*batch*
modulation vectors derived from the timestep/condition embedding.  Fusing
the norm with the modulation saves one full HBM round-trip of the
activation tensor per DiT sublayer (2 per block), which matters because the
Decode/Diffuse stages are bandwidth-sensitive at high resolution.

Tiling: rows (B*L) blocked by ``block_rows``; D kept whole (<= 8192 for all
zoo configs -> a (256, 8192) f32 tile is 8 MiB, within v5e's 16 MiB VMEM
alongside in/out streams at bf16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(x_ref, scale_ref, shift_ref, o_ref, *, eps: float):
    x = x_ref[0].astype(jnp.float32)                   # (block_rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    xn = x * jax.lax.rsqrt(var + eps)
    s = scale_ref[0].astype(jnp.float32)               # (block_rows, D)
    t = shift_ref[0].astype(jnp.float32)
    o_ref[0] = (xn * (1.0 + s) + t).astype(o_ref.dtype)


def adaln_rmsnorm(x: Array, scale: Array, shift: Array, *, eps: float = 1e-6,
                  block_rows: int = 256, interpret: bool = False) -> Array:
    """x: (B, L, D); scale/shift: (B, D)."""
    b, l, d = x.shape
    rows = b * l
    xf = x.reshape(rows, d)
    sf = jnp.broadcast_to(scale[:, None, :], (b, l, d)).reshape(rows, d)
    tf = jnp.broadcast_to(shift[:, None, :], (b, l, d)).reshape(rows, d)

    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        sf = jnp.pad(sf, ((0, pad), (0, 0)))
        tf = jnp.pad(tf, ((0, pad), (0, 0)))
    n = xf.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, block_rows, d), lambda i: (0, i, 0))] * 3,
        out_specs=pl.BlockSpec((1, block_rows, d), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((1, xf.shape[0], d), x.dtype),
        interpret=interpret,
    )(xf[None], sf[None], tf[None])
    return out[0, :rows].reshape(b, l, d)
