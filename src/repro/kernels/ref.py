"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: each kernel's tests sweep shapes and
dtypes and assert_allclose against the functions here.  The model code also
calls these on the CPU path (``use_flash=False``).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Flash attention oracle
# ---------------------------------------------------------------------------

def attention_ref(q: Array, k: Array, v: Array, mask: Optional[Array] = None,
                  softcap: float = 0.0) -> Array:
    """q: (B, Lq, H, D); k/v: (B, Lkv, H, D); mask (Lq, Lkv) True=attend."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    if mask is not None:
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# Gated linear-attention scan oracle (Mamba2 / RWKV6 shared recurrence)
#
#   S_t = diag(decay_t) @ S_{t-1} + k_t (outer) v_t
#   o_t = q_t @ (S_{t-1} + diag(bonus*k_t) applied current step)   [rwkv6]
#   o_t = q_t @ S_t                                                 [mamba2]
#
# decay_t: (B, H, L, K) per-key-channel decay in (0, 1].
# bonus:   (H, K) or None.  When given, the current token contributes via
#          the bonus path instead of entering S before the readout (RWKV).
# ---------------------------------------------------------------------------

def linear_scan_ref(q: Array, k: Array, v: Array, decay: Array,
                    bonus: Optional[Array] = None,
                    initial_state: Optional[Array] = None,
                    ) -> Tuple[Array, Array]:
    """Naive sequential oracle. Shapes:
    q,k,decay: (B, H, L, K); v: (B, H, L, V) -> out (B, H, L, V), S (B, H, K, V).
    """
    b, h, l, dk = q.shape
    dv = v.shape[-1]
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    wf = decay.astype(jnp.float32)
    s0 = (jnp.zeros((b, h, dk, dv), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s, inp):
        qt, kt, vt, wt = inp                      # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,V)
        if bonus is not None:
            s_read = s + bonus[None, :, :, None].astype(jnp.float32) * kv
            s_new = wt[..., :, None] * s + kv
        else:
            s_new = wt[..., :, None] * s + kv
            s_read = s_new
        ot = jnp.einsum("bhk,bhkv->bhv", qt, s_read)
        return s_new, ot

    xs = (jnp.moveaxis(qf, 2, 0), jnp.moveaxis(kf, 2, 0),
          jnp.moveaxis(vf, 2, 0), jnp.moveaxis(wf, 2, 0))
    s_final, out = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(out, 0, 2).astype(v.dtype), s_final


def chunked_linear_scan_ref(q: Array, k: Array, v: Array, decay: Array,
                            bonus: Optional[Array] = None,
                            initial_state: Optional[Array] = None,
                            chunk: int = 32) -> Tuple[Array, Array]:
    """Chunked parallel form (O(L*C) work, O(L/C) sequential steps).

    Within a chunk, with cumulative decays D_t = prod_{s<=t} w_s:
      S_t   = D_t*(S_0 + sum_{s<=t} (k_s/D_s) x v_s)
      o_t   = (q_t*D_t) @ S_0 + sum_{s<=t or <t} A[t,s] v_s
      A[t,s]= (q_t * D_t/D_s) . k_s          (strict past when bonus given)
    Matches linear_scan_ref to fp32 tolerance for decays >= ~0.7^chunk.
    """
    b, h, l, dk = q.shape
    dv = v.shape[-1]
    if l % chunk:
        pad = chunk - l % chunk
        zq = jnp.zeros((b, h, pad, dk), q.dtype)
        q = jnp.concatenate([q, zq], 2)
        k = jnp.concatenate([k, zq.astype(k.dtype)], 2)
        v = jnp.concatenate([v, jnp.zeros((b, h, pad, dv), v.dtype)], 2)
        decay = jnp.concatenate([decay, jnp.ones((b, h, pad, dk), decay.dtype)], 2)
    lp = q.shape[2]
    n = lp // chunk

    qf = q.astype(jnp.float32).reshape(b, h, n, chunk, dk)
    kf = k.astype(jnp.float32).reshape(b, h, n, chunk, dk)
    vf = v.astype(jnp.float32).reshape(b, h, n, chunk, dv)
    wf = decay.astype(jnp.float32).reshape(b, h, n, chunk, dk)

    logw = jnp.log(jnp.clip(wf, 1e-12))
    cum = jnp.cumsum(logw, axis=3)                 # log D_t (inclusive of w_t)
    d_tot = jnp.exp(cum[..., -1, :])               # full-chunk decay (B,H,N,K)

    if bonus is None:
        q_in = qf * jnp.exp(cum)                   # q_t * D_t   (reads S_t)
    else:
        q_in = qf * jnp.exp(cum - logw)            # q_t * D_{t-1} (reads S_{t-1})
    k_out = kf * jnp.exp(cum[..., -1:, :] - cum)   # k_s * D_C/D_s (state update)
    k_in = kf * jnp.exp(-cum)                      # k_s / D_s     (intra-chunk)

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=(-1 if bonus is not None else 0))
    attn = jnp.einsum("bhntk,bhnsk->bhnts", q_in, k_in) * tri
    intra = jnp.einsum("bhnts,bhnsv->bhntv", attn, vf)
    if bonus is not None:
        bn = bonus[None, :, None, None, :].astype(jnp.float32)
        intra = intra + jnp.sum(qf * bn * kf, -1, keepdims=True) * vf

    kv_chunk = jnp.einsum("bhnsk,bhnsv->bhnkv", k_out, vf)  # chunk contribution to S

    s0 = (jnp.zeros((b, h, dk, dv), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s, inp):
        q_in_c, d_tot_c, kv_c = inp
        inter = jnp.einsum("bhtk,bhkv->bhtv", q_in_c, s)
        s_new = d_tot_c[..., :, None] * s + kv_c
        return s_new, inter

    xs = (jnp.moveaxis(q_in, 2, 0), jnp.moveaxis(d_tot, 2, 0), jnp.moveaxis(kv_chunk, 2, 0))
    s_final, inter = jax.lax.scan(step, s0, xs)
    inter = jnp.moveaxis(inter, 0, 2)              # (B,H,N,chunk,V)
    out = (intra + inter).reshape(b, h, lp, dv)[:, :, :l]
    return out.astype(v.dtype), s_final


def linear_scan_decode_ref(q: Array, k: Array, v: Array, decay: Array,
                           state: Array, bonus: Optional[Array] = None,
                           ) -> Tuple[Array, Array]:
    """Single-token recurrent step.  q/k/decay: (B,H,K); v: (B,H,V);
    state: (B,H,K,V) -> (out (B,H,V), new_state)."""
    qf, kf, vf, wf = (x.astype(jnp.float32) for x in (q, k, v, decay))
    sf = state.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    if bonus is not None:
        read = sf + bonus[None, :, :, None].astype(jnp.float32) * kv
        new = wf[..., :, None] * sf + kv
    else:
        new = wf[..., :, None] * sf + kv
        read = new
    out = jnp.einsum("bhk,bhkv->bhv", qf, read)
    return out.astype(v.dtype), new


# ---------------------------------------------------------------------------
# AdaLN-modulated RMSNorm oracle (DiT hot spot)
# ---------------------------------------------------------------------------

def adaln_rmsnorm_ref(x: Array, scale: Array, shift: Array, eps: float = 1e-6) -> Array:
    """x: (B, L, D); scale/shift: (B, D) broadcast over L (AdaLN-Zero)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xn = xf * jax.lax.rsqrt(var + eps)
    out = xn * (1.0 + scale.astype(jnp.float32)[:, None, :]) + shift.astype(jnp.float32)[:, None, :]
    return out.astype(x.dtype)
