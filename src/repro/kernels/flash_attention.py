"""Flash attention as a Pallas TPU kernel.

TPU-native tiling: the (B*H, Lq, D) query stream is blocked (block_q, D) into
VMEM; the KV stream is blocked (block_k, D) and iterated as the innermost
*sequential* grid dimension carrying the online-softmax state (m, l, acc) in
VMEM scratch.  Block sizes default to 128 to match the MXU systolic array;
D is kept whole per block (<= 256 for every config in the zoo).

Supports causal masking, sliding-window (gemma2/starcoder2), and the gemma2
score softcap.  Oracle: ``repro.kernels.ref.attention_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int, softcap: float,
               block_q: int, block_k: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    needed = True
    if causal:
        # skip blocks strictly above the diagonal / outside the window
        first_q = qi * block_q + q_offset
        last_q = first_q + block_q - 1
        first_k = ki * block_k
        needed = first_k <= last_q
        if window:
            needed = jnp.logical_and(needed, (ki + 1) * block_k - 1 > first_q - window)

    @pl.when(needed if causal else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (block_q, D)
        k = k_ref[0].astype(jnp.float32)            # (block_k, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        if causal:
            mask = k_pos <= q_pos
            if window:
                mask = jnp.logical_and(mask, k_pos > q_pos - window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...], l_ref[...] = m_new, l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)             # fully-masked rows -> 0 output
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> Array:
    """q: (B, Lq, H, D); k/v: (B, Lkv, H, D) with H already GQA-expanded."""
    b, lq, h, d = q.shape
    lkv = k.shape[1]
    q_offset = lkv - lq  # decode/extend: queries sit at the end of kv

    block_q = min(block_q, max(8, lq))
    block_k = min(block_k, max(8, lkv))
    pq = (-lq) % block_q
    pk = (-lkv) % block_k

    qt = jnp.moveaxis(q, 2, 1).reshape(b * h, lq, d)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * h, lkv, d)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * h, lkv, d)
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pk), (0, 0)))
        if not causal:
            raise ValueError("non-causal padding unsupported; pad upstream")
    lq_p, lkv_p = lq + pq, lkv + pk

    grid = (b * h, lq_p // block_q, lkv_p // block_k)
    kernel = functools.partial(
        _fa_kernel, scale=1.0 / math.sqrt(d), causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, lq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :lq, :].reshape(b, h, lq, d)
    return jnp.moveaxis(out, 1, 2)
