"""Batched serving engine for the assigned architectures.

The stage-level serving ideas of the paper generalize to LLM serving as
prefill/decode disaggregation (the paper itself cites DistServe/EPD as the
LLM analogue); this module provides the executable stages:

* ``prefill_step``  — full-prompt pass producing last-token logits + cache
  (the compute-bound "Diffuse-like" stage; lowered for prefill_32k);
* ``serve_step``    — ONE token against the KV/state cache (the
  memory-bound stage; lowered for decode_32k / long_500k);
* ``ServeEngine``   — a batch scheduler that groups queued requests into
  padded batches and runs greedy generation (examples/serve_llm.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.common import ModelConfig


def prefill_step(cfg: ModelConfig, params, tokens, max_len: int,
                 prefix_embeds=None):
    return transformer.prefill(cfg, params, tokens, max_len, prefix_embeds)


def serve_step(cfg: ModelConfig, params, tokens, caches, offset):
    """ONE new token per sequence against the cache (the dry-run target)."""
    return transformer.decode_step(cfg, params, tokens, caches, offset)


@dataclasses.dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray            # (L,) int32  [or (K, L) audio]
    max_new: int = 16
    done: bool = False
    output: Optional[np.ndarray] = None


class ServeEngine:
    """Greedy batched generation over padded same-length groups."""

    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: List[GenRequest] = []
        self._prefill = jax.jit(
            lambda p, t: transformer.prefill(cfg, p, t, max_len))
        self._decode = jax.jit(
            lambda p, t, c, o: transformer.decode_step(cfg, p, t, c, o))

    def submit(self, req: GenRequest):
        self.queue.append(req)

    def _pad_group(self) -> Tuple[List[GenRequest], np.ndarray]:
        group = self.queue[: self.max_batch]
        self.queue = self.queue[self.max_batch:]
        lmax = max(r.prompt.shape[-1] for r in group)
        padded = []
        for r in group:
            pad = lmax - r.prompt.shape[-1]
            width = [(0, 0)] * (r.prompt.ndim - 1) + [(pad, 0)]  # left-pad
            padded.append(np.pad(r.prompt, width))
        return group, np.stack(padded)

    def step(self) -> List[GenRequest]:
        """Serve one batch group to completion; returns finished requests."""
        if not self.queue:
            return []
        group, prompts = self._pad_group()
        logits, cache, offset = self._prefill(self.params, jnp.asarray(prompts))
        max_new = max(r.max_new for r in group)
        outs = []
        tok = jnp.argmax(logits[:, -1, ...], axis=-1)
        for _ in range(max_new):
            if self.cfg.modality == "audio_codec":
                step_tok = tok.reshape(len(group), self.cfg.num_codebooks, 1)
            else:
                step_tok = tok.reshape(len(group), 1)
            outs.append(np.asarray(tok))
            logits, cache = self._decode(self.params, step_tok, cache, offset)
            offset = offset + 1
            tok = jnp.argmax(logits[:, -1, ...], axis=-1)
        gen = np.stack(outs, axis=1)
        for i, r in enumerate(group):
            r.output = gen[i, : r.max_new]
            r.done = True
        return group
