"""LLM-side serving: batched prefill/decode engine for the assigned archs."""
from repro.serving import engine

__all__ = ["engine"]
