"""Train a small decoder LM for a few hundred steps (end-to-end driver).

Default is a ~5M-param model sized for this CPU container; ``--preset 100m``
gives the ~100M configuration for real hardware.  Loss is printed every 10
steps and must decrease; a checkpoint is written at the end.

  PYTHONPATH=src python examples/train_llm.py --steps 200
  PYTHONPATH=src python examples/train_llm.py --arch rwkv6-3b --steps 100
"""
import argparse
import dataclasses

import jax.numpy as jnp

import repro.configs as C
from repro.data import pipeline as dp
from repro.models.common import count_params
from repro.models import transformer
from repro.training import checkpoint, loop
from repro.training.optimizer import AdamWConfig

PRESETS = {
    "tiny": dict(d_model=128, num_layers=4, num_heads=4, num_kv_heads=2,
                 head_dim=32, d_ff=512, vocab_size=2048),
    "100m": dict(d_model=768, num_layers=12, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=list(C.ARCH_IDS))
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_llm.npz")
    args = ap.parse_args()

    cfg = dataclasses.replace(C.get_smoke(args.arch), **PRESETS[args.preset],
                              dtype=jnp.float32)
    import jax
    n = count_params(jax.eval_shape(
        lambda k: transformer.init(cfg, k), jax.random.PRNGKey(0)))
    print(f"arch={cfg.name} params={n / 1e6:.1f}M "
          f"pattern={cfg.layer_pattern} layers={cfg.num_layers}")

    dcfg = dp.DataConfig(batch=args.batch, seq_len=args.seq)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    state, history = loop.train(cfg, dp.iterator(cfg, dcfg), args.steps,
                                ocfg=ocfg, log_every=10)
    for h in history:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"lr {h['lr']:.2e}  wall {h['wall']:.1f}s")
    assert history[-1]["loss"] < history[0]["loss"], "loss must decrease"
    checkpoint.save(args.ckpt, state.params)
    print(f"checkpoint written to {args.ckpt}")


if __name__ == "__main__":
    main()
