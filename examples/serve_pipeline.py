"""End-to-end serving driver: a full simulated cluster serving a dynamic
diffusion workload with TridentServe vs the strongest baseline (B6),
printing the SLO/latency comparison and the placement-switch timeline.

  PYTHONPATH=src python examples/serve_pipeline.py [--pipeline flux]
      [--workload dynamic] [--duration 480]
"""
import argparse

from repro.core.baselines import BASELINES
from repro.core.simulator import run_sim
from repro.core.trident import TridentScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipeline", default="flux",
                    choices=["sd3", "flux", "cogvideox", "hunyuanvideo"])
    ap.add_argument("--workload", default="dynamic",
                    choices=["light", "medium", "heavy", "dynamic",
                             "proprietary"])
    ap.add_argument("--duration", type=float, default=480.0)
    ap.add_argument("--baselines", default="B1,B5,B6")
    args = ap.parse_args()

    res = run_sim(args.pipeline, TridentScheduler, args.workload,
                  args.duration)
    print(res.summary())
    print(f"  VR distribution: {res.vr_histogram}")
    print("  placement timeline:")
    for t, hist in res.placement_switches:
        print(f"    t={t:7.1f}s  {hist}")
    print(f"  engine: merged={res.engine_stats.get('merged_runs')} "
          f"pushes={res.engine_stats.get('device_pushes')} "
          f"adjust_loads={res.engine_stats.get('adjust_loads')}")
    for name in args.baselines.split(","):
        r = run_sim(args.pipeline, BASELINES[name], args.workload,
                    args.duration)
        print(r.summary())


if __name__ == "__main__":
    main()
