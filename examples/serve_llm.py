"""Serve a small LLM with batched requests through the ServeEngine
(prefill + KV-cache decode) — the assigned-architecture serving path.

  PYTHONPATH=src python examples/serve_llm.py --arch gemma2-9b --requests 6
"""
import argparse
import time

import jax
import numpy as np

import repro.configs as C
from repro.models import transformer
from repro.serving.engine import GenRequest, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b", choices=list(C.ARCH_IDS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    if cfg.modality != "text":
        raise SystemExit(f"{args.arch}: use quickstart/audio paths for "
                         "non-text modalities")
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=4, max_len=128)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 16))
        eng.submit(GenRequest(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new=args.max_new))
    t0 = time.perf_counter()
    done = []
    while eng.queue:
        done += eng.step()
    dt = time.perf_counter() - t0
    toks = sum(r.max_new for r in done)
    print(f"arch={cfg.name}: served {len(done)} requests, "
          f"{toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  rid={r.rid} prompt_len={r.prompt.shape[-1]} "
              f"output={r.output.tolist()}")


if __name__ == "__main__":
    main()
