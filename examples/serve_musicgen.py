"""Audio-codec serving: MusicGen-style delayed-codebook generation with the
EnCodec-stub frontend (one decode step predicts one frame across all four
codebooks).

  PYTHONPATH=src python examples/serve_musicgen.py --frames 8
"""
import argparse

import jax
import numpy as np

import repro.configs as C
from repro.models import audio, transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=8)
    args = ap.parse_args()

    cfg = C.get_smoke("musicgen-medium")
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    # conditioning prefix: 4 stub codec frames
    prefix = audio.codec_stub_tokens(cfg, 1, 4, jax.random.PRNGKey(1))
    delayed = audio.apply_delay_pattern(prefix)
    logits, cache, offset = transformer.prefill(cfg, params, delayed,
                                                max_len=64)
    frames = []
    tok = jax.numpy.argmax(logits[:, -1], axis=-1)       # (B, K)
    for _ in range(args.frames):
        frames.append(np.asarray(tok))
        logits, cache = transformer.decode_step(
            cfg, params, tok[:, :, None], cache, offset)
        offset = offset + 1
        tok = jax.numpy.argmax(logits[:, -1], axis=-1)
    gen = np.stack(frames, axis=-1)                       # (B, K, T)
    undone = audio.undo_delay_pattern(jax.numpy.asarray(gen))
    print(f"generated {args.frames} frames across {cfg.num_codebooks} "
          f"codebooks: shape {gen.shape}")
    print(np.asarray(undone)[0])


if __name__ == "__main__":
    main()
