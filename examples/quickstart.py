"""Quickstart: generate an image with a tiny diffusion pipeline, then serve
three requests stage-by-stage with the real TridentServe planners.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

import repro.configs as C
from repro.core.dispatcher import Dispatcher
from repro.core.orchestrator import Orchestrator
from repro.core.profiler import Profiler
from repro.core.request import Request
from repro.models import pipeline as pl


def main():
    # --- 1. a runnable (reduced) Stable-Diffusion-3-style pipeline ---------
    cfg = C.get_smoke("sd3")
    params = pl.init(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.encoder.vocab_size)
    image = pl.generate(cfg, params, prompt, resolution=64, seconds=0.0,
                        key=jax.random.PRNGKey(2))
    print(f"generated image: shape={image.shape} "
          f"range=[{float(image.min()):.2f}, {float(image.max()):.2f}]")

    # --- 2. plan placement + dispatch with the paper's algorithms ----------
    prof = Profiler(C.get("sd3"))        # full-size cost model drives plans
    orch = Orchestrator(prof, num_chips=32)
    reqs = []
    for res in (512, 1024, 1536):
        r = Request("sd3", res)
        r.deadline = 2.5 * prof.pipeline_time(r)
        reqs.append(r)
    plan = orch.generate(reqs)
    print(f"placement plan (32 chips): {plan.type_histogram()}")
    disp = Dispatcher(prof)
    idle = set(range(plan.num_units))
    decisions = disp.dispatch(reqs, plan, idle, {g: 0.0 for g in idle}, 0.0)
    for d in decisions:
        print(f"  req res={d.request.resolution}: VR type V{d.vr_type}, "
              f"Diffuse on units {d.d_units} (degree {d.degree}), "
              f"E on {d.e_units}, C on {d.c_units}")

    # --- 3. execute one dispatched request end-to-end ----------------------
    d = decisions[0]
    cond = pl.encode(cfg, params, prompt)                     # Γ^E
    lat = pl.diffuse(cfg, params, cond,
                     (1, cfg.latent_tokens(64, 0.0), cfg.dit.latent_dim),
                     jax.random.PRNGKey(3))                   # Γ^D
    out = pl.decode(cfg, params, lat, cfg.latent_grid(64, 0.0))  # Γ^C
    assert np.isfinite(np.asarray(out)).all()
    print(f"stage-level execution OK: output {out.shape}")


if __name__ == "__main__":
    main()
