"""§Roofline — three-term roofline table from the dry-run artifacts
(results/dryrun_*.jsonl, produced by repro.launch.dryrun)."""
from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import Row

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    for fname in ("dryrun_single_pod.jsonl", "dryrun_multi_pod.jsonl"):
        path = os.path.join(RESULTS, fname)
        if not os.path.exists(path):
            rows.append((f"roofline/{fname}/missing", 0.0,
                         {"hint": "run python -m repro.launch.dryrun --all"}))
            continue
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
                if r["status"] == "skipped":
                    rows.append((f"{tag}/skipped", 0.0,
                                 {"reason": r["reason"][:60]}))
                    continue
                if r["status"] != "ok":
                    rows.append((f"{tag}/error", -1.0,
                                 {"error": r.get("error", "")[:80]}))
                    continue
                dom = r["bottleneck"]
                t_dom = r[f"t_{dom}_s"]
                rows.append((f"{tag}/t_{dom}_ms", round(t_dom * 1e3, 3),
                             {"compute_ms": round(r["t_compute_s"] * 1e3, 3),
                              "memory_ms": round(r["t_memory_s"] * 1e3, 3),
                              "collective_ms": round(r["t_collective_s"] * 1e3, 3),
                              "bottleneck": dom,
                              "useful_flops_ratio": round(r["useful_ratio"], 4),
                              "peak_mem_GiB": round(
                                  r.get("peak_mem_per_device", 0) / 2 ** 30, 2)}))
    return rows
