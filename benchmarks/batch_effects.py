"""Fig. 17 / Appendix E.1 — batch-size effects per stage (Encode batches
almost freely, Diffuse only at low resolution, Decode not at all)."""
from __future__ import annotations

from typing import List

import repro.configs as C
from benchmarks.common import Row
from repro.core.profiler import HBM_BW, MFU, PEAK_FLOPS, Profiler
from repro.core.request import Request


def _batched_time(prof: Profiler, req: Request, stage: str, bs: int) -> float:
    """Latency of a batch of ``bs`` identical requests on one unit.
    Compute-bound stages amortize; memory-bound ones scale linearly."""
    flops = prof.stage_flops(req, stage) * bs
    hbm = (prof.info[stage].bytes if stage in prof.info else 0)
    hbm = prof.stage_hbm_bytes(req, stage) + (bs - 1) * prof.stage_act_bytes(req, stage) * 3
    k = prof.k_min
    return max(flops / (k * PEAK_FLOPS * MFU), hbm / (k * HBM_BW))


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    prof = Profiler(C.get("sd3"))
    for stage, res in (("E", 512), ("D", 256), ("D", 1024), ("C", 1024)):
        req = Request("sd3", res)
        t1 = _batched_time(prof, req, stage, 1)
        opt_bs = 1
        for bs in (2, 4, 8, 16, 32):
            tb = _batched_time(prof, req, stage, bs)
            if tb <= t1 * 1.2:   # paper E.1: batch latency <= 1.2x single
                opt_bs = bs
        rows.append((f"batch_effects/sd3/{stage}@{res}/opt_batch", opt_bs,
                     {"t1_ms": round(t1 * 1e3, 2),
                      "t_at_opt_ms": round(_batched_time(prof, req, stage,
                                                         opt_bs) * 1e3, 2)}))
    return rows
