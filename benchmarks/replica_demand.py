"""Fig. 4 — replica proportions that balance stage processing speeds, per
workload level (what motivates dynamic re-placement)."""
from __future__ import annotations

import random
from typing import List

import repro.configs as C
from benchmarks.common import Row
from repro.core.orchestrator import Orchestrator
from repro.core.profiler import Profiler
from repro.core.request import Request
from repro.core.workloads import MIXES


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    pipes = ("flux",) if quick else list(C.PIPELINE_IDS)
    for pid in pipes:
        prof = Profiler(C.get(pid))
        orch = Orchestrator(prof, num_chips=128)
        rng = random.Random(0)
        for level in ("light", "medium", "heavy"):
            mix = MIXES[pid][level]
            reqs = []
            for _ in range(200):
                total = sum(w for _, w in mix)
                x = rng.uniform(0, total)
                acc = 0.0
                for (res, sec), w in mix:
                    acc += w
                    if x <= acc:
                        break
                reqs.append(Request(pid, res, float(sec)))
            plan = orch.generate(reqs)
            hist = plan.type_histogram()
            d_units = sum(n for t, n in hist.items() if "D" in t)  # detlint: ignore[DET001] int unit counts: exact
            rows.append((
                f"replica_demand/{pid}/{level}/d_unit_share",
                round(d_units / plan.num_units, 3),
                {"placement": hist}))
    return rows
