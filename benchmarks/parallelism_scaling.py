"""Fig. 3 + Appendix A — per-stage speedup vs SP degree and resolution."""
from __future__ import annotations

from typing import List

import repro.configs as C
from benchmarks.common import Row
from repro.core.profiler import Profiler
from repro.core.request import Request


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    cases = {
        "sd3": [(r, 0.0) for r in (512, 1024, 2048, 4096)],
        "flux": [(r, 0.0) for r in (512, 1024, 2048, 4096)],
        "cogvideox": [(480, 2.0), (720, 4.0), (720, 8.0)],
        "hunyuanvideo": [(540, 2.0), (720, 4.0), (720, 8.0)],
    }
    pipes = ("flux", "cogvideox") if quick else list(cases)
    for pid in pipes:
        prof = Profiler(C.get(pid))
        for res, sec in cases[pid]:
            req = Request(pid, res, sec)
            for stage in "EDC":
                speed = {k: round(prof.speedup(req, stage, k * prof.k_min), 3)
                         for k in (1, 2, 4, 8)}
                rows.append((
                    f"parallelism/{pid}/{res}x{sec}/{stage}/opt_degree",
                    prof.optimal_degree(req, stage),
                    {"speedup": speed,
                     "t1_ms": round(prof.stage_time(req, stage, prof.k_min)
                                    * 1e3, 2)}))
    return rows
