"""Fig. 11 — throughput timeline + placement switches on the Dynamic
workload (Trident vs the static-placement B6)."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.baselines import BASELINES
from repro.core.simulator import run_sim
from repro.core.trident import TridentScheduler


RATE = 2.2   # stressed arrival rate: load surges force re-placement (Fig 11)


def run(quick: bool = True) -> List[Row]:
    dur = 900.0 if quick else 1800.0   # switches need warm-up past T_win/2
    rows: List[Row] = []
    t = run_sim("flux", TridentScheduler, "dynamic", dur, rate=RATE)
    b6 = run_sim("flux", BASELINES["B6"], "dynamic", dur, rate=RATE)
    rows.append(("placement_switch/flux/dynamic/trident/switches",
                 len(t.placement_switches) - 1,
                 {"slo_pct": round(t.slo_attainment * 100, 1),
                  "timeline": t.placement_switches[:6],
                  "throughput_per_min": t.throughput_timeline[:10]}))
    rows.append(("placement_switch/flux/dynamic/B6/switches", 0,
                 {"slo_pct": round(b6.slo_attainment * 100, 1),
                  "throughput_per_min": b6.throughput_timeline[:10]}))
    return rows
