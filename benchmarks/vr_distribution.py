"""Fig. 12 — Virtual Replica type distribution (most requests must land on
the lowest-communication feasible type)."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, duration
from repro.core.simulator import run_sim
from repro.core.trident import TridentScheduler


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    for pid in ("flux", "hunyuanvideo"):
        res = run_sim(pid, TridentScheduler, "medium", duration(quick))
        total = sum(res.vr_histogram.values()) or 1  # detlint: ignore[DET001] int request counts: exact
        v0_share = res.vr_histogram.get(0, 0) / total
        low2 = (res.vr_histogram.get(0, 0) + res.vr_histogram.get(1, 0)) / total
        rows.append((f"vr_distribution/{pid}/v0_share", round(v0_share, 3),
                     {"hist": res.vr_histogram,
                      "v0_plus_v1_share": round(low2, 3)}))
    return rows
