"""Table 4 — dispatcher ILP solve time per tick, 128 -> 8192 GPUs with a
fixed request/GPU ratio.

Each size is dispatched three times against the same frozen pending set:

* ``solve_ms`` — cold dispatch on a fresh incremental dispatcher (the DP
  fast path handles effectively-one-dimensional instances; multi-dim
  instances take the branch-and-bound).
* ``warm_solve_ms`` — a second dispatch on a *non*-incremental dispatcher,
  whose surviving choices warm-start the incumbent: nodes explored drop,
  but the instance is still fully re-solved.
* ``incremental_solve_ms`` — a second dispatch on the incremental
  dispatcher: the (options, budgets) signature is unchanged, so the
  previous solution is reused without a solve (nodes == 0).

The nodes-explored columns are the before/after record for the
incremental re-solve work: cold vs warm-incumbent vs signature reuse.
"""
from __future__ import annotations

import random
import time
from typing import List, Tuple

import repro.configs as C
from benchmarks.common import Row
from repro.core.dispatcher import Dispatcher
from repro.core.orchestrator import Orchestrator
from repro.core.profiler import Profiler
from repro.core.request import Request
from repro.core.workloads import MIXES


def _timed_dispatch(disp: Dispatcher, reqs, plan, idle,
                    free) -> Tuple[float, int]:
    t0 = time.perf_counter()
    decisions = disp.dispatch(reqs, plan, set(idle), dict(free), 0.0)
    return (time.perf_counter() - t0) * 1e3, len(decisions)


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    prof = Profiler(C.get("flux"))
    rng = random.Random(0)
    classes = [cls for mix in MIXES["flux"].values() for cls, _ in mix]
    sizes = ((128, 512, 2048, 4096) if quick
             else (128, 256, 512, 1024, 2048, 4096, 8192))
    for chips in sizes:
        orch = Orchestrator(prof, num_chips=chips)
        n_req = max(8, 20 * chips // 128)
        reqs = []
        for _ in range(n_req):
            res, sec = rng.choice(classes)
            r = Request("flux", res, float(sec))
            r.deadline = 2.5 * prof.pipeline_time(r)
            reqs.append(r)
        plan = orch.generate(reqs)
        idle = set(range(plan.num_units))
        free = {g: 0.0 for g in idle}

        inc = Dispatcher(prof, max_batch=n_req, incremental=True)
        cold_ms, dispatched = _timed_dispatch(inc, reqs, plan, idle, free)
        cold = dict(inc.last_solve_stats)
        rows.append((f"dispatcher_scalability/{chips}gpus/solve_ms",
                     round(cold_ms, 1),
                     {"pending": n_req, "dispatched": dispatched,
                      "ilp": cold}))

        base = Dispatcher(prof, max_batch=n_req)
        _timed_dispatch(base, reqs, plan, idle, free)
        warm_ms, _ = _timed_dispatch(base, reqs, plan, idle, free)
        warm = dict(base.last_solve_stats)
        rows.append((f"dispatcher_scalability/{chips}gpus/warm_solve_ms",
                     round(warm_ms, 1),
                     {"nodes_cold": cold.get("nodes"),
                      "nodes_warm": warm.get("nodes"), "ilp": warm}))

        reuse_ms, _ = _timed_dispatch(inc, reqs, plan, idle, free)
        reuse = dict(inc.last_solve_stats)
        rows.append((f"dispatcher_scalability/{chips}gpus"
                     "/incremental_solve_ms", round(reuse_ms, 1),
                     {"nodes": reuse.get("nodes"),
                      "reused": bool(reuse.get("reused")),
                      "solve_reuses": inc.solve_reuses}))
    return rows
