"""Table 4 — dispatcher ILP solve time per tick, 128 -> 4096 GPUs with a
fixed request/GPU ratio."""
from __future__ import annotations

import random
import time
from typing import List

import repro.configs as C
from benchmarks.common import Row
from repro.core.dispatcher import Dispatcher
from repro.core.orchestrator import Orchestrator
from repro.core.profiler import Profiler
from repro.core.request import Request
from repro.core.workloads import MIXES


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    prof = Profiler(C.get("flux"))
    rng = random.Random(0)
    classes = [cls for mix in MIXES["flux"].values() for cls, _ in mix]
    sizes = (128, 512, 4096) if quick else (128, 256, 512, 1024, 4096)
    for chips in sizes:
        orch = Orchestrator(prof, num_chips=chips)
        n_req = max(8, 20 * chips // 128)
        reqs = []
        for _ in range(n_req):
            res, sec = rng.choice(classes)
            r = Request("flux", res, float(sec))
            r.deadline = 2.5 * prof.pipeline_time(r)
            reqs.append(r)
        plan = orch.generate(reqs)
        disp = Dispatcher(prof, max_batch=n_req)
        idle = set(range(plan.num_units))
        free = {g: 0.0 for g in idle}
        t0 = time.perf_counter()
        decisions = disp.dispatch(reqs, plan, idle, free, 0.0)
        dt = (time.perf_counter() - t0) * 1e3
        rows.append((f"dispatcher_scalability/{chips}gpus/solve_ms",
                     round(dt, 1),
                     {"pending": n_req, "dispatched": len(decisions),
                      "ilp": disp.last_solve_stats}))
    return rows
