"""Fig. 14 — component ablations: wo-switch / wo-stageAware / wo-scheduler."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.simulator import run_sim
from repro.core.trident import TridentScheduler

VARIANTS = {
    "full": {},
    "wo-switch": {"enable_switch": False},
    "wo-stageAware": {"stage_aware": False},
    "wo-scheduler": {"use_ilp": False},
}


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    pipes = ("flux",) if quick else ("flux", "hunyuanvideo")
    workloads = ("dynamic",) if quick else ("dynamic", "medium")
    dur = 900.0 if quick else 1800.0
    rate = 2.2  # stressed load: components only matter under contention
    for pid in pipes:
        for wl in workloads:
            for name, kw in VARIANTS.items():
                res = run_sim(pid, TridentScheduler, wl, dur, rate=rate, **kw)
                rows.append((
                    f"ablation/{pid}/{wl}/{name}/slo_pct",
                    round(res.slo_attainment * 100, 2),
                    {"mean_s": round(res.mean_latency, 3),
                     "p95_s": round(res.p95_latency, 3)}))
    return rows
