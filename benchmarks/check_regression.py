"""Bench-regression gate: compare fresh benchmark JSON against the
committed baselines and exit non-zero on regression.

Two comparison regimes per (baseline, current) pair, keyed by the files'
``bench`` field:

* **same scale** (equal ``duration_s``/scenarios): headline metrics must
  stay within ``--tolerance`` (default 10%) of the baseline — deterministic
  metrics (wakeup counts, SLO, improvement ratios) use it directly;
  wall-clock-derived metrics (speedups) use the looser ``--wall-tolerance``
  (default 35%) because CI machines are noisy.
* **different scale** (e.g. the 240 s shared smoke vs the committed 600 s
  run): exact ratios are not comparable, so the gate falls back to the
  scenario's acceptance *floors* (the same ones documented in
  benchmarks/README.md).

Usage (what CI and ``benchmarks.run --smoke`` do):

    python -m benchmarks.check_regression \
        --pair BENCH_event_sim.json results/BENCH_event_sim.smoke.json \
        --pair BENCH_shared_cluster.json results/BENCH_shared_smoke.json

Exit status 0 = no regression; 1 = regression (problems printed); 2 = bad
invocation / unreadable files.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

# acceptance floors, per bench kind (benchmarks/README.md)
EVENT_SPEEDUP_FLOOR = 1.2          # event clock must beat the tick clock
SHARED_P95_FLOOR = 1.2             # adaptive fleet vs static sub-clusters
LENDING_WORST_P95_FLOOR = 1.0      # lending must never hurt the worst lane
PREDICTIVE_P95_FLOOR = 1.15        # predictive vs adaptive, worst pipeline
                                   # on the committed diurnal trace
PREDICTIVE_SMOKE_FLOOR = 1.0       # scale-aware: at smoke scale the
                                   # predictive scheduler must never be
                                   # worse than adaptive
CROSS_BATCH_P95_FLOOR = 1.15       # cross-lane batching vs off, aggregate
                                   # P95 on the committed burst-storm trace
CROSS_BATCH_SMOKE_FLOOR = 1.0      # scale-aware: at smoke scale batching
                                   # must never be worse than off
UNIFIED_OVERHEAD_CEIL_PCT = 5.0    # kernel overhead vs the old hand-rolled
                                   # loops (wall-clock-class measurement)
SCALE_SPEEDUP_FLOOR = 5.0          # sim-core throughput vs the pre-scale-out
                                   # tree, extrapolated to the full 4096-chip
                                   # tier (the PR's acceptance bar)
SCALE_SMOKE_SPEEDUP_FLOOR = 0.6    # scale-aware: at 512 chips the fast paths
                                   # barely matter (the broken bottlenecks
                                   # were superlinear in chips) — the smoke
                                   # check only guards against the fast paths
                                   # becoming an outright slowdown
SCALE_SLO_FLOOR_PCT = 95.0         # the scale trace is sized to be servable;
                                   # a throughput "win" that drops SLO is a
                                   # broken scheduler, not a fast one
ELASTIC_RECOVERY_FLOOR = 1.15      # drain-aware vs drain-unaware recovery
                                   # P95 on the committed preemption storm
ELASTIC_SWEEP_FLOOR = 0.95         # off-canonical arrival seeds: drain must
                                   # never make recovery materially worse
ELASTIC_SMOKE_FLOOR = 0.9          # scale-aware: the 128-chip smoke storm
                                   # is too small to back the pool up, so
                                   # smoke only guards parity — the
                                   # mechanism canaries below do the work
SCALE_RPS_SANITY_FRACTION = 0.05   # cross-scale wall sanity fallback: only
                                   # consulted when the smoke run timed no
                                   # reference tree (the same-machine probe
                                   # ratio is strictly better evidence, so it
                                   # takes precedence) — a 512-chip smoke run
                                   # below 5% of the committed 4096-chip
                                   # throughput is a hung machine or a broken
                                   # build, not a slow one


def _ratio_check(problems: List[str], name: str, current: float,
                 baseline: float, tol: float, floor: float = 0.0) -> None:
    """Higher-is-better metric: current must stay within ``tol`` of the
    baseline and above the absolute floor."""
    if current < floor:
        problems.append(f"{name}: {current} below acceptance floor {floor}")
    elif baseline > 0 and current < baseline * (1.0 - tol):
        problems.append(f"{name}: {current} regressed vs baseline "
                        f"{baseline} (tolerance {tol:.0%})")


def _count_check(problems: List[str], name: str, current: float,
                 baseline: float, tol: float) -> None:
    """Lower-is-better deterministic counter (e.g. scheduler wake-ups)."""
    if baseline > 0 and current > baseline * (1.0 + tol):
        problems.append(f"{name}: {current} exceeds baseline "
                        f"{baseline} (tolerance {tol:.0%})")


def check_event_sim(base: Dict, cur: Dict, tol: float,
                    wall_tol: float) -> List[str]:
    problems: List[str] = []
    if not cur.get("metrics_match", False):
        problems.append("metrics_match: event clock diverged from tick clock")
    if base.get("scenarios") == cur.get("scenarios"):
        _count_check(problems, "sched_wakeups_event",
                     cur.get("sched_wakeups_event", 0),
                     base.get("sched_wakeups_event", 0), tol)
    _ratio_check(problems, "speedup_event_vs_tick",
                 cur.get("speedup_event_vs_tick", 0.0),
                 base.get("speedup_event_vs_tick", 0.0),
                 wall_tol, floor=EVENT_SPEEDUP_FLOOR)
    return problems


def check_shared_cluster(base: Dict, cur: Dict, tol: float,
                         wall_tol: float) -> List[str]:
    problems: List[str] = []
    same_scale = base.get("duration_s") == cur.get("duration_s")
    for key in ("p95_improvement_adaptive_vs_static",
                "worst_pipeline_p95_improvement"):
        if same_scale:
            _ratio_check(problems, key, cur.get(key, 0.0),
                         base.get(key, 0.0), tol, floor=SHARED_P95_FLOOR)
    if not same_scale:
        # shorter smoke traces never reach the full run's aggregate ratio;
        # the scale-free signals are "adaptive not worse than static" on
        # aggregate P95 and the acceptance floor on the worst pipeline
        # (where the mix flip bites hardest even at smoke scale)
        _ratio_check(problems, "p95_improvement_adaptive_vs_static",
                     cur.get("p95_improvement_adaptive_vs_static", 0.0),
                     0.0, tol, floor=1.0)
        _ratio_check(problems, "worst_pipeline_p95_improvement",
                     cur.get("worst_pipeline_p95_improvement", 0.0),
                     0.0, tol, floor=SHARED_P95_FLOOR)
    if same_scale:
        for mode, m in base.get("modes", {}).items():
            cur_m = cur.get("modes", {}).get(mode)
            if cur_m is None:
                continue
            _ratio_check(problems, f"modes.{mode}.slo_pct",
                         cur_m.get("slo_pct", 0.0), m.get("slo_pct", 0.0),
                         tol)
    else:
        # scale-free sanity: adaptive must not do worse than static
        modes = cur.get("modes", {})
        if "static" in modes and "adaptive" in modes:
            if (modes["adaptive"].get("slo_pct", 0.0)
                    < modes["static"].get("slo_pct", 0.0) - 100 * tol):
                problems.append("modes.adaptive.slo_pct fell below static")
    return problems


def check_unit_lending(base: Dict, cur: Dict, tol: float,
                       wall_tol: float) -> List[str]:
    problems: List[str] = []
    key = "worst_pipeline_p95_improvement_lending_vs_adaptive"
    same_scale = base.get("duration_s") == cur.get("duration_s")
    _ratio_check(problems, key, cur.get(key, 0.0),
                 base.get(key, 0.0) if same_scale else 0.0, tol,
                 floor=LENDING_WORST_P95_FLOOR)
    if cur.get("diffuse_runs_on_borrowed_units", 0) != 0:
        problems.append("diffuse work landed on borrowed units")
    return problems


def check_unified_clock(base: Dict, cur: Dict, tol: float,
                        wall_tol: float) -> List[str]:
    """The unified event-clock kernel's acceptance record
    (BENCH_unified_clock.json).  Deterministic signals are tight: the
    kernel must keep reproducing tick-mode metrics and must not inflate
    wake-up counts.  Wall-derived signals get the wall-clock-class
    tolerance: the event-vs-tick speedup must hold vs the baseline, and —
    when the run measured it against a pre-unification tree — the
    kernel's per-mode overhead must stay under the 5% acceptance ceiling."""
    # same contract as the event-sim smoke pair (delegated, so the two
    # gates can never drift apart) ...
    problems = check_event_sim(base, cur, tol, wall_tol)
    # ... plus the tick-mode wakeup count and the overhead ceiling
    if base.get("scenarios") == cur.get("scenarios"):
        _count_check(problems, "sched_wakeups_tick",
                     cur.get("sched_wakeups_tick", 0),
                     base.get("sched_wakeups_tick", 0), tol)
    for key in ("kernel_overhead_pct_event", "kernel_overhead_pct_tick"):
        if key in cur and cur[key] > UNIFIED_OVERHEAD_CEIL_PCT:
            problems.append(f"{key}: {cur[key]}% exceeds the "
                            f"{UNIFIED_OVERHEAD_CEIL_PCT}% kernel-overhead "
                            f"ceiling")
    return problems


def check_predictive(base: Dict, cur: Dict, tol: float,
                     wall_tol: float) -> List[str]:
    """Predictive re-partitioning on the diurnal trace
    (BENCH_predictive.json).  Same scale: the worst-pipeline improvement
    must hold near the committed baseline and above the 1.15x acceptance
    floor.  Different scale (the CI smoke variant): scale-aware floor —
    predictive must never be worse than adaptive (>= 1.0x) and must have
    actually exercised the pre-warm path (a run that never stages is a
    broken forecaster, not a passing one)."""
    problems: List[str] = []
    key = "worst_pipeline_p95_improvement_predictive_vs_adaptive"
    same_scale = base.get("duration_s") == cur.get("duration_s")
    _ratio_check(problems, key, cur.get(key, 0.0),
                 base.get(key, 0.0) if same_scale else 0.0, tol,
                 floor=(PREDICTIVE_P95_FLOOR if same_scale
                        else PREDICTIVE_SMOKE_FLOOR))
    if cur.get("prewarm_units", 0) <= 0:
        problems.append("predictive run staged no pre-warm loads")
    if cur.get("predictive_repartitions", 0) <= 0:
        problems.append("predictive run never fired a predicted shift")
    return problems


def check_cross_batch(base: Dict, cur: Dict, tol: float,
                      wall_tol: float) -> List[str]:
    """Cross-lane dynamic batching on the burst-storm trace
    (BENCH_cross_batch.json).  Same scale: the aggregate P95 improvement
    must hold near the committed baseline and above the 1.15x acceptance
    floor.  Different scale (the CI smoke variant): scale-aware floor —
    batching must never be worse than off (>= 1.0x).  Either way the run
    must have actually fused launches across lanes (a run with zero
    merges is a broken candidate path, not a passing one)."""
    problems: List[str] = []
    key = "p95_improvement_batching_vs_off"
    same_scale = base.get("duration_s") == cur.get("duration_s")
    _ratio_check(problems, key, cur.get(key, 0.0),
                 base.get(key, 0.0) if same_scale else 0.0, tol,
                 floor=(CROSS_BATCH_P95_FLOOR if same_scale
                        else CROSS_BATCH_SMOKE_FLOOR))
    if cur.get("cross_lane_merges", 0) <= 0:
        problems.append("batching run fused no cross-lane launches")
    return problems


def check_scale(base: Dict, cur: Dict, tol: float,
                wall_tol: float) -> List[str]:
    """Sim-core throughput at fleet scale (BENCH_scale.json).  Same scale
    (equal chips and requests): throughput must hold near the committed
    baseline within the wall-clock-class tolerance, and when the run
    measured a pre-scale-out reference tree the extrapolated speedup must
    stay above the acceptance floor — and a run that *lost* the reference
    measurement the baseline has is itself flagged, so the floor cannot be
    skipped silently.  Different scale (the 512-chip CI smoke vs the
    committed 4096-chip tier): raw throughput is not comparable, so the
    gate checks structural invariants — every request finished, SLO held,
    the fast paths were actually on — plus the scale-aware smoke speedup
    floor when a reference tree was timed (at 512 chips the broken
    bottlenecks barely bite, so the floor only rejects outright
    slowdowns); only when no same-machine probe exists does it fall back
    to the lenient cross-scale throughput sanity fraction."""
    problems: List[str] = []
    same_scale = (base.get("num_chips") == cur.get("num_chips")
                  and base.get("n_requests") == cur.get("n_requests"))
    if cur.get("n_finished", 0) != cur.get("n_requests", -1):
        problems.append("scale run dropped requests "
                        f"({cur.get('n_finished')}/{cur.get('n_requests')})")
    if cur.get("slo_pct", 0.0) < SCALE_SLO_FLOOR_PCT:
        problems.append(f"slo_pct: {cur.get('slo_pct')} below the "
                        f"{SCALE_SLO_FLOOR_PCT}% floor")
    fast = cur.get("fast_path", {})
    if not all(fast.get(k) for k in ("array_state", "incremental_ilp",
                                     "step_changed_lanes_only")):
        problems.append(f"fast paths not fully enabled: {fast}")
    if cur.get("sched_wakeups", 0) <= 0:
        problems.append("scale run recorded no scheduler wake-ups")
    if same_scale:
        _ratio_check(problems, "throughput_rps",
                     cur.get("throughput_rps", 0.0),
                     base.get("throughput_rps", 0.0), wall_tol)
        if "speedup_extrapolated" in cur:
            _ratio_check(problems, "speedup_extrapolated",
                         cur["speedup_extrapolated"],
                         base.get("speedup_extrapolated", 0.0), wall_tol,
                         floor=SCALE_SPEEDUP_FLOOR)
        elif "speedup_extrapolated" in base:
            # The committed baseline measured a pre-scale-out reference
            # tree but this run did not: the reference timing failed (or
            # --scale-ref was dropped).  Silently skipping the floor here
            # would let the acceptance bar rot, so surface it.
            problems.append("speedup_extrapolated missing: baseline has a "
                            "reference-tree measurement but the current "
                            "run recorded none (reference timing failed "
                            "or --scale-ref not passed)")
    else:
        if "speedup_same_tier" in cur:
            _ratio_check(problems, "speedup_same_tier",
                         cur["speedup_same_tier"], 0.0, wall_tol,
                         floor=SCALE_SMOKE_SPEEDUP_FLOOR)
        else:
            # No same-machine probe ratio: fall back to the lenient
            # machine-speed sanity fraction against the committed tier.
            _ratio_check(problems, "throughput_rps (cross-scale sanity)",
                         cur.get("throughput_rps", 0.0), 0.0, wall_tol,
                         floor=(SCALE_RPS_SANITY_FRACTION
                                * base.get("throughput_rps", 0.0)))
    return problems


def check_elastic(base: Dict, cur: Dict, tol: float,
                  wall_tol: float) -> List[str]:
    """Elastic preemption storm (BENCH_elastic.json).  Same scale: the
    drain-aware recovery-P95 win on the canonical storm must hold near
    the committed baseline and above the 1.15x acceptance floor, and the
    arrival-seed sweep must stay above the never-worse floor.  Different
    scale (the CI smoke variant): the two-node smoke storm cannot back a
    128-chip pool up, so the gate only asks for parity — the real smoke
    signal is the mechanism canaries: the unaware arm must pay requeues
    (the fault path ran), the aware arm must drain units and stage
    pre-warm chips (the notice path ran), and both arms must end at the
    scheduled chip count (joins landed)."""
    problems: List[str] = []
    key = "recovery_p95_improvement_drain_vs_unaware"
    same_scale = base.get("duration_s") == cur.get("duration_s")
    _ratio_check(problems, key, cur.get(key, 0.0),
                 base.get(key, 0.0) if same_scale else 0.0, tol,
                 floor=(ELASTIC_RECOVERY_FLOOR if same_scale
                        else ELASTIC_SMOKE_FLOOR))
    if same_scale:
        _ratio_check(problems, "recovery_p95_sweep_floor",
                     cur.get("recovery_p95_sweep_floor", 0.0),
                     base.get("recovery_p95_sweep_floor", 0.0), tol,
                     floor=ELASTIC_SWEEP_FLOOR)
    modes = cur.get("modes", {})
    unaware = modes.get("drain_unaware", {})
    aware = modes.get("drain_aware", {})
    if unaware.get("requeued_requests", 0) <= 0:
        problems.append("unaware arm paid no requeues: the storm never "
                        "caught in-flight work (broken fault path or a "
                        "trace too cold to exercise it)")
    if aware.get("drained_units", 0) <= 0:
        problems.append("aware arm drained no units: the preemption "
                        "notice path never ran")
    if aware.get("elastic_prewarm_chips", 0) <= 0:
        problems.append("aware arm staged no pre-warm chips: the join "
                        "announce path never ran")
    for arm, m in modes.items():
        if m.get("nodes_lost", 0) <= 0 or m.get("nodes_joined", 0) <= 0:
            problems.append(f"{arm}: schedule lost/joined no nodes")
    return problems


CHECKERS = {
    "event_driven_simulator_smoke": check_event_sim,
    "shared_cluster_mix_flip": check_shared_cluster,
    "unit_lending_bursty_ec": check_unit_lending,
    "unified_clock_kernel": check_unified_clock,
    "predictive_prewarm_diurnal": check_predictive,
    "cross_lane_batching_burst_storm": check_cross_batch,
    "scale_sim_core": check_scale,
    "elastic_preemption_storm": check_elastic,
}


def check_pair(baseline_path: str, current_path: str, tol: float,
               wall_tol: float) -> List[str]:
    with open(baseline_path) as f:
        base = json.load(f)
    with open(current_path) as f:
        cur = json.load(f)
    kind = base.get("bench")
    if kind != cur.get("bench"):
        return [f"bench kind mismatch: {kind} vs {cur.get('bench')}"]
    checker = CHECKERS.get(kind)
    if checker is None:
        return [f"unknown bench kind: {kind}"]
    return [f"[{kind}] {p}" for p in checker(base, cur, tol, wall_tol)]


def run_checks(pairs, tolerance: float = 0.10,
               wall_tolerance: float = 0.35) -> List[str]:
    problems: List[str] = []
    for baseline, current in pairs:
        problems.extend(check_pair(baseline, current, tolerance,
                                   wall_tolerance))
    return problems


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pair", nargs=2, action="append", required=True,
                    metavar=("BASELINE", "CURRENT"),
                    help="baseline JSON (committed) and current JSON "
                         "(fresh run); repeatable")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="relative tolerance for deterministic metrics "
                         "(default 0.10)")
    ap.add_argument("--wall-tolerance", type=float, default=0.35,
                    help="relative tolerance for wall-clock-derived "
                         "metrics like speedups (default 0.35)")
    args = ap.parse_args(argv)
    try:
        problems = run_checks(args.pair, args.tolerance, args.wall_tolerance)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_regression: cannot read inputs: {e}")
        return 2
    if problems:
        print(f"REGRESSION: {len(problems)} problem(s)")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"check_regression: {len(args.pair)} pair(s) OK "
          f"(tolerance {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
