"""Fig. 15 — SLO scaling: attainment as the SLO scale factor alpha varies."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, duration
from repro.core.baselines import BASELINES
from repro.core.simulator import run_sim
from repro.core.trident import TridentScheduler


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    alphas = (1.5, 2.5, 5.0) if quick else (1.0, 1.5, 2.5, 5.0, 10.0)
    scheds = {"trident": TridentScheduler, "B6": BASELINES["B6"],
              "B5": BASELINES["B5"]}
    for alpha in alphas:
        for name, cls in scheds.items():
            res = run_sim("flux", cls, "dynamic", duration(quick),
                          slo_scale=alpha)
            rows.append((f"slo_sensitivity/flux/alpha{alpha}/{name}/slo_pct",
                         round(res.slo_attainment * 100, 2),
                         {"mean_s": round(res.mean_latency, 3)}))
    return rows
