"""Kernel microbenchmarks: oracle-path wall time on CPU (the TPU kernels
are validated in interpret mode; wall-clock here tracks the jnp reference
implementations the CPU examples execute)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.kernels import ops


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)

    b, l, h, d = 1, 512, 4, 64
    q = jax.random.normal(ks[0], (b, l, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, l, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, l, h, d), jnp.float32)
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True,
                                                     use_kernel=False))
    rows.append(("kernels/attention_ref_512/us_per_call",
                 round(_time(fa, q, k, v), 1), {"shape": f"{b}x{l}x{h}x{d}"}))

    q2 = jax.random.normal(ks[0], (1, 4, 1024, 16))
    k2 = jax.random.normal(ks[1], (1, 4, 1024, 16))
    v2 = jax.random.normal(ks[2], (1, 4, 1024, 16))
    w2 = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (1, 4, 1024, 16)) * 0.3))
    scan = jax.jit(lambda q, k, v, w: ops.linear_scan(q, k, v, w))
    rows.append(("kernels/linear_scan_ref_1024/us_per_call",
                 round(_time(scan, q2, k2, v2, w2), 1),
                 {"shape": "1x4x1024x16"}))

    x = jax.random.normal(ks[0], (4, 1024, 256), jnp.float32)
    s = jax.random.normal(ks[1], (4, 256)) * 0.1
    t = jax.random.normal(ks[2], (4, 256)) * 0.1
    al = jax.jit(lambda x, s, t: ops.adaln_rmsnorm(x, s, t))
    rows.append(("kernels/adaln_rmsnorm_ref/us_per_call",
                 round(_time(al, x, s, t), 1), {"shape": "4x1024x256"}))
    return rows
