"""Shared benchmark plumbing: each module exposes run(quick) -> rows.

A row is (name, value, derived) where value is the headline number for the
CSV and ``derived`` is a dict of extra fields.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

Row = Tuple[str, float, Dict[str, Any]]


def emit(rows: List[Row]):
    for name, value, derived in rows:
        extra = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{value},{extra}", flush=True)


DUR_QUICK = 120.0
DUR_FULL = 600.0


def duration(quick: bool) -> float:
    return DUR_QUICK if quick else DUR_FULL
