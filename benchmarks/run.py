"""Benchmark harness — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows.  Default is quick mode
(shorter traces, fewer combos); ``--full`` reproduces the paper-scale
sweeps; ``--only <name>`` runs a single module.

  PYTHONPATH=src python -m benchmarks.run
  PYTHONPATH=src python -m benchmarks.run --full --only e2e
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

from benchmarks.common import emit

MODULES = [
    "parallelism_scaling",     # Fig. 3 / Appendix A
    "replica_demand",          # Fig. 4
    "e2e",                     # Fig. 10
    "placement_switch",        # Fig. 11
    "vr_distribution",         # Fig. 12
    "adjust_on_dispatch",      # Fig. 13
    "ablation",                # Fig. 14
    "slo_sensitivity",         # Fig. 15
    "dispatcher_scalability",  # Table 4
    "batch_effects",           # Fig. 17 / Appendix E.1
    "kernels_bench",           # kernel microbenchmarks
    "roofline",                # §Roofline table from dry-run artifacts
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-style fast pass: e2e smoke set with the "
                         "event-vs-tick speedup check (BENCH_event_sim.json) "
                         "plus a short shared-cluster co-serving run")
    args = ap.parse_args()
    if args.smoke:
        import os
        os.makedirs("results", exist_ok=True)
        smoke_event_json = os.path.join("results",
                                        "BENCH_event_sim.smoke.json")
        smoke_shared_json = os.path.join("results",
                                         "BENCH_shared_smoke.json")
        smoke_unified_json = os.path.join("results",
                                          "BENCH_unified_clock.smoke.json")
        smoke_predictive_json = os.path.join("results",
                                             "BENCH_predictive.smoke.json")
        smoke_cross_batch_json = os.path.join("results",
                                              "BENCH_cross_batch.smoke.json")
        smoke_scale_json = os.path.join("results", "BENCH_scale.smoke.json")
        smoke_elastic_json = os.path.join("results",
                                          "BENCH_elastic.smoke.json")
        t0 = time.perf_counter()
        print("# --- e2e (smoke) ---", flush=True)
        from benchmarks import e2e
        # fresh JSONs go under results/ so the committed baselines stay
        # intact for the regression gate below
        smoke_rows = e2e.run_smoke(bench_path=smoke_event_json,
                                   unified_bench_path=smoke_unified_json)
        emit(smoke_rows)
        print(f"# e2e smoke took {time.perf_counter() - t0:.1f}s", flush=True)
        t0 = time.perf_counter()
        print("# --- e2e (shared-cluster smoke) ---", flush=True)
        emit(e2e.run_shared_smoke(bench_path=smoke_shared_json))
        print(f"# shared smoke took {time.perf_counter() - t0:.1f}s",
              flush=True)
        t0 = time.perf_counter()
        print("# --- e2e (predictive smoke) ---", flush=True)
        emit(e2e.run_predictive_smoke(bench_path=smoke_predictive_json))
        print(f"# predictive smoke took {time.perf_counter() - t0:.1f}s",
              flush=True)
        t0 = time.perf_counter()
        print("# --- e2e (cross-batch smoke) ---", flush=True)
        emit(e2e.run_cross_batch_smoke(bench_path=smoke_cross_batch_json))
        print(f"# cross-batch smoke took {time.perf_counter() - t0:.1f}s",
              flush=True)
        t0 = time.perf_counter()
        print("# --- e2e (scale smoke) ---", flush=True)
        # 512-chip / 100k-request slice of the 4096-chip tier; no reference
        # tree in CI, so the checker's different-scale regime applies
        emit(e2e.run_scale(full=False, bench_path=smoke_scale_json))
        print(f"# scale smoke took {time.perf_counter() - t0:.1f}s",
              flush=True)
        t0 = time.perf_counter()
        print("# --- e2e (elastic smoke) ---", flush=True)
        emit(e2e.run_elastic_smoke(bench_path=smoke_elastic_json))
        print(f"# elastic smoke took {time.perf_counter() - t0:.1f}s",
              flush=True)
        # event-vs-tick parity is the smoke pass's one hard check: a clock
        # regression must fail CI, not just land in the BENCH json.
        # The row must be present — a missing row is a broken check, not a
        # passing one.
        parity = [v for n, v, _ in smoke_rows
                  if n.endswith("metrics_match_event_vs_tick")]
        parity_ok = len(parity) == 1 and parity[0] == 1.0
        if not parity_ok:
            print("# SMOKE FAILURE: event clock diverged from tick clock",
                  flush=True)
        # bench-regression gate: fresh smoke metrics vs committed baselines
        print("# --- check_regression ---", flush=True)
        from benchmarks import check_regression
        problems = check_regression.run_checks(
            [("BENCH_event_sim.json", smoke_event_json),
             ("BENCH_shared_cluster.json", smoke_shared_json),
             ("BENCH_unified_clock.json", smoke_unified_json),
             ("BENCH_predictive.json", smoke_predictive_json),
             ("BENCH_cross_batch.json", smoke_cross_batch_json),
             ("BENCH_scale.json", smoke_scale_json),
             ("BENCH_elastic.json", smoke_elastic_json)])
        for p in problems:
            print(f"# REGRESSION: {p}", flush=True)
        if not problems:
            print("# check_regression: OK", flush=True)
        sys.exit(0 if parity_ok and not problems else 1)
    mods = [args.only] if args.only else MODULES
    ok = True
    for name in mods:
        t0 = time.perf_counter()
        print(f"# --- {name} ---", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run(quick=not args.full)
            emit(rows)
        except Exception as e:  # keep the harness going; report at the end
            ok = False
            print(f"{name}/ERROR,{-1},{type(e).__name__}: {e}", flush=True)
        print(f"# {name} took {time.perf_counter() - t0:.1f}s", flush=True)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
