"""Fig. 13 — Adjust-on-Dispatch vs naive shutdown adjustment."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.simulator import SimConfig, run_sim
from repro.core.trident import TridentScheduler


def run(quick: bool = True) -> List[Row]:
    dur = 900.0 if quick else 1800.0
    aod = run_sim("flux", TridentScheduler, "dynamic", dur, rate=2.2)
    down = run_sim("flux", TridentScheduler, "dynamic", dur, rate=2.2,
                   sim_cfg=SimConfig(downtime_adjust=True))
    return [
        ("adjust_on_dispatch/flux/dynamic/mean_latency_s",
         round(aod.mean_latency, 3),
         {"p95_s": round(aod.p95_latency, 3),
          "slo_pct": round(aod.slo_attainment * 100, 1),
          "downtime_s": aod.engine_stats.get("downtime", 0.0),
          "adjust_loads": aod.engine_stats.get("adjust_loads", 0)}),
        ("adjust_on_dispatch/flux/dynamic/downtime_mean_latency_s",
         round(down.mean_latency, 3),
         {"p95_s": round(down.p95_latency, 3),
          "slo_pct": round(down.slo_attainment * 100, 1),
          "downtime_s": round(down.engine_stats.get("downtime", 0.0), 2)}),
    ]
