"""Fig. 10 — end-to-end SLO attainment / mean / P95 across 4 pipelines x
workloads x {TridentServe, B1..B6}."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, duration
from repro.core.baselines import BASELINES
from repro.core.simulator import run_sim
from repro.core.trident import TridentScheduler

PIPES_QUICK = ("flux", "hunyuanvideo")
PIPES_FULL = ("sd3", "flux", "cogvideox", "hunyuanvideo")
WORKLOADS_QUICK = ("medium", "dynamic")
WORKLOADS_FULL = ("light", "medium", "heavy", "dynamic", "proprietary")


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    pipes = PIPES_QUICK if quick else PIPES_FULL
    workloads = WORKLOADS_QUICK if quick else WORKLOADS_FULL
    dur = duration(quick)
    scheds = {"trident": TridentScheduler, **BASELINES}
    for pid in pipes:
        for wl in workloads:
            for name, cls in scheds.items():
                res = run_sim(pid, cls, wl, dur)
                rows.append((
                    f"e2e/{pid}/{wl}/{name}/slo_pct",
                    round(res.slo_attainment * 100, 2),
                    {"mean_s": (round(res.mean_latency, 3)
                                if not res.oom else "OOM"),
                     "p95_s": (round(res.p95_latency, 3)
                               if not res.oom else "OOM"),
                     "oom": res.oom,
                     "finished": res.n_finished,
                     "requests": res.n_requests}))
    return rows
