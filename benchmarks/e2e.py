"""Fig. 10 — end-to-end SLO attainment / mean / P95 across 4 pipelines x
workloads x {TridentServe, B1..B6}.

Also hosts:

* ``--smoke``: a CI-sized scenario set that times the event-driven clock
  against the legacy tick clock on identical traces and records the
  speedup in ``BENCH_event_sim.json`` (acceptance: >= 5x);
* ``--mixed``: the 512-chip mixed SD3+Flux+CogVideoX deployment — three
  stage-level sub-clusters under one arrival budget.  At this horizon the
  O(horizon/tick) loop does ~10^5 scheduler iterations per pipeline; the
  event clock makes the scenario routine.
* ``--mixed --shared``: the same 512 chips as ONE shared cluster
  (core/fleet.py) under a heterogeneous trace with a mid-trace traffic-mix
  flip.  Compares the fleet scheduler trio — static sub-clusters (the
  ``--mixed`` paradigm), proportional-share, adaptive — and records the
  adaptive-vs-static goodput and P95 deltas in ``BENCH_shared_cluster.json``
  (acceptance: >= 1.2x P95 improvement).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

from benchmarks.common import Row, duration
from repro.core.baselines import BASELINES
from repro.core.simulator import SimConfig, run_sim
from repro.core.trident import TridentScheduler

PIPES_QUICK = ("flux", "hunyuanvideo")
PIPES_FULL = ("sd3", "flux", "cogvideox", "hunyuanvideo")
WORKLOADS_QUICK = ("medium", "dynamic")
WORKLOADS_FULL = ("light", "medium", "heavy", "dynamic", "proprietary")

SCHEDS = {"trident": TridentScheduler, **BASELINES}

BENCH_REPEATS = 3   # best-of-N sim-core timing (damps machine noise)

# CI smoke set: small enough to run in seconds under the event clock, with
# enough sparse-video coverage that the tick clock's O(horizon/tick) cost
# shows.  (pipeline, scheduler, workload, duration_s, rate_override)
SMOKE_SCENARIOS: Tuple[Tuple[str, str, str, float, Optional[float]], ...] = (
    ("sd3", "trident", "light", 60.0, None),
    ("sd3", "B4", "light", 60.0, None),
    ("flux", "trident", "medium", 120.0, None),
    ("hunyuanvideo", "trident", "heavy", 300.0, None),
    ("hunyuanvideo", "B6", "heavy", 300.0, None),
    ("cogvideox", "trident", "medium", 300.0, None),
    # the event clock's home turf: long sparse video traces, where the tick
    # loop burns 1/tick iterations per simulated second doing nothing —
    # overnight-valley traffic at a twentieth of the Table-5 rates
    ("hunyuanvideo", "trident", "dynamic", 3600.0, None),
    ("hunyuanvideo", "trident", "proprietary", 3600.0, 0.05),
    ("hunyuanvideo", "trident", "light", 3600.0, 0.05),
    ("cogvideox", "trident", "light", 3600.0, 0.05),
    ("cogvideox", "trident", "medium", 3600.0, 0.1),
    ("flux", "trident", "light", 3600.0, 0.1),
)

# 512-chip mixed deployment: static sub-clusters per pipeline, each run by
# its own TridentServe instance over its share of the arrival budget.
MIXED_PARTITION: Dict[str, int] = {"sd3": 128, "flux": 192, "cogvideox": 192}

# Shared-cluster variant: one 512-chip pool, heterogeneous trace with a
# mid-trace mix flip (image-dominated first half, heavy-pipeline second
# half).  Rates/flip live next to the trace generator so there is exactly
# one tuned scenario definition (workloads.FLEET_RATES / MIX_FLIP).
from repro.core.workloads import FLEET_RATES as SHARED_RATES
from repro.core.workloads import MIX_FLIP as SHARED_FLIP

SHARED_PIPELINES = ("sd3", "flux", "cogvideox")
SHARED_MODES = ("static", "proportional", "adaptive")


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    pipes = PIPES_QUICK if quick else PIPES_FULL
    workloads = WORKLOADS_QUICK if quick else WORKLOADS_FULL
    dur = duration(quick)
    for pid in pipes:
        for wl in workloads:
            for name, cls in SCHEDS.items():
                res = run_sim(pid, cls, wl, dur)
                rows.append((
                    f"e2e/{pid}/{wl}/{name}/slo_pct",
                    round(res.slo_attainment * 100, 2),
                    {"mean_s": (round(res.mean_latency, 3)
                                if not res.oom else "OOM"),
                     "p95_s": (round(res.p95_latency, 3)
                               if not res.oom else "OOM"),
                     "oom": res.oom,
                     "finished": res.n_finished,
                     "requests": res.n_requests}))
    return rows


# ---------------------------------------------------------------- smoke bench

def run_smoke_mode(mode: str) -> Tuple[List[Row], float, int]:
    """Run the smoke set under one clock mode; returns (rows, wall_s, wakeups).

    Only ``Simulator.run`` is timed: profiler tables and traces are built
    outside the timer (they are identical across modes — same seeds, same
    cost model), so the wall-clock ratio measures the simulation core the
    clock mode actually changes.
    """
    import repro.configs as configs
    from repro.core import workloads
    from repro.core.profiler import Profiler
    from repro.core.simulator import Simulator

    rows: List[Row] = []
    wakeups = 0
    wall = 0.0
    profs: Dict[Tuple[str, Optional[int]], Profiler] = {}
    for pid, sched, wl, dur, rate in SMOKE_SCENARIOS:
        cls = SCHEDS[sched]
        k_min = getattr(cls, "FORCE_KMIN", None)
        prof = profs.get((pid, k_min))
        if prof is None:
            prof = profs[(pid, k_min)] = Profiler(configs.get(pid),
                                                  force_k_min=k_min)
        trace = workloads.make_trace(pid, wl, dur, prof, seed=0, rate=rate)
        sim_cfg = SimConfig(mode=mode)
        sim = Simulator(pid, cls(prof, sim_cfg, trace), trace, sim_cfg)
        t0 = time.perf_counter()
        res = sim.run()
        wall += time.perf_counter() - t0
        wakeups += res.sched_wakeups
        # duration/rate are part of the name: the set may contain the same
        # (pipeline, workload, scheduler) at several scales
        tag = f"{wl}{int(dur)}s" + (f"r{rate:g}" if rate is not None else "")
        rows.append((f"e2e_smoke/{pid}/{tag}/{sched}/{mode}/slo_pct",
                     round(res.slo_attainment * 100, 2),
                     {"mean_s": round(res.mean_latency, 3),
                      "p95_s": round(res.p95_latency, 3),
                      "wakeups": res.sched_wakeups,
                      "finished": res.n_finished}))
    return rows, wall, wakeups


_SEED_DRIVER = r"""
import json, sys, time
import repro.configs as configs
from repro.core import workloads
from repro.core.baselines import BASELINES
from repro.core.profiler import Profiler
from repro.core.simulator import SimConfig, Simulator
from repro.core.trident import TridentScheduler
SCHEDS = {"trident": TridentScheduler, **BASELINES}
payload = json.load(sys.stdin)
scenarios, repeats = payload[0], payload[1]
mode = payload[2] if len(payload) > 2 else None
best = None
for _ in range(repeats):
    wall = 0.0
    for pid, sched, wl, dur, rate in scenarios:
        cls = SCHEDS[sched]
        prof = Profiler(configs.get(pid),
                        force_k_min=getattr(cls, "FORCE_KMIN", None))
        trace = workloads.make_trace(pid, wl, dur, prof, seed=0, rate=rate)
        # no mode given: the seed SimConfig (fixed-tick loop only)
        cfg = SimConfig() if mode is None else SimConfig(mode=mode)
        sim = Simulator(pid, cls(prof, cfg, trace), trace, cfg)
        t0 = time.perf_counter()
        sim.run()
        wall += time.perf_counter() - t0
    best = wall if best is None else min(best, wall)
print(json.dumps({"wall_s": best}))
"""


def _time_ref_tree(ref_root: str, mode: Optional[str],
                   label: str) -> Optional[float]:
    """Run the smoke scenarios against a checked-out reference tree and
    return its best-of sim-core wall-clock (``mode=None`` for the seed
    tree, whose SimConfig predates clock modes)."""
    import os
    import subprocess
    import sys as _sys
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ref_root, "src")
    payload = [[list(s) for s in SMOKE_SCENARIOS], BENCH_REPEATS]
    if mode is not None:
        payload.append(mode)
    try:
        out = subprocess.run([_sys.executable, "-c", _SEED_DRIVER],
                             input=json.dumps(payload),
                             capture_output=True, text=True, env=env,
                             timeout=1800, check=True)
        return float(json.loads(out.stdout.strip().splitlines()[-1])["wall_s"])
    except Exception as e:  # missing worktree etc. — report, don't fail smoke
        print(f"# {label} timing unavailable: {e}", flush=True)
        return None


def time_seed_tree(seed_ref: str) -> Optional[float]:
    """Seed-tree timing (the original fixed-tick loop, pre hot-path
    optimizations); ``seed_ref`` is the seed repo root (e.g. a worktree)."""
    return _time_ref_tree(seed_ref, None, "seed-ref")


def kernel_overhead_pct(pre_ref: str, mode: str,
                        rounds: int = 3) -> Optional[Tuple[float, float,
                                                           float]]:
    """Unified-kernel overhead vs a pre-unification tree, one clock mode.

    Machine load drifts on the minutes scale, so timing one tree and then
    the other lets noise masquerade as overhead; this interleaves the two
    trees in alternating subprocesses and takes best-of-rounds for each,
    which is what the <= 5% acceptance ceiling is judged against.
    Returns (overhead_pct, wall_now_s, wall_pre_s), or None when the
    reference tree is unusable."""
    import os
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    now_best = pre_best = None
    for _ in range(rounds):
        now = _time_ref_tree(here, mode, f"self({mode})")
        pre = _time_ref_tree(pre_ref, mode, f"pre-ref({mode})")
        if now is None or pre is None:
            return None
        now_best = now if now_best is None else min(now_best, now)
        pre_best = pre if pre_best is None else min(pre_best, pre)
    pct = 100.0 * (now_best - pre_best) / max(pre_best, 1e-9)
    return pct, now_best, pre_best


def _best_of(mode: str) -> Tuple[List[Row], float, int]:
    best: Optional[Tuple[List[Row], float, int]] = None
    for _ in range(BENCH_REPEATS):
        rows, wall, wk = run_smoke_mode(mode)
        if best is None or wall < best[1]:
            best = (rows, wall, wk)
    return best


def run_smoke(bench_path: Optional[str] = "BENCH_event_sim.json",
              seed_ref: Optional[str] = None,
              unified_bench_path: Optional[str] = None,
              pre_ref: Optional[str] = None) -> List[Row]:
    """Event vs tick clock on identical traces; records the speedup.

    With ``unified_bench_path`` also writes the unified-kernel BENCH: the
    same smoke measurements re-badged as the kernel's acceptance record,
    plus — when ``pre_ref`` points at a checked-out pre-unification tree
    (the last commit with the two hand-rolled loops) — the kernel's
    overhead vs those old loops, per clock mode (acceptance: <= 5%).
    """
    rows, wall_event, wk_event = _best_of("event")
    tick_rows, wall_tick, wk_tick = _best_of("tick")
    speedup = wall_tick / max(wall_event, 1e-9)
    rows.append(("e2e_smoke/wallclock_speedup_event_vs_tick", round(speedup, 2),
                 {"wall_event_s": round(wall_event, 3),
                  "wall_tick_s": round(wall_tick, 3),
                  "wakeups_event": wk_event, "wakeups_tick": wk_tick}))
    # machine-checkable parity row: benchmarks.run --smoke exits nonzero
    # when the event clock stops reproducing the tick clock's metrics
    rows.append(("e2e_smoke/metrics_match_event_vs_tick",
                 float(_smoke_metrics_match(rows, tick_rows)), {}))
    bench = {
        "bench": "event_driven_simulator_smoke",
        "scenarios": [list(s) for s in SMOKE_SCENARIOS],
        "wall_event_s": round(wall_event, 4),
        "wall_tick_s": round(wall_tick, 4),
        "speedup_event_vs_tick": round(speedup, 2),
        "sched_wakeups_event": wk_event,
        "sched_wakeups_tick": wk_tick,
        "metrics_match": _smoke_metrics_match(rows, tick_rows),
    }
    if seed_ref:
        wall_seed = time_seed_tree(seed_ref)
        if wall_seed is not None:
            bench["wall_seed_tick_s"] = round(wall_seed, 4)
            bench["speedup_vs_seed_tick"] = round(
                wall_seed / max(wall_event, 1e-9), 2)
            rows.append(("e2e_smoke/wallclock_speedup_vs_seed_tick",
                         bench["speedup_vs_seed_tick"],
                         {"wall_seed_tick_s": bench["wall_seed_tick_s"]}))
    if bench_path:
        with open(bench_path, "w") as f:
            json.dump(bench, f, indent=2)
            f.write("\n")
    if unified_bench_path:
        unified = {
            "bench": "unified_clock_kernel",
            "scenarios": [list(s) for s in SMOKE_SCENARIOS],
            "wall_event_s": round(wall_event, 4),
            "wall_tick_s": round(wall_tick, 4),
            "speedup_event_vs_tick": round(speedup, 2),
            "sched_wakeups_event": wk_event,
            "sched_wakeups_tick": wk_tick,
            "metrics_match": bench["metrics_match"],
        }
        if pre_ref:
            for label in ("event", "tick"):
                measured = kernel_overhead_pct(pre_ref, label)
                if measured is None:
                    continue
                pct, now, pre = measured
                unified[f"wall_pre_{label}_s"] = round(pre, 4)
                unified[f"kernel_overhead_pct_{label}"] = round(pct, 2)
                rows.append((f"e2e_smoke/unified_kernel_overhead_pct_{label}",
                             unified[f"kernel_overhead_pct_{label}"],
                             {"wall_pre_s": round(pre, 4),
                              "wall_now_s": round(now, 4)}))
        with open(unified_bench_path, "w") as f:
            json.dump(unified, f, indent=2)
            f.write("\n")
    return rows


def _smoke_metrics_match(event_rows: List[Row], tick_rows: List[Row]) -> bool:
    ev = {n.rsplit("/", 2)[0]: (v, d.get("mean_s"), d.get("p95_s"))
          for n, v, d in event_rows if "/slo_pct" in n}
    tk = {n.rsplit("/", 2)[0]: (v, d.get("mean_s"), d.get("p95_s"))
          for n, v, d in tick_rows if "/slo_pct" in n}
    return ev == tk


# ---------------------------------------------------------------- mixed-512

def run_mixed(quick: bool = True) -> List[Row]:
    """512-chip mixed SD3+Flux+CogVideoX deployment (event clock).

    Each pipeline gets a static sub-cluster (chips per MIXED_PARTITION) and
    its Table-5 arrival rate; the trace horizon is 1h in full mode.  Under
    the tick loop this is ~4 * 3600 / 0.25 = 57k scheduler iterations per
    pipeline even when idle — the event clock visits only arrivals,
    completions, and window boundaries.
    """
    dur = 600.0 if quick else 3600.0
    rows: List[Row] = []
    tot_reqs = tot_fin = 0
    slo_weighted = 0.0
    lat_weighted = 0.0
    p95_max = 0.0
    t0 = time.perf_counter()
    wakeups = 0
    for pid, chips in MIXED_PARTITION.items():  # detlint: ignore[DET001] module-literal dict: iteration order is source order
        cfg = SimConfig(num_chips=chips, mode="event")
        res = run_sim(pid, TridentScheduler, "dynamic", dur, sim_cfg=cfg)
        wakeups += res.sched_wakeups
        rows.append((f"e2e_mixed512/{pid}/slo_pct",
                     round(res.slo_attainment * 100, 2),
                     {"chips": chips, "mean_s": round(res.mean_latency, 3),
                      "p95_s": round(res.p95_latency, 3),
                      "finished": res.n_finished, "requests": res.n_requests,
                      "wakeups": res.sched_wakeups}))
        tot_reqs += res.n_requests
        tot_fin += res.n_finished
        slo_weighted += res.slo_attainment * res.n_requests
        lat_weighted += res.mean_latency * res.n_requests
        p95_max = max(p95_max, res.p95_latency)
    rows.append(("e2e_mixed512/aggregate/slo_pct",
                 round(100.0 * slo_weighted / max(1, tot_reqs), 2),
                 {"chips": sum(MIXED_PARTITION.values()),  # detlint: ignore[DET001] int chip counts: exact
                  "duration_s": dur,
                  "mean_s": round(lat_weighted / max(1, tot_reqs), 3),
                  "p95_max_s": round(p95_max, 3),
                  "finished": tot_fin, "requests": tot_reqs,
                  "wakeups": wakeups,
                  "wall_s": round(time.perf_counter() - t0, 2)}))
    return rows


# ---------------------------------------------------------------- shared-512

def run_mixed_shared(quick: bool = True,
                     bench_path: Optional[str] = "BENCH_shared_cluster.json",
                     duration: Optional[float] = None,
                     modes: Tuple[str, ...] = SHARED_MODES,
                     fleet_cfg_kw: Optional[Dict] = None) -> List[Row]:
    """512-chip shared cluster, SD3+Flux+CogVideoX, mid-trace mix flip.

    One heterogeneous trace per mode (same seed -> identical arrivals);
    modes are the fleet scheduler trio.  The static baseline partitions the
    pool from the first-window traffic (today's ``--mixed`` paradigm) and
    never moves; when the mix flips, its Flux/CogVideoX slices drown while
    SD3 chips idle — the adaptive fleet re-partitions and the gap between
    the two is the headline number.
    """
    from repro.core import workloads
    from repro.core.fleet import FleetConfig, PipelineRegistry, run_fleet

    dur = duration if duration is not None else (600.0 if quick else 3600.0)
    registry = PipelineRegistry(SHARED_PIPELINES)
    profs = {pid: registry.profiler(pid) for pid in SHARED_PIPELINES}
    rows: List[Row] = []
    results = {}
    for mode in modes:
        cfg = FleetConfig(num_chips=512, **(fleet_cfg_kw or {}))
        # a fresh trace per mode (requests are mutated by the sim; the seed
        # makes arrivals identical), built outside the wall timer so the
        # per-mode wall_s measures the fleet simulator alone
        trace = workloads.fleet_trace(SHARED_PIPELINES, dur, profs, seed=0,
                                      rates=SHARED_RATES, phases=SHARED_FLIP)
        t0 = time.perf_counter()
        res = run_fleet(SHARED_PIPELINES, mode=mode, duration=dur, cfg=cfg,
                        registry=registry, trace=trace)
        wall = time.perf_counter() - t0
        results[mode] = res
        rows.append((f"e2e_shared512/{mode}/p95_s", round(res.p95_latency, 3),
                     {"slo_pct": round(res.slo_attainment * 100, 2),
                      "goodput_rps": round(res.goodput, 3),
                      "mean_s": round(res.mean_latency, 3),
                      "finished": res.n_finished, "requests": res.n_requests,
                      "repartitions": len(res.repartitions) - 1,
                      "swap_cost_s": round(res.swap_cost_s, 2),
                      "wakeups": res.sched_wakeups,
                      "wall_s": round(wall, 2)}))
        for pid, m in res.per_pipeline.items():
            rows.append((f"e2e_shared512/{mode}/{pid}/p95_s",
                         round(m["p95_s"], 3),
                         {"slo_pct": round(m["slo"] * 100, 2),
                          "mean_s": round(m["mean_s"], 3),
                          "finished": int(m["finished"]),
                          "requests": int(m["requests"]),
                          "chips_final": int(m["chips"])}))
    return _shared_summary_rows(rows, results, bench_path, dur)


# ---------------------------------------------------------------- lending-256

LENDING_PIPELINES = ("sd3", "cogvideox")


def run_lending(quick: bool = True,
                bench_path: Optional[str] = "BENCH_unit_lending.json",
                duration: Optional[float] = None) -> List[Row]:
    """Cross-pipeline unit lending on the bursty-E/C trace.

    256 chips, sd3 + cogvideox, calm sizing window then three sub-window
    decode bursts (``workloads.BURSTY_EC``): too short for the adaptive
    re-partitioner's hysteresis + cooldown to chase, so without lending the
    burst pipeline drowns while sd3 units idle.  Compares ``adaptive``
    against ``adaptive`` + lending on identical arrivals; the headline is
    the worst-pipeline P95 ratio, with the diffuse path untouched by
    construction (borrowed units host E/C only — the run asserts it).

    The scenario is tuned at its 600 s scale (burst lengths are the point),
    so ``--full`` widens across seeds instead of lengthening the trace:
    the worst-pipeline ratio must hold on every seed, while aggregate
    metrics legitimately vary with the adaptive re-partition trajectory.
    """
    from repro.core import workloads
    from repro.core.fleet import FleetConfig, PipelineRegistry, run_fleet

    dur = duration if duration is not None else 600.0
    seeds = (0,) if quick else (0, 1, 2)
    registry = PipelineRegistry(LENDING_PIPELINES)
    profs = {pid: registry.profiler(pid) for pid in LENDING_PIPELINES}
    rows: List[Row] = []
    results = {}
    worst_by_seed = {}
    phases = workloads.bursty_ec_phases(dur)
    for seed in seeds:
        per_mode = {}
        for mode, lending in (("adaptive", False),
                              ("adaptive+lending", True)):
            cfg = FleetConfig(num_chips=256, lending=lending)
            trace = workloads.fleet_trace(LENDING_PIPELINES, dur, profs,
                                          seed=seed,
                                          rates=workloads.LENDING_RATES,
                                          phases=phases)
            t0 = time.perf_counter()
            res = run_fleet(LENDING_PIPELINES, mode="adaptive", duration=dur,
                            cfg=cfg, registry=registry, trace=trace)
            wall = time.perf_counter() - t0
            per_mode[mode] = res
            tag = f"e2e_lending256/{mode}" + (f"/s{seed}" if seed else "")
            rows.append((f"{tag}/p95_s", round(res.p95_latency, 3),
                         {"slo_pct": round(res.slo_attainment * 100, 2),
                          "goodput_rps": round(res.goodput, 3),
                          "mean_s": round(res.mean_latency, 3),
                          "loans": res.loans,
                          "borrowed_unit_s":
                              round(res.borrowed_unit_seconds, 1),
                          "lend_swap_cost_s":
                              round(res.lend_swap_cost_s, 2),
                          "repartitions": len(res.repartitions) - 1,
                          "wall_s": round(wall, 2)}))
            for pid, m in res.per_pipeline.items():
                rows.append((f"{tag}/{pid}/p95_s", round(m["p95_s"], 3),
                             {"slo_pct": round(m["slo"] * 100, 2),
                              "mean_s": round(m["mean_s"], 3)}))
        ad, lend = per_mode["adaptive"], per_mode["adaptive+lending"]
        worst_by_seed[seed] = (
            max(m["p95_s"] for m in ad.per_pipeline.values())  # detlint: ignore[DET004] numeric extremum over values: order-free
            / max(1e-9, max(m["p95_s"]  # detlint: ignore[DET004] numeric extremum over values: order-free
                            for m in lend.per_pipeline.values())))
        if seed == seeds[0]:
            results = per_mode
    ad, lend = results["adaptive"], results["adaptive+lending"]
    worst_x = min(worst_by_seed.values())  # detlint: ignore[DET004] numeric extremum over values: order-free
    p95_x = ad.p95_latency / max(lend.p95_latency, 1e-9)
    rows.append(("e2e_lending256/worst_pipeline_p95_improvement",
                 round(worst_x, 3),
                 {"p95_x": round(p95_x, 3),
                  "per_seed": {s: round(v, 3)
                               for s, v in worst_by_seed.items()},
                  "slo_pts": round((lend.slo_attainment
                                    - ad.slo_attainment) * 100, 2)}))
    if bench_path:
        bench = {
            "bench": "unit_lending_bursty_ec",
            "num_chips": 256,
            "pipelines": list(LENDING_PIPELINES),
            "duration_s": dur,
            "rates_rps": workloads.LENDING_RATES,
            "phases": [[f, dict(m)] for f, m in phases],
            "worst_pipeline_p95_improvement_lending_vs_adaptive":
                round(worst_x, 3),
            "worst_pipeline_p95_improvement_per_seed":
                {s: round(v, 3) for s, v in worst_by_seed.items()},
            "p95_improvement_lending_vs_adaptive": round(p95_x, 3),
            "slo_improvement_pts": round((lend.slo_attainment
                                          - ad.slo_attainment) * 100, 2),
            "loans": lend.loans,
            "borrowed_unit_seconds": round(lend.borrowed_unit_seconds, 1),
            "lend_swap_cost_s": round(lend.lend_swap_cost_s, 2),
            "borrowed_stage_runs": lend.borrowed_stage_runs,
            "diffuse_runs_on_borrowed_units":
                lend.borrowed_stage_runs.get("D", 0),
            "modes": {
                mode: {
                    "p95_s": round(r.p95_latency, 3),
                    "mean_s": round(r.mean_latency, 3),
                    "slo_pct": round(r.slo_attainment * 100, 2),
                    "goodput_rps": round(r.goodput, 3),
                    "repartitions": len(r.repartitions) - 1,
                    "per_pipeline": {
                        pid: {k: (round(v, 3) if isinstance(v, float)
                                  else v) for k, v in m.items()}
                        for pid, m in r.per_pipeline.items()},
                } for mode, r in results.items()},
        }
        with open(bench_path, "w") as f:
            json.dump(bench, f, indent=2)
            f.write("\n")
    return rows


# ---------------------------------------------------------------- predictive

PREDICTIVE_PIPELINES = ("sd3", "cogvideox")

# diurnal mix-flip scenario: 5 anti-phase square-wave periods, windows and
# forecast knobs scaled to the period so the forecaster sees >= 2 full
# periods before the trace's second half.  Rates live next to the trace
# generator (workloads.PREDICTIVE_RATES / diurnal_phases) so there is
# exactly one tuned scenario definition; these hold the fleet knobs.
from repro.core.workloads import PREDICTIVE_RATES

PREDICTIVE_PERIODS = 5
PREDICTIVE_DURATION = 1500.0
PREDICTIVE_CFG: Dict = dict(
    num_chips=256, t_win=120.0, cooldown=100.0,
    forecast_bin=10.0, forecast_history=600.0, forecast_horizon=250.0,
    prewarm_lead=50.0, prewarm_cooldown=80.0, prewarm_ttl=240.0,
    forecast_grace=60.0)

# CI-sized variant: same shape, 4 periods of 240 s on 128 chips (the
# forecaster needs 2 full periods of history, so 3 of the 7 flips land in
# the forecastable second half)
PREDICTIVE_SMOKE: Dict = dict(
    duration=960.0, periods=4,
    rates={"sd3": 14.0, "cogvideox": 0.42},
    cfg=dict(num_chips=128, t_win=90.0, cooldown=70.0,
             forecast_bin=5.0, forecast_history=480.0,
             forecast_horizon=200.0, prewarm_lead=40.0,
             prewarm_cooldown=60.0, prewarm_ttl=200.0,
             forecast_grace=50.0))


def run_predictive(quick: bool = True,
                   bench_path: Optional[str] = "BENCH_predictive.json",
                   duration: Optional[float] = None,
                   periods: int = PREDICTIVE_PERIODS,
                   rates: Optional[Dict[str, float]] = None,
                   fleet_cfg_kw: Optional[Dict] = None,
                   seeds: Optional[Tuple[int, ...]] = None) -> List[Row]:
    """Predictive re-partitioning on the diurnal mix-flip trace.

    Anti-phase square-wave demand between sd3 and cogvideox
    (``workloads.diurnal_phases``): every half period the mix flips hard,
    and the adaptive scheduler detects each flip a demand-window late,
    re-partitions with trailing-window sizing, and pays the weight reloads
    mid-queue.  The ``predictive`` scheduler (core/forecast.py) fits the
    period from rate history, pre-warms the target partition's weights on
    the units that will flip before the shift lands, and fires the swap as
    soon as the freshest observed rates confirm the predicted mix — the
    headline is the worst-pipeline P95 ratio on identical arrivals
    (acceptance: >= 1.15x at the committed scale, >= 1.0x on every
    ``--full`` seed).
    """
    from repro.core import workloads
    from repro.core.fleet import FleetConfig, PipelineRegistry, run_fleet

    dur = duration if duration is not None else PREDICTIVE_DURATION
    seeds = seeds if seeds is not None else ((0,) if quick else (0, 1, 2))
    rates = rates or PREDICTIVE_RATES
    cfg_kw = dict(PREDICTIVE_CFG)
    cfg_kw.update(fleet_cfg_kw or {})
    phases = workloads.diurnal_phases(n_periods=periods)
    registry = PipelineRegistry(PREDICTIVE_PIPELINES)
    profs = {pid: registry.profiler(pid) for pid in PREDICTIVE_PIPELINES}
    rows: List[Row] = []
    results = {}
    worst_by_seed = {}
    for seed in seeds:
        per_mode = {}
        for mode in ("adaptive", "predictive"):
            cfg = FleetConfig(**cfg_kw)
            trace = workloads.fleet_trace(PREDICTIVE_PIPELINES, dur, profs,
                                          seed=seed, rates=rates,
                                          phases=phases)
            t0 = time.perf_counter()
            res = run_fleet(PREDICTIVE_PIPELINES, mode=mode, duration=dur,
                            cfg=cfg, registry=registry, trace=trace)
            wall = time.perf_counter() - t0
            per_mode[mode] = res
            tag = f"e2e_predictive/{mode}" + (f"/s{seed}" if seed else "")
            rows.append((f"{tag}/p95_s", round(res.p95_latency, 3),
                         {"slo_pct": round(res.slo_attainment * 100, 2),
                          "goodput_rps": round(res.goodput, 3),
                          "mean_s": round(res.mean_latency, 3),
                          "repartitions": len(res.repartitions) - 1,
                          "predictive_repartitions":
                              res.predictive_repartitions,
                          "prewarm_units": res.prewarm_units,
                          "prewarm_hits": res.prewarm_hits,
                          "prewarm_cost_s": round(res.prewarm_cost_s, 2),
                          "swap_cost_s": round(res.swap_cost_s, 2),
                          "wall_s": round(wall, 2)}))
            for pid, m in res.per_pipeline.items():
                rows.append((f"{tag}/{pid}/p95_s", round(m["p95_s"], 3),
                             {"slo_pct": round(m["slo"] * 100, 2),
                              "mean_s": round(m["mean_s"], 3)}))
        ad, pr = per_mode["adaptive"], per_mode["predictive"]
        worst_by_seed[seed] = (
            max(m["p95_s"] for m in ad.per_pipeline.values())  # detlint: ignore[DET004] numeric extremum over values: order-free
            / max(1e-9, max(m["p95_s"]  # detlint: ignore[DET004] numeric extremum over values: order-free
                            for m in pr.per_pipeline.values())))
        if seed == seeds[0]:
            results = per_mode
    ad, pr = results["adaptive"], results["predictive"]
    worst_x = min(worst_by_seed.values())  # detlint: ignore[DET004] numeric extremum over values: order-free
    p95_x = ad.p95_latency / max(pr.p95_latency, 1e-9)
    rows.append(("e2e_predictive/worst_pipeline_p95_improvement",
                 round(worst_x, 3),
                 {"p95_x": round(p95_x, 3),
                  "per_seed": {s: round(v, 3)
                               for s, v in worst_by_seed.items()},
                  "slo_pts": round((pr.slo_attainment
                                    - ad.slo_attainment) * 100, 2)}))
    if bench_path:
        bench = {
            "bench": "predictive_prewarm_diurnal",
            "num_chips": cfg_kw["num_chips"],
            "pipelines": list(PREDICTIVE_PIPELINES),
            "duration_s": dur,
            "periods": periods,
            "rates_rps": dict(rates),
            "worst_pipeline_p95_improvement_predictive_vs_adaptive":
                round(worst_x, 3),
            "worst_pipeline_p95_improvement_per_seed":
                {s: round(v, 3) for s, v in worst_by_seed.items()},
            "p95_improvement_predictive_vs_adaptive": round(p95_x, 3),
            "slo_improvement_pts": round((pr.slo_attainment
                                          - ad.slo_attainment) * 100, 2),
            "predictive_repartitions": pr.predictive_repartitions,
            "prewarm_units": pr.prewarm_units,
            "prewarm_hits": pr.prewarm_hits,
            "prewarm_cost_s": round(pr.prewarm_cost_s, 3),
            "prewarm_loan_returns": pr.prewarm_loan_returns,
            "modes": {
                mode: {
                    "p95_s": round(r.p95_latency, 3),
                    "mean_s": round(r.mean_latency, 3),
                    "slo_pct": round(r.slo_attainment * 100, 2),
                    "goodput_rps": round(r.goodput, 3),
                    "repartitions": len(r.repartitions) - 1,
                    "predictive_repartitions": r.predictive_repartitions,
                    "prewarm_units": r.prewarm_units,
                    "swap_cost_s": round(r.swap_cost_s, 3),
                    "per_pipeline": {
                        pid: {k: (round(v, 3) if isinstance(v, float)
                                  else v) for k, v in m.items()}
                        for pid, m in r.per_pipeline.items()},
                } for mode, r in results.items()},
        }
        with open(bench_path, "w") as f:
            json.dump(bench, f, indent=2)
            f.write("\n")
    return rows


def run_predictive_smoke(bench_path: Optional[str] = None) -> List[Row]:
    """CI-sized ``--predictive`` variant: 4 diurnal periods on 128 chips,
    seed 0 only — exercises the whole forecast → pre-warm → predictive-fire
    path on every smoke run without touching BENCH_predictive.json.  The
    scale-aware acceptance floor is 1.0x (never worse than adaptive);
    the committed full-scale baseline pins 1.15x."""
    sm = PREDICTIVE_SMOKE
    return run_predictive(bench_path=bench_path, duration=sm["duration"],
                          periods=sm["periods"], rates=sm["rates"],
                          fleet_cfg_kw=sm["cfg"], seeds=(0,))


# -------------------------------------------------------------- cross-batch

# Fleet-level cross-lane dynamic batching on the long-prompt burst-storm
# trace (workloads.cross_batch_trace): identical arrivals, predictive
# scheduler both arms, ``cross_lane_batching`` off vs on.  The scenario
# and its rates live next to the trace generator
# (workloads.CROSS_BATCH_*); these hold the fleet knobs.
CROSS_BATCH_PIPELINES = ("flux", "hunyuanvideo")
CROSS_BATCH_DURATION = 900.0
CROSS_BATCH_CFG: Dict = dict(num_chips=96, t_win=120.0, cooldown=100.0)
CROSS_BATCH_MAX_BATCH = 8

# CI-sized variant: same burst shape at 2/3 scale (64 chips, 600 s with a
# shortened head so two full burst cycles still land).  The scale-aware
# acceptance floor is 1.0x (never worse than batching-off); the committed
# full-scale baseline pins 1.15x.
CROSS_BATCH_SMOKE: Dict = dict(
    duration=600.0, head=160.0,
    base_rates={"flux": 1.45, "hunyuanvideo": 0.35},
    wave_rates={"flux": 4.6, "hunyuanvideo": 0.2},
    cfg=dict(num_chips=64, t_win=120.0, cooldown=100.0))


def run_cross_batch(quick: bool = True,
                    bench_path: Optional[str] = "BENCH_cross_batch.json",
                    duration: Optional[float] = None,
                    base_rates: Optional[Dict[str, float]] = None,
                    wave_rates: Optional[Dict[str, float]] = None,
                    head: float = 240.0,
                    fleet_cfg_kw: Optional[Dict] = None,
                    seeds: Optional[Tuple[int, ...]] = None,
                    narrative_arms: bool = True) -> List[Row]:
    """Cross-lane dynamic batching on the long-prompt burst-storm trace.

    Correlated waves of cond-4096 prompt-expansion requests overload each
    lane's single auxiliary encode unit (the steady cheap-prompt base
    stream froze the plans with exactly one).  With ``cross_lane_batching``
    on, the fleet dispatcher fuses flux and hunyuanvideo encodes that
    share a placement shape into one batched launch on the freer aux unit
    (~1.55x batch amortization at this prompt length); the headline is the
    aggregate P95 ratio off/on on identical arrivals (acceptance:
    >= 1.15x at the committed scale, worst over ``--full`` seeds).

    ``narrative_arms`` adds two seed-0 reference runs showing the
    alternatives are structurally out on this trace: adaptive
    re-partitioning (every plan shape carries exactly one aux E unit, and
    each burst is sub-window) and unit lending (flux's 0.37 s encode sits
    below the ``lend_min_stage_s`` gate and the waves are correlated, so
    lending only adds force-return thrash).
    """
    from repro.core import workloads
    from repro.core.fleet import FleetConfig, PipelineRegistry, run_fleet

    dur = duration if duration is not None else CROSS_BATCH_DURATION
    seeds = seeds if seeds is not None else ((0,) if quick else (0, 1, 2))
    cfg_kw = dict(CROSS_BATCH_CFG)
    cfg_kw.update(fleet_cfg_kw or {})
    registry = PipelineRegistry(CROSS_BATCH_PIPELINES)
    profs = {pid: registry.profiler(pid) for pid in CROSS_BATCH_PIPELINES}

    def mk_trace(seed):
        return workloads.cross_batch_trace(dur, profs, seed=seed,
                                           base_rates=base_rates,
                                           wave_rates=wave_rates, head=head)

    def one(mode, seed, **extra_cfg):
        cfg = FleetConfig(**{**cfg_kw, **extra_cfg})
        t0 = time.perf_counter()
        res = run_fleet(CROSS_BATCH_PIPELINES, mode=mode, duration=dur,
                        cfg=cfg, registry=registry, trace=mk_trace(seed))
        return res, time.perf_counter() - t0

    rows: List[Row] = []
    results = {}
    ratio_by_seed = {}
    for seed in seeds:
        per_arm = {}
        for arm, extra in (("off", {}),
                           ("batching", dict(
                               cross_lane_batching=True,
                               cross_lane_max_batch=CROSS_BATCH_MAX_BATCH))):
            res, wall = one("predictive", seed, **extra)
            per_arm[arm] = res
            tag = f"e2e_cross_batch/{arm}" + (f"/s{seed}" if seed else "")
            rows.append((f"{tag}/p95_s", round(res.p95_latency, 3),
                         {"slo_pct": round(res.slo_attainment * 100, 2),
                          "goodput_rps": round(res.goodput, 3),
                          "mean_s": round(res.mean_latency, 3),
                          "cross_lane_merges": res.cross_lane_merges,
                          "repartitions": len(res.repartitions) - 1,
                          "wall_s": round(wall, 2)}))
            for pid, m in res.per_pipeline.items():
                rows.append((f"{tag}/{pid}/p95_s", round(m["p95_s"], 3),
                             {"slo_pct": round(m["slo"] * 100, 2),
                              "mean_s": round(m["mean_s"], 3)}))
        off, on = per_arm["off"], per_arm["batching"]
        ratio_by_seed[seed] = off.p95_latency / max(on.p95_latency, 1e-9)
        if seed == seeds[0]:
            results = per_arm
    off, on = results["off"], results["batching"]
    worst_x = min(ratio_by_seed.values())  # detlint: ignore[DET004] numeric extremum over values: order-free
    rows.append(("e2e_cross_batch/p95_improvement_batching_vs_off",
                 round(worst_x, 3),
                 {"per_seed": {s: round(v, 3)
                               for s, v in ratio_by_seed.items()},
                  "cross_lane_merges": on.cross_lane_merges,
                  "slo_pts": round((on.slo_attainment
                                    - off.slo_attainment) * 100, 2)}))
    narrative = {}
    if narrative_arms:
        ad, _ = one("adaptive", seeds[0])
        ln, _ = one("predictive", seeds[0], lending=True)
        narrative = {
            "adaptive_p95_s": round(ad.p95_latency, 3),
            "adaptive_repartitions": len(ad.repartitions) - 1,
            "lending_p95_s": round(ln.p95_latency, 3),
            "lending_loans": ln.loans,
        }
        rows.append(("e2e_cross_batch/narrative/adaptive_p95_s",
                     round(ad.p95_latency, 3),
                     {"repartitions": len(ad.repartitions) - 1}))
        rows.append(("e2e_cross_batch/narrative/lending_p95_s",
                     round(ln.p95_latency, 3), {"loans": ln.loans}))
    if bench_path:
        bench = {
            "bench": "cross_lane_batching_burst_storm",
            "num_chips": cfg_kw["num_chips"],
            "pipelines": list(CROSS_BATCH_PIPELINES),
            "duration_s": dur,
            "base_rates_rps": dict(base_rates
                                   or workloads.CROSS_BATCH_BASE_RATES),
            "wave_rates_rps": dict(wave_rates
                                   or workloads.CROSS_BATCH_WAVE_RATES),
            "cond_len": dict(workloads.CROSS_BATCH_COND),
            "cross_lane_max_batch": CROSS_BATCH_MAX_BATCH,
            "p95_improvement_batching_vs_off": round(worst_x, 3),
            "p95_improvement_per_seed":
                {s: round(v, 3) for s, v in ratio_by_seed.items()},
            "slo_improvement_pts": round((on.slo_attainment
                                          - off.slo_attainment) * 100, 2),
            "cross_lane_merges": on.cross_lane_merges,
            "narrative": narrative,
            "modes": {
                arm: {
                    "p95_s": round(r.p95_latency, 3),
                    "mean_s": round(r.mean_latency, 3),
                    "slo_pct": round(r.slo_attainment * 100, 2),
                    "goodput_rps": round(r.goodput, 3),
                    "cross_lane_merges": r.cross_lane_merges,
                    "repartitions": len(r.repartitions) - 1,
                    "per_pipeline": {
                        pid: {k: (round(v, 3) if isinstance(v, float)
                                  else v) for k, v in m.items()}
                        for pid, m in r.per_pipeline.items()},
                } for arm, r in results.items()},
        }
        with open(bench_path, "w") as f:
            json.dump(bench, f, indent=2)
            f.write("\n")
    return rows


def run_cross_batch_smoke(bench_path: Optional[str] = None) -> List[Row]:
    """CI-sized ``--cross-batch`` variant: the same burst storm at 2/3
    scale, seed 0 only, no narrative arms — exercises the whole cross-lane
    fuse path (candidate marking, E-hold, grouped ILP column, merged
    completion events) on every smoke run without touching
    BENCH_cross_batch.json."""
    sm = CROSS_BATCH_SMOKE
    return run_cross_batch(bench_path=bench_path, duration=sm["duration"],
                           head=sm["head"], base_rates=sm["base_rates"],
                           wave_rates=sm["wave_rates"],
                           fleet_cfg_kw=sm["cfg"], seeds=(0,),
                           narrative_arms=False)


# ------------------------------------------------------------------ elastic

# Elastic, failure-prone fleet on the preemption-storm capacity script
# (workloads.preemption_storm_schedule): identical arrivals and identical
# capacity events on both arms, drain-aware (act on the preemption notice
# — decommission doomed units, force-return their loans, pre-warm the
# announced join) vs drain-unaware (ignore the notice, eat the full
# in-flight requeue at the loss).  The scenario rates and schedule
# generators live next to the trace generators (workloads.ELASTIC_*);
# these hold the fleet knobs.
from repro.core.workloads import ELASTIC_LEVEL, ELASTIC_RATES

ELASTIC_PIPELINES = ("sd3", "hunyuanvideo")
ELASTIC_DURATION = 900.0
ELASTIC_CFG: Dict = dict(num_chips=256, t_win=120.0, cooldown=100.0)
# recovery window: the headline is P95 latency over requests arriving
# between a preemption *notice* and this long after its *landing* — the
# tail the drain window exists to protect.
ELASTIC_RECOVERY_TAIL = 120.0

# CI-sized variant: one storm on 128 chips at ~half rate.  Too small to
# show the drain win (the two-node storm's requeues don't back a 128-chip
# pool up), so smoke is a *mechanism canary*: the unaware arm must pay
# requeues, the aware arm must drain, and recovery P95 must hold parity
# (>= 0.9x).  The committed full-scale baseline pins the 1.15x win.
ELASTIC_SMOKE: Dict = dict(
    duration=480.0, n_storms=1,
    rates={"sd3": 4.0, "hunyuanvideo": 0.8},
    cfg=dict(num_chips=128, t_win=90.0, cooldown=70.0))


def _recovery_windows(schedule, tail: float) -> List[Tuple[float, float]]:
    """[notice, land + tail] span of every preemption in the schedule."""
    return [(ev.t - ev.lead, ev.t + tail)
            for ev in schedule if ev.kind == "preempt"]


def _recovery_p95(trace, windows, horizon_lat: float) -> Tuple[float, int]:
    """P95 latency (censored at the horizon, like FleetResult) over the
    requests that arrive inside any recovery window."""
    lat: List[float] = []
    for r in trace:
        if not any(lo <= r.arrival <= hi for lo, hi in windows):
            continue
        f = r.stage_done.get("C")
        lat.append((f - r.arrival) if f is not None
                   else (horizon_lat - r.arrival))
    lat.sort()
    n = len(lat)
    return (lat[int(0.95 * (n - 1))] if n else 0.0), n


def run_elastic(quick: bool = True,
                bench_path: Optional[str] = "BENCH_elastic.json",
                duration: Optional[float] = None,
                rates: Optional[Dict[str, float]] = None,
                n_storms: int = 2,
                fleet_cfg_kw: Optional[Dict] = None,
                seeds: Optional[Tuple[int, ...]] = None) -> List[Row]:
    """Elastic capacity + fault injection on the preemption-storm script.

    Both arms play the *same* capacity schedule through the FaultInjector
    wake source on identical arrivals: degraded node (detected and
    quarantined), announced preemption storms, autoscale joins.  The
    drain-aware arm acts on each notice — doomed units drain (only work
    that lands before the loss keeps flowing through them), their loans
    force-return, the join's incoming chips pre-warm — while the
    drain-unaware arm ignores notices and pays the full in-flight
    requeue when the nodes vanish.

    The storm script is *fixed* (``preemption_storm_schedule(seed=0)``,
    the canonical committed scenario) and bench seeds vary only the
    arrival trace — a controlled experiment: re-rolling the script with
    the seed would conflate storm-severity variance with the arm
    difference.  The headline is the recovery-window P95 ratio
    unaware/aware on the canonical trace (``seeds[0]``; acceptance:
    >= 1.15x at the committed scale, >= 0.9x in smoke); the remaining
    seeds are a robustness sweep with a never-worse floor (>= 0.95x —
    window P95 sits on the long video pipeline's runtime tail, so
    off-canonical traces read as noisy parity whenever the loss
    transient, which both arms share, dominates their windows).
    """
    from repro.core import workloads
    from repro.core.fleet import FleetConfig, PipelineRegistry, run_fleet

    dur = duration if duration is not None else ELASTIC_DURATION
    seeds = seeds if seeds is not None else ((0,) if quick else (0, 1, 2))
    rates = rates or ELASTIC_RATES
    cfg_kw = dict(ELASTIC_CFG)
    cfg_kw.update(fleet_cfg_kw or {})
    chips = cfg_kw["num_chips"]
    registry = PipelineRegistry(ELASTIC_PIPELINES)
    profs = {pid: registry.profiler(pid) for pid in ELASTIC_PIPELINES}
    rows: List[Row] = []
    results = {}
    rec = {}
    ratio_by_seed = {}
    # one canonical storm script for every bench seed (see docstring)
    schedule = workloads.preemption_storm_schedule(
        dur, chips, seed=0, n_storms=n_storms)
    windows = _recovery_windows(schedule, ELASTIC_RECOVERY_TAIL)
    for seed in seeds:
        per_arm = {}
        rec_arm = {}
        for arm, act in (("drain_aware", True), ("drain_unaware", False)):
            cfg = FleetConfig(**cfg_kw, elastic=True,
                              elastic_schedule=schedule,
                              elastic_drain=act, elastic_prewarm=act)
            trace = workloads.fleet_trace(ELASTIC_PIPELINES, dur, profs,
                                          seed=seed, rates=rates,
                                          level=ELASTIC_LEVEL)
            t0 = time.perf_counter()
            res = run_fleet(ELASTIC_PIPELINES, mode="adaptive", duration=dur,
                            cfg=cfg, registry=registry, trace=trace)
            wall = time.perf_counter() - t0
            trace_end = trace[-1].arrival if trace else 0.0
            rp95, n_rec = _recovery_p95(trace, windows,
                                        trace_end + cfg.horizon_slack)
            per_arm[arm] = res
            rec_arm[arm] = (rp95, n_rec)
            tag = f"e2e_elastic/{arm}" + (f"/s{seed}" if seed else "")
            rows.append((f"{tag}/recovery_p95_s", round(rp95, 3),
                         {"recovery_requests": n_rec,
                          "p95_s": round(res.p95_latency, 3),
                          "slo_pct": round(res.slo_attainment * 100, 2),
                          "requeued": res.requeued_requests,
                          "drained_units": res.drained_units,
                          "nodes_lost": res.nodes_lost,
                          "nodes_joined": res.nodes_joined,
                          "prewarm_chips": res.elastic_prewarm_chips,
                          "quarantined": res.quarantined_units,
                          "final_chips": res.final_chips,
                          "wall_s": round(wall, 2)}))
        aware, unaware = rec_arm["drain_aware"], rec_arm["drain_unaware"]
        ratio_by_seed[seed] = unaware[0] / max(aware[0], 1e-9)
        if seed == seeds[0]:
            results = per_arm
            rec = rec_arm
    aware, unaware = results["drain_aware"], results["drain_unaware"]
    headline_x = ratio_by_seed[seeds[0]]
    sweep_floor = min(ratio_by_seed.values())  # detlint: ignore[DET004] numeric extremum over values: order-free
    rows.append(("e2e_elastic/recovery_p95_improvement_drain_vs_unaware",
                 round(headline_x, 3),
                 {"per_seed": {s: round(v, 3)
                               for s, v in ratio_by_seed.items()},
                  "sweep_floor": round(sweep_floor, 3),
                  "requeued_unaware": unaware.requeued_requests,
                  "requeued_aware": aware.requeued_requests,
                  "slo_pts": round((aware.slo_attainment
                                    - unaware.slo_attainment) * 100, 2)}))
    if bench_path:
        bench = {
            "bench": "elastic_preemption_storm",
            "num_chips": chips,
            "pipelines": list(ELASTIC_PIPELINES),
            "duration_s": dur,
            "rates_rps": dict(rates),
            "n_storms": n_storms,
            "recovery_tail_s": ELASTIC_RECOVERY_TAIL,
            "recovery_p95_improvement_drain_vs_unaware": round(headline_x, 3),
            "recovery_p95_improvement_per_seed":
                {s: round(v, 3) for s, v in ratio_by_seed.items()},
            "recovery_p95_sweep_floor": round(sweep_floor, 3),
            "slo_improvement_pts": round((aware.slo_attainment
                                          - unaware.slo_attainment) * 100, 2),
            "modes": {
                arm: {
                    "recovery_p95_s": round(rec[arm][0], 3),
                    "recovery_requests": rec[arm][1],
                    "p95_s": round(r.p95_latency, 3),
                    "mean_s": round(r.mean_latency, 3),
                    "slo_pct": round(r.slo_attainment * 100, 2),
                    "goodput_rps": round(r.goodput, 3),
                    "capacity_events": r.capacity_events,
                    "nodes_joined": r.nodes_joined,
                    "nodes_lost": r.nodes_lost,
                    "requeued_requests": r.requeued_requests,
                    "drained_units": r.drained_units,
                    "quarantined_units": r.quarantined_units,
                    "elastic_prewarm_chips": r.elastic_prewarm_chips,
                    "final_chips": r.final_chips,
                    "repartitions": len(r.repartitions) - 1,
                    "per_pipeline": {
                        pid: {k: (round(v, 3) if isinstance(v, float)
                                  else v) for k, v in m.items()}
                        for pid, m in r.per_pipeline.items()},
                } for arm, r in results.items()},
        }
        with open(bench_path, "w") as f:
            json.dump(bench, f, indent=2)
            f.write("\n")
    return rows


def run_elastic_smoke(bench_path: Optional[str] = None) -> List[Row]:
    """CI-sized ``--elastic`` variant: one preemption storm on 128 chips
    at half rate, seed 0 only — exercises the whole fault path (notice →
    drain → loss → requeue → compacted re-partition, join pre-warm,
    degrade quarantine) on every smoke run without touching
    BENCH_elastic.json."""
    sm = ELASTIC_SMOKE
    return run_elastic(bench_path=bench_path, duration=sm["duration"],
                       rates=sm["rates"], n_storms=sm["n_storms"],
                       fleet_cfg_kw=sm["cfg"], seeds=(0,))


# ---------------------------------------------------------------- scale tier

# 8-pipeline fleet at datacenter scale: the 4 base configs plus a -v2 alias
# of each (same profile, separately-tracked traffic), rates tuned so 4096
# chips sit hot-but-not-saturated (~528 req/s aggregate).  The canonical
# definition lives in workloads.SCALE_* — the values here only name the two
# committed tiers.
SCALE_SMOKE_CHIPS = 512
SCALE_SMOKE_REQUESTS = 100_000
SCALE_FULL_CHIPS = 4096
SCALE_FULL_REQUESTS = 1_000_000
SCALE_LEVEL = "medium"
# the three flag-gated hot paths this tier exists to measure (FleetConfig
# fields; the committed BENCH baselines all run with these at their off
# defaults, pinned bit-exact by tests/test_scale_parity.py)
SCALE_FAST_KW: Dict = dict(array_state=True, incremental_ilp=True,
                           step_changed_lanes_only=True)

# Self-contained so it also runs against a pre-scale-out reference tree:
# the trace is built from the (rates, aliases, level) payload via the
# pre-existing fleet_trace API instead of workloads.scale_trace (which the
# reference tree does not have), and unknown FleetConfig fields are
# filtered out.  Only ``FleetSimulator.run`` is timed.
_SCALE_DRIVER = r"""
import dataclasses, gc, json, sys, time
from repro.core import workloads
from repro.core.fleet import (FleetConfig, FleetOrchestrator, FleetSimulator,
                              PipelineRegistry, FLEET_SCHEDULERS)
p = json.load(sys.stdin)
aliases = p["aliases"]
scale = p["num_chips"] / p["base_chips"]
rates = {pid: r * scale for pid, r in p["rates"].items()}
duration = p["n_requests"] / sum(rates.values())
pipelines = list(p["rates"])
mix = {a: workloads.MIXES[b][p["level"]] for a, b in aliases.items()}
# older trees resolve RATES[pid] eagerly inside fleet_trace's rate lookup;
# aliases only need the key to exist (their real rate comes from ``rates``)
for a in aliases:
    workloads.RATES.setdefault(a, 0.0)
fields = {f.name for f in dataclasses.fields(FleetConfig)}
cfg_kw = {k: v for k, v in p["cfg_kw"].items() if k in fields}
best = None
for _ in range(p["repeats"]):
    reg = PipelineRegistry()
    for pid in pipelines:
        if pid not in aliases:
            reg.register(pid)
    for a, b in aliases.items():
        reg.register(a, profiler=reg.profiler(b))
    profs = {pid: reg.profiler(pid) for pid in pipelines}
    trace = workloads.fleet_trace(pipelines, duration, profs, seed=0,
                                  rates=rates, level=p["level"],
                                  mix_override=mix)
    cfg = FleetConfig(num_chips=p["num_chips"], **cfg_kw)
    orch = FleetOrchestrator(reg, num_chips=p["num_chips"])
    sched = FLEET_SCHEDULERS["adaptive"](orch, cfg)
    sim = FleetSimulator(reg, sched, trace, cfg)
    # cyclic-GC pauses scale with the live heap (every trace request stays
    # reachable), so leaving the collector on taxes the longer tier
    # superlinearly for work that is not the sim core's.  Both trees are
    # timed under the same policy, so speedup ratios stay apples-to-apples.
    gc.collect()
    gc.disable()
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0
    gc.enable()
    if best is None or wall < best["wall_s"]:
        best = {"wall_s": wall, "duration_s": duration,
                "n_requests": len(trace), "n_finished": res.n_finished,
                "slo": res.slo_attainment, "wakeups": res.sched_wakeups,
                "repartitions": len(res.repartitions) - 1}
print(json.dumps(best))
"""


def _time_scale_tree(root: str, num_chips: int, n_requests: int,
                     fast: bool, repeats: int, label: str) -> Optional[Dict]:
    """Run the scale scenario against a checked-out tree; returns the
    best-of-``repeats`` sim-core measurement dict, or None."""
    import os
    import subprocess
    import sys as _sys
    from repro.core import workloads as wl
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    payload = {"num_chips": num_chips, "n_requests": n_requests,
               "base_chips": wl.SCALE_BASE_CHIPS, "rates": wl.SCALE_RATES,
               "aliases": wl.SCALE_ALIASES, "level": SCALE_LEVEL,
               "cfg_kw": SCALE_FAST_KW if fast else {}, "repeats": repeats}
    try:
        out = subprocess.run([_sys.executable, "-c", _SCALE_DRIVER],
                             input=json.dumps(payload),
                             capture_output=True, text=True, env=env,
                             timeout=3600, check=True)
        return json.loads(out.stdout.strip().splitlines()[-1])
    except Exception as e:  # missing worktree etc. — report, don't fail
        print(f"# {label} timing unavailable: {e}", flush=True)
        return None


def run_scale(full: bool = False,
              bench_path: Optional[str] = "BENCH_scale.json",
              scale_ref: Optional[str] = None) -> List[Row]:
    """The 4096-chip / 1M-request sim-core throughput tier (``--scale``).

    Headline: requests per second of *wall clock* the simulator core
    sustains on the 8-pipeline scale trace with the three flag-gated hot
    paths on (``SCALE_FAST_KW``) — the same role BENCH_unified_clock.json
    plays for kernel overhead, at fleet scale.  Smoke mode runs the
    512-chip / 100k-request slice; ``--full`` runs the committed
    4096-chip / 1M-request tier.

    With ``scale_ref`` (a checked-out pre-scale-out tree), a 100k-request
    probe slice at the same chip count is timed against both trees in
    alternating subprocesses (best-of interleaved rounds, the
    BENCH_unified_clock method, so minutes-scale machine drift cannot
    masquerade as speedup).  ``speedup_same_tier`` is the probe ratio;
    ``speedup_extrapolated`` divides the full run's throughput by the
    reference tree's probe throughput — flat extrapolation across request
    count, which is *generous* to the reference (its per-wake-up costs
    cannot shrink on a 10x longer trace).
    """
    import os
    from repro.core import workloads as wl
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    chips = SCALE_FULL_CHIPS if full else SCALE_SMOKE_CHIPS
    n_req = SCALE_FULL_REQUESTS if full else SCALE_SMOKE_REQUESTS
    probe_req = min(n_req, SCALE_SMOKE_REQUESTS)
    rows: List[Row] = []

    now_probe = pre_probe = None
    for _ in range(BENCH_REPEATS):
        now = _time_scale_tree(here, chips, probe_req, True, 1,
                               "self(scale)")
        if now is None:
            return rows
        if now_probe is None or now["wall_s"] < now_probe["wall_s"]:
            now_probe = now
        if scale_ref:
            pre = _time_scale_tree(scale_ref, chips, probe_req, False, 1,
                                   "scale-ref")
            if pre is not None and (pre_probe is None
                                    or pre["wall_s"] < pre_probe["wall_s"]):
                pre_probe = pre

    if full:
        head = _time_scale_tree(here, chips, n_req, True, 1, "self(scale)")
        if head is None:
            return rows
    else:
        head = now_probe
    rps = head["n_requests"] / max(head["wall_s"], 1e-9)
    rows.append((f"e2e_scale/{chips}chips/{head['n_requests']}req"
                 "/throughput_rps", round(rps, 1),
                 {"wall_s": round(head["wall_s"], 2),
                  "slo_pct": round(head["slo"] * 100, 2),
                  "finished": head["n_finished"],
                  "wakeups": head["wakeups"],
                  "repartitions": head["repartitions"]}))
    bench = {
        "bench": "scale_sim_core",
        "num_chips": chips,
        "pipelines": list(wl.SCALE_PIPELINES),
        "level": SCALE_LEVEL,
        "fast_path": dict(SCALE_FAST_KW),
        "n_requests": head["n_requests"],
        "duration_s": round(head["duration_s"], 1),
        "wall_s": round(head["wall_s"], 2),
        "throughput_rps": round(rps, 1),
        "n_finished": head["n_finished"],
        "slo_pct": round(head["slo"] * 100, 2),
        "sched_wakeups": head["wakeups"],
    }
    if pre_probe is not None:
        rps_now_probe = now_probe["n_requests"] / max(now_probe["wall_s"],
                                                      1e-9)
        rps_pre_probe = pre_probe["n_requests"] / max(pre_probe["wall_s"],
                                                      1e-9)
        bench["probe"] = {
            "num_chips": chips, "n_requests": now_probe["n_requests"],
            "wall_now_s": round(now_probe["wall_s"], 2),
            "wall_pre_s": round(pre_probe["wall_s"], 2),
            "throughput_now_rps": round(rps_now_probe, 1),
            "throughput_pre_rps": round(rps_pre_probe, 1),
        }
        bench["speedup_same_tier"] = round(rps_now_probe
                                           / max(rps_pre_probe, 1e-9), 2)
        bench["speedup_extrapolated"] = round(rps
                                              / max(rps_pre_probe, 1e-9), 2)
        rows.append((f"e2e_scale/{chips}chips/speedup_same_tier",
                     bench["speedup_same_tier"],
                     {"pre_rps": round(rps_pre_probe, 1),
                      "now_rps": round(rps_now_probe, 1)}))
        rows.append((f"e2e_scale/{chips}chips/speedup_extrapolated",
                     bench["speedup_extrapolated"],
                     {"full_rps": round(rps, 1),
                      "pre_probe_rps": round(rps_pre_probe, 1)}))
    if bench_path:
        with open(bench_path, "w") as f:
            json.dump(bench, f, indent=2)
            f.write("\n")
    return rows


# ------------------------------------------------------------- wall profile

# per-subsystem wall-share buckets: (bucket, module path, class, methods)
_PROFILE_TARGETS = (
    ("dispatch_ilp", "repro.core.dispatcher", "Dispatcher", ("dispatch",)),
    ("cross_lane_batching", "repro.core.dispatcher", "CrossLaneBatcher",
     ("select", "step")),
    ("monitor", "repro.core.monitor", "Monitor",
     ("record_stage", "record_backlog", "next_window_boundary",
      "pattern_change")),
    ("monitor", "repro.core.monitor", "FleetMonitor",
     ("record_arrival", "record_finish", "record_util",
      "record_class_demand", "demand", "demand_shares", "slo_attainment",
      "backlog_pressure", "idle_supply", "next_window_boundary",
      "mix_shift")),
    ("orchestrator", "repro.core.fleet", "FleetOrchestrator",
     ("generate", "budgets")),
    ("lending", "repro.core.lending", "UnitLendingBroker",
     ("step", "sample")),
    ("engine_execute", "repro.core.runtime", "RuntimeEngine", ("execute",)),
)


def run_profile(full: bool = False) -> List[Row]:
    """``--profile``: per-subsystem wall shares of one scale-tier run.

    Wraps the subsystem entry points (dispatch/ILP, monitor, orchestrator,
    lending, cross-lane batching, engine execute) with wall accumulators
    and runs the scale slice in-process; whatever wall is left over is the
    clock kernel + lane bookkeeping.  A single global re-entrancy guard
    attributes nested calls (e.g. the orchestrator consulting the monitor)
    to the *outermost* bucket, so the shares are additive.
    """
    import importlib
    from repro.core import workloads
    from repro.core.fleet import (FleetConfig, FleetOrchestrator,
                                  FleetSimulator, PipelineRegistry,
                                  FLEET_SCHEDULERS)

    chips = SCALE_FULL_CHIPS if full else SCALE_SMOKE_CHIPS
    n_req = (SCALE_FULL_REQUESTS if full else SCALE_SMOKE_REQUESTS) // 10
    acc: Dict[str, float] = {}
    depth = [0]
    patched = []
    for bucket, modname, clsname, methods in _PROFILE_TARGETS:
        try:
            cls = getattr(importlib.import_module(modname), clsname)
        except (ImportError, AttributeError):
            continue
        for meth in methods:
            orig = cls.__dict__.get(meth)
            if orig is None:
                continue

            def timed(*a, __orig=orig, __b=bucket, **kw):
                if depth[0]:
                    return __orig(*a, **kw)
                depth[0] = 1
                t0 = time.perf_counter()
                try:
                    return __orig(*a, **kw)
                finally:
                    depth[0] = 0
                    acc[__b] = (acc.get(__b, 0.0)
                                + time.perf_counter() - t0)
            setattr(cls, meth, timed)
            patched.append((cls, meth, orig))
    try:
        reg = PipelineRegistry()
        for pid in workloads.SCALE_PIPELINES:
            if pid not in workloads.SCALE_ALIASES:
                reg.register(pid)
        for a, b in workloads.SCALE_ALIASES.items():
            reg.register(a, profiler=reg.profiler(b))
        profs = {pid: reg.profiler(pid) for pid in workloads.SCALE_PIPELINES}
        dur = workloads.scale_duration(n_req, chips)
        trace = workloads.scale_trace(dur, profs, seed=0, num_chips=chips,
                                      level=SCALE_LEVEL)
        cfg = FleetConfig(num_chips=chips, **SCALE_FAST_KW)
        orch = FleetOrchestrator(reg, num_chips=chips)
        sched = FLEET_SCHEDULERS["adaptive"](orch, cfg)
        sim = FleetSimulator(reg, sched, trace, cfg)
        t0 = time.perf_counter()
        sim.run()
        wall = time.perf_counter() - t0
    finally:
        for cls, meth, orig in patched:
            setattr(cls, meth, orig)
    rows: List[Row] = []
    accounted = sum(acc[k] for k in sorted(acc))
    acc["clock_kernel_and_lanes"] = max(0.0, wall - accounted)
    for bucket in sorted(acc):
        rows.append((f"e2e_scale_profile/{chips}chips/{bucket}/wall_s",
                     round(acc[bucket], 3),
                     {"share_pct": round(100.0 * acc[bucket]
                                         / max(wall, 1e-9), 1)}))
    rows.append((f"e2e_scale_profile/{chips}chips/total/wall_s",
                 round(wall, 3),
                 {"requests": len(trace),
                  "throughput_rps": round(len(trace) / max(wall, 1e-9), 1)}))
    return rows


def run_shared_smoke(bench_path: Optional[str] = None) -> List[Row]:
    """CI-sized ``--mixed --shared`` variant: short flip trace, static vs
    adaptive only, fleet windows shrunk to match — exercises the whole fleet
    path (partition, mix-shift detection, re-partition with reload costs)
    on every smoke run without touching BENCH_shared_cluster.json.
    ``bench_path`` (used by ``benchmarks.run --smoke``) writes the smoke
    run's own JSON for the check_regression gate."""
    return run_mixed_shared(bench_path=bench_path, duration=240.0,
                            modes=("static", "adaptive"),
                            fleet_cfg_kw={"t_win": 90.0, "cooldown": 60.0})


def _shared_summary_rows(rows: List[Row], results: Dict,
                         bench_path: Optional[str], dur: float) -> List[Row]:
    if "static" in results and "adaptive" in results:
        st, ad = results["static"], results["adaptive"]
        p95_x = st.p95_latency / max(ad.p95_latency, 1e-9)
        goodput_x = ad.goodput / max(st.goodput, 1e-9)
        worst_x = (max(m["p95_s"] for m in st.per_pipeline.values())  # detlint: ignore[DET004] numeric extremum over values: order-free
                   / max(1e-9, max(m["p95_s"]  # detlint: ignore[DET004] numeric extremum over values: order-free
                                   for m in ad.per_pipeline.values())))
        rows.append(("e2e_shared512/p95_improvement_adaptive_vs_static",
                     round(p95_x, 2),
                     {"goodput_x": round(goodput_x, 3),
                      "worst_pipeline_p95_x": round(worst_x, 2)}))
        if bench_path:
            bench = {
                "bench": "shared_cluster_mix_flip",
                "num_chips": 512,
                "pipelines": list(SHARED_PIPELINES),
                "duration_s": dur,
                "rates_rps": SHARED_RATES,
                "phases": [[f, dict(m)] for f, m in SHARED_FLIP],
                "p95_improvement_adaptive_vs_static": round(p95_x, 2),
                "goodput_improvement_adaptive_vs_static": round(goodput_x, 3),
                "worst_pipeline_p95_improvement": round(worst_x, 2),
                "modes": {
                    mode: {
                        "p95_s": round(r.p95_latency, 3),
                        "mean_s": round(r.mean_latency, 3),
                        "slo_pct": round(r.slo_attainment * 100, 2),
                        "goodput_rps": round(r.goodput, 3),
                        "finished": r.n_finished,
                        "requests": r.n_requests,
                        "repartitions": len(r.repartitions) - 1,
                        "swap_cost_s": round(r.swap_cost_s, 2),
                        "units_reloaded": r.units_reloaded,
                        "per_pipeline": {
                            pid: {k: (round(v, 3) if isinstance(v, float)
                                      else v) for k, v in m.items()}
                            for pid, m in r.per_pipeline.items()},
                    } for mode, r in results.items()},
            }
            with open(bench_path, "w") as f:
                json.dump(bench, f, indent=2)
                f.write("\n")
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke set + event-vs-tick speedup "
                         "(writes BENCH_event_sim.json)")
    ap.add_argument("--mixed", action="store_true",
                    help="512-chip mixed SD3+Flux+CogVideoX scenario")
    ap.add_argument("--shared", action="store_true",
                    help="one shared 512-chip cluster under a mix-flip "
                         "trace; fleet scheduler trio (writes "
                         "BENCH_shared_cluster.json); implies --mixed")
    ap.add_argument("--lending", action="store_true",
                    help="cross-pipeline unit lending on the bursty-E/C "
                         "trace: adaptive vs adaptive+lending (writes "
                         "BENCH_unit_lending.json); implies --mixed "
                         "--shared")
    ap.add_argument("--predictive", action="store_true",
                    help="predictive re-partitioning on the diurnal "
                         "mix-flip trace: adaptive vs predictive (writes "
                         "BENCH_predictive.json)")
    ap.add_argument("--cross-batch", action="store_true",
                    help="cross-lane dynamic batching on the long-prompt "
                         "burst-storm trace: predictive with batching off "
                         "vs on (writes BENCH_cross_batch.json)")
    ap.add_argument("--elastic", action="store_true",
                    help="elastic, failure-prone fleet on the "
                         "preemption-storm capacity script: drain-aware "
                         "vs drain-unaware recovery (writes "
                         "BENCH_elastic.json)")
    ap.add_argument("--scale", action="store_true",
                    help="sim-core throughput tier: the 8-pipeline scale "
                         "trace with the flag-gated hot paths on — "
                         "512 chips / 100k requests by default, "
                         "4096 chips / 1M requests with --full (writes "
                         "BENCH_scale.json)")
    ap.add_argument("--profile", action="store_true",
                    help="per-subsystem wall shares (clock kernel, "
                         "dispatch/ILP, monitor, orchestrator, lending, "
                         "cross-lane batching) of one scale-tier run")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--bench-json", default="BENCH_event_sim.json")
    ap.add_argument("--seed-ref", default=None,
                    help="path to a checked-out seed tree; also times the "
                         "original tick loop for the BENCH record")
    ap.add_argument("--unified-json", default=None,
                    help="with --smoke: also write the unified-kernel "
                         "BENCH (e.g. BENCH_unified_clock.json)")
    ap.add_argument("--shared-json", default="BENCH_shared_cluster.json",
                    help="output path for the --shared BENCH (point it "
                         "away from the committed baseline when the run "
                         "feeds the regression gate, e.g. in nightly CI)")
    ap.add_argument("--lending-json", default="BENCH_unit_lending.json",
                    help="output path for the --lending BENCH (same "
                         "caveat as --shared-json)")
    ap.add_argument("--predictive-json", default="BENCH_predictive.json",
                    help="output path for the --predictive BENCH (same "
                         "caveat as --shared-json)")
    ap.add_argument("--cross-batch-json", default="BENCH_cross_batch.json",
                    help="output path for the --cross-batch BENCH (same "
                         "caveat as --shared-json)")
    ap.add_argument("--elastic-json", default="BENCH_elastic.json",
                    help="output path for the --elastic BENCH (same "
                         "caveat as --shared-json)")
    ap.add_argument("--pre-ref", default=None,
                    help="path to a checked-out pre-unification tree (the "
                         "last commit with the two hand-rolled loops); "
                         "records the kernel's overhead vs them in the "
                         "unified-kernel BENCH")
    ap.add_argument("--scale-ref", default=None,
                    help="path to a checked-out pre-scale-out tree; times "
                         "a same-chip-count probe slice against it in "
                         "interleaved subprocesses and records the "
                         "speedup in the scale BENCH")
    ap.add_argument("--scale-json", default="BENCH_scale.json",
                    help="output path for the --scale BENCH (same caveat "
                         "as --shared-json)")
    args = ap.parse_args()
    if args.scale:
        emit(run_scale(full=args.full, bench_path=args.scale_json,
                       scale_ref=args.scale_ref))
    if args.profile:
        emit(run_profile(full=args.full))
    if args.smoke:
        emit(run_smoke(bench_path=args.bench_json, seed_ref=args.seed_ref,
                       unified_bench_path=args.unified_json,
                       pre_ref=args.pre_ref))
    if args.predictive:
        emit(run_predictive(quick=not args.full,
                            bench_path=args.predictive_json))
    if args.cross_batch:
        emit(run_cross_batch(quick=not args.full,
                             bench_path=args.cross_batch_json))
    if args.elastic:
        emit(run_elastic(quick=not args.full,
                         bench_path=args.elastic_json))
    if args.lending:
        emit(run_lending(quick=not args.full, bench_path=args.lending_json))
    elif args.shared:
        emit(run_mixed_shared(quick=not args.full,
                              bench_path=args.shared_json))
    elif args.mixed:
        emit(run_mixed(quick=not args.full))
    if not (args.smoke or args.mixed or args.shared or args.lending
            or args.predictive or args.cross_batch or args.elastic
            or args.scale or args.profile):
        emit(run(quick=not args.full))
