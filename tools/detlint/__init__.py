"""detlint — AST determinism-and-contract linter for the sim core.

Encodes the repo's reproducibility invariants (docs/determinism.md) as
named, machine-checked rules over ``src/repro/core``, ``src/repro/serving``
and ``benchmarks``:

=======  ==================================================================
DET001   order-sensitive accumulation fed by unordered (set/dict) iteration
DET002   wall-clock read reaching control flow, or bare in the strict core
DET003   module-level (global-state) RNG use
DET004   min/max/sort selection over unordered collections (hash-order ties)
DET005   unordered iteration mutating shared scheduler state unsorted
=======  ==================================================================

Stdlib-only (``ast`` + ``tokenize``): a visitor with lightweight
intra-function dataflow — collection-kind inference for DET001/4/5 and
wall-clock taint for DET002.  Findings carry stable rule IDs and
``file:line:col`` anchors; ``# detlint: ignore[DETnnn] <reason>``
suppresses on the flagged line; a committed baseline file grandfathers
accepted findings; ``--format=github`` emits workflow annotations.

The linter's own output is deterministic under any ``PYTHONHASHSEED``
(tests/test_detlint.py proves it) — a determinism gate that itself
depended on hash order would be worse than none.
"""
from tools.detlint.engine import lint_paths, lint_source
from tools.detlint.findings import Finding, RULES

__all__ = ["Finding", "RULES", "lint_paths", "lint_source"]

__version__ = "1.0"
