"""Inline suppression comments, parsed from the token stream.

Grammar (one comment, anywhere on a line of the flagged construct's
header):

    # detlint: ignore[DET001] <reason>
    # detlint: ignore[DET002,DET004] <reason>
    # detlint: skip-file <reason>

The reason is required: a bare ``ignore[...]`` is itself reported as a
malformed suppression so accepted findings always document *why* they
are acceptable (the burn-down contract in docs/determinism.md).
"""
from __future__ import annotations

import io
import re
import tokenize
from typing import Dict, List, Set, Tuple

_IGNORE_RE = re.compile(
    r"#\s*detlint:\s*ignore\[([A-Za-z0-9,\s]+)\]\s*(.*)")
_SKIP_FILE_RE = re.compile(r"#\s*detlint:\s*skip-file\b")


class Suppressions:
    def __init__(self) -> None:
        self.by_line: Dict[int, Set[str]] = {}
        self.skip_file = False
        self.malformed: List[Tuple[int, str]] = []   # (line, problem)

    def covers(self, rule: str, extent: Tuple[int, int]) -> bool:
        if self.skip_file:
            return True
        start, end = extent
        for line in range(start, end + 1):
            if rule in self.by_line.get(line, ()):  # noqa: SIM118 — set lookup
                return True
        return False


def parse_suppressions(source: str) -> Suppressions:
    sup = Suppressions()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(tok.start[0], tok.string) for tok in tokens
                    if tok.type == tokenize.COMMENT]
    except (tokenize.TokenError, IndentationError, SyntaxError):
        comments = [(i + 1, line.split("#", 1)[1].strip() and "#" + line.split("#", 1)[1])
                    for i, line in enumerate(source.splitlines()) if "#" in line]
        comments = [(ln, c) for ln, c in comments if c]
    for line, text in comments:
        if _SKIP_FILE_RE.search(text):
            sup.skip_file = True
            continue
        m = _IGNORE_RE.search(text)
        if m is None:
            if "detlint:" in text:
                sup.malformed.append((line, "unrecognized detlint directive"))
            continue
        rules = {r.strip().upper() for r in m.group(1).split(",") if r.strip()}
        reason = m.group(2).strip()
        if not reason:
            sup.malformed.append(
                (line, "suppression without a reason — "
                       "`# detlint: ignore[DETnnn] <why this is acceptable>`"))
            continue
        sup.by_line.setdefault(line, set()).update(rules)
    return sup
