"""Finding record + the rule registry (stable IDs, one-line contracts)."""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Tuple

# Rule registry: id -> (title, contract sentence).  IDs are stable and
# append-only; retired rules keep their number (never reuse).
RULES = {
    "DET001": (
        "unordered-float-accumulation",
        "Order-sensitive accumulation (+=, sum(), math.fsum) fed by iteration "
        "over a set/dict/.keys()/.values()/.items() with no sorted() wrapper; "
        "float addition is not associative, so the result follows "
        "PYTHONHASHSEED.",
    ),
    "DET002": (
        "wall-clock-control-flow",
        "A time.time/perf_counter/monotonic/datetime.now read whose result "
        "reaches a comparison, branch, loop bound, or return — or any bare "
        "wall-clock read inside the strict core, where even metrics-only use "
        "must carry an explicit suppression.",
    ),
    "DET003": (
        "global-rng",
        "Module-level RNG state (random.*, np.random.*) is shared and "
        "seed-order dependent; use an explicitly seeded random.Random / "
        "np.random.Generator / jax.random key instead.",
    ),
    "DET004": (
        "unordered-selection",
        "min/max/sort over an unordered collection resolves ties (or a "
        "key-stable sort resolves equal keys) by hash iteration order; "
        "iterate sorted(...) or make the ordering total.",
    ),
    "DET005": (
        "unordered-iteration-mutates-state",
        "Iteration over a set/dict mutating shared scheduler state "
        "(placement, lane, broker, accumulators) without a sorted() ordering "
        "makes the mutation sequence follow PYTHONHASHSEED.",
    ),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str          # DETnnn
    path: str          # posix-style path as given on the command line
    line: int          # 1-based
    col: int           # 0-based (ast convention)
    message: str
    snippet: str = ""  # stripped source line, for baseline fingerprints

    def sort_key(self) -> Tuple[str, int, int, str]:
        return (self.path, self.line, self.col, self.rule)

    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline file.

        Hashing the stripped source line (not the line number) keeps
        baseline entries stable across unrelated edits above the finding.
        """
        digest = hashlib.sha1(self.snippet.strip().encode()).hexdigest()[:12]
        return f"{self.path}:{self.rule}:{digest}"

    def format_text(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: {self.rule} {self.message}"

    def format_github(self) -> str:
        # '::error' annotation lines render inline on the PR diff
        return (f"::error file={self.path},line={self.line},"
                f"title=detlint {self.rule}::{self.message}")
