"""detlint engine: file discovery, per-module runs, suppressions, baseline.

Everything here is deterministic under any ``PYTHONHASHSEED``: files are
walked in sorted order, findings are sorted by (path, line, col, rule),
and no output is derived from set/dict iteration order.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

from tools.detlint import dataflow as df
from tools.detlint.findings import Finding
from tools.detlint.rules import ModuleChecker, collect_return_kinds
from tools.detlint.suppress import parse_suppressions

# the strict zone: bare wall-clock reads (DET002) are flagged here even
# when the taint never reaches a control-flow sink
DEFAULT_STRICT_PREFIXES = ("src/repro/core", "src/repro/serving")


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]            # unsuppressed, not yet baselined
    suppressed: int                    # inline-ignored findings
    baselined: int = 0                 # grandfathered by the baseline file
    files: int = 0
    errors: List[str] = dataclasses.field(default_factory=list)


def _norm(path: str) -> str:
    rel = os.path.relpath(path)
    return rel.replace(os.sep, "/")


def discover(paths: Sequence[str]) -> Tuple[List[str], List[str]]:
    """Expand files/directories into a sorted list of .py files."""
    out: List[str] = []
    errors: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs.sort()
                if "__pycache__" in dirs:
                    dirs.remove("__pycache__")
                for name in sorted(files):
                    if name.endswith(".py"):
                        out.append(_norm(os.path.join(root, name)))
        elif os.path.isfile(p):
            out.append(_norm(p))
        else:
            errors.append(f"{p}: no such file or directory")
    return sorted(set(out)), errors


def is_strict(path: str, strict_prefixes: Sequence[str]) -> bool:
    norm = path.replace(os.sep, "/")
    return any(norm.startswith(pref.rstrip("/") + "/")
               or norm == pref.rstrip("/") for pref in strict_prefixes)


def lint_source(path: str, source: str, strict: bool = False,
                return_kinds: Optional[Dict[str, str]] = None,
                ) -> Tuple[List[Finding], int, Optional[str]]:
    """Lint one module's source.

    Returns (unsuppressed findings, suppressed count, parse error).
    """
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [], 0, f"{path}:{exc.lineno or 0}: syntax error: {exc.msg}"
    checker = ModuleChecker(path, tree, source.splitlines(), strict,
                            return_kinds=return_kinds)
    raw = checker.run()
    sup = parse_suppressions(source)
    findings: List[Finding] = []
    suppressed = 0
    for f in raw:
        extent = getattr(f, "_extent", (f.line, f.line))
        if sup.covers(f.rule, extent):
            suppressed += 1
        else:
            findings.append(f)
    for line, problem in sup.malformed:
        snippet = (source.splitlines()[line - 1]
                   if line - 1 < len(source.splitlines()) else "")
        findings.append(Finding(rule="DET000", path=path, line=line, col=0,
                                message=problem, snippet=snippet))
    findings.sort(key=Finding.sort_key)
    return findings, suppressed, None


def lint_paths(paths: Sequence[str],
               strict_prefixes: Sequence[str] = DEFAULT_STRICT_PREFIXES,
               ) -> LintResult:
    files, errors = discover(paths)
    sources: List[Tuple[str, str]] = []
    for path in files:
        try:
            with open(path, encoding="utf-8") as fh:
                sources.append((path, fh.read()))
        except OSError as exc:
            errors.append(f"{path}: {exc}")

    # project-wide pre-pass: annotated return kinds from every scanned file
    return_kinds: Dict[str, str] = {}
    for path, source in sources:
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError:
            continue
        for name, kind in collect_return_kinds(tree).items():
            if name in return_kinds:
                return_kinds[name] = df.join(return_kinds[name], kind)
            else:
                return_kinds[name] = kind

    result = LintResult(findings=[], suppressed=0, files=len(sources),
                        errors=errors)
    for path, source in sources:
        strict = is_strict(path, strict_prefixes)
        findings, suppressed, err = lint_source(
            path, source, strict=strict, return_kinds=return_kinds)
        if err is not None:
            result.errors.append(err)
        result.findings.extend(findings)
        result.suppressed += suppressed
    result.findings.sort(key=Finding.sort_key)
    return result


# ---------------------------------------------------------------------------
# baseline

def load_baseline(path: str) -> Optional[Dict[str, int]]:
    """Baseline file -> {fingerprint: allowed multiplicity}."""
    if not os.path.isfile(path):
        return None
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    counts: Dict[str, int] = {}
    for fp in data.get("findings", []):
        counts[fp] = counts.get(fp, 0) + 1
    return counts


def apply_baseline(result: LintResult,
                   baseline: Optional[Dict[str, int]]) -> None:
    """Drop findings the baseline grandfathers (by fingerprint, counted)."""
    if not baseline:
        return
    remaining = dict(baseline)
    kept: List[Finding] = []
    for f in result.findings:
        fp = f.fingerprint()
        if remaining.get(fp, 0) > 0:
            remaining[fp] -= 1
            result.baselined += 1
        else:
            kept.append(f)
    result.findings = kept


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    fps = sorted(f.fingerprint() for f in findings)
    payload = {
        "comment": ("detlint accepted-findings baseline; regenerate with "
                    "`python -m tools.detlint --update-baseline <paths>`. "
                    "The gate target is an empty list — prefer fixing or "
                    "inline-suppressing with a reason."),
        "findings": fps,
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
