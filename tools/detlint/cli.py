"""detlint command line.

    python -m tools.detlint src/repro/core src/repro/serving benchmarks

Exit codes: 0 clean, 1 findings, 2 usage/IO/parse errors.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from tools.detlint.engine import (DEFAULT_STRICT_PREFIXES, apply_baseline,
                                  lint_paths, load_baseline, write_baseline)
from tools.detlint.findings import RULES

_DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m tools.detlint",
        description="AST determinism-and-contract linter for the sim core "
                    "(rules DET001-DET005, docs/determinism.md).")
    p.add_argument("paths", nargs="*", help="files or directories to lint")
    p.add_argument("--format", choices=("text", "github"), default="text",
                   help="finding output format (github = workflow "
                        "::error annotations)")
    p.add_argument("--baseline", default=_DEFAULT_BASELINE,
                   help="accepted-findings baseline file "
                        "(default: tools/detlint/baseline.json)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline file")
    p.add_argument("--update-baseline", action="store_true",
                   help="write current unsuppressed findings to the "
                        "baseline file and exit 0")
    p.add_argument("--strict-prefix", action="append", default=None,
                   metavar="PREFIX",
                   help="path prefix treated as the strict zone for DET002 "
                        "(repeatable; default: src/repro/core, "
                        "src/repro/serving)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table and exit")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = _build_parser().parse_args(argv)

    if args.list_rules:
        for rule in sorted(RULES):
            title, contract = RULES[rule]
            print(f"{rule}  {title}")
            print(f"        {contract}")
        return 0

    if not args.paths:
        print("detlint: no paths given (try: python -m tools.detlint "
              "src/repro/core src/repro/serving benchmarks)", file=sys.stderr)
        return 2

    strict = tuple(args.strict_prefix) if args.strict_prefix else \
        DEFAULT_STRICT_PREFIXES
    result = lint_paths(args.paths, strict_prefixes=strict)

    for err in result.errors:
        print(f"detlint: error: {err}", file=sys.stderr)

    if args.update_baseline:
        write_baseline(args.baseline, result.findings)
        print(f"detlint: baseline written to {args.baseline} "
              f"({len(result.findings)} findings)")
        return 0 if not result.errors else 2

    if not args.no_baseline:
        apply_baseline(result, load_baseline(args.baseline))

    for f in result.findings:
        print(f.format_github() if args.format == "github"
              else f.format_text())

    tail = (f"detlint: {result.files} files, "
            f"{len(result.findings)} finding"
            f"{'' if len(result.findings) == 1 else 's'} "
            f"({result.suppressed} suppressed inline, "
            f"{result.baselined} baselined)")
    print(tail, file=sys.stderr)

    if result.errors:
        return 2
    return 1 if result.findings else 0
