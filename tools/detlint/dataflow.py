"""Lightweight intra-function dataflow for detlint.

Two analyses, both deliberately shallow (statement-order walk, last
writer wins at joins — a lint, not a verifier):

* **collection kinds** — classifies expressions as SET / DICT / ORDERED /
  UNKNOWN so the iteration rules (DET001/4/5) know which loops follow
  hash order.  Sources of truth: literals and comprehensions, builtin
  constructor calls, set-algebra operators, ``.keys()``-family views,
  annotations (``x: Set[int]``), and per-class ``self.attr`` assignment
  joins collected in a pre-pass.
* **wall-clock taint** — marks names derived from ``time.*`` /
  ``datetime.now`` reads so DET002 can flag the control-flow sinks they
  reach (comparisons, branch tests, loop bounds, returns) while leaving
  metrics-only accumulation alone.
"""
from __future__ import annotations

import ast
from typing import Dict, Optional

# ---------------------------------------------------------------------------
# collection kinds

SET = "set"
DICT = "dict"
ORDERED = "ordered"
UNKNOWN = "unknown"

UNORDERED = (SET, DICT)

# annotation / constructor name -> kind
_ANNOTATION_KINDS = {
    "set": SET, "Set": SET, "frozenset": SET, "FrozenSet": SET,
    "AbstractSet": SET, "MutableSet": SET,
    "dict": DICT, "Dict": DICT, "Mapping": DICT, "MutableMapping": DICT,
    "DefaultDict": DICT, "defaultdict": DICT, "Counter": DICT,
    "OrderedDict": DICT, "ChainMap": DICT,
    "list": ORDERED, "List": ORDERED, "tuple": ORDERED, "Tuple": ORDERED,
    "Sequence": ORDERED, "MutableSequence": ORDERED, "Deque": ORDERED,
    "deque": ORDERED, "str": ORDERED,
}

_CONSTRUCTOR_KINDS = {
    "set": SET, "frozenset": SET,
    "dict": DICT, "defaultdict": DICT, "Counter": DICT, "OrderedDict": DICT,
    "sorted": ORDERED, "range": ORDERED, "str": ORDERED, "repr": ORDERED,
}

# builtins that materialize / re-wrap their input's iteration order
_ORDER_PRESERVING = {"list", "tuple", "iter", "reversed", "enumerate"}

_DICT_VIEW_METHODS = {"keys", "values", "items"}

# set methods that return a new set
_SET_ALGEBRA_METHODS = {"union", "intersection", "difference",
                        "symmetric_difference", "copy"}


def join(a: str, b: str) -> str:
    """Kind join for merge points: agree -> that kind; any unordered wins
    over UNKNOWN/ORDERED (conservative for a determinism lint)."""
    if a == b:
        return a
    for k in (SET, DICT):
        if k in (a, b):
            return k
    return UNKNOWN


def annotation_kind(node: Optional[ast.expr]) -> str:
    """Kind implied by a type annotation expression, if recognizable."""
    if node is None:
        return UNKNOWN
    if isinstance(node, ast.Subscript):            # Set[int], Dict[str, float]
        base = node.value
    else:
        base = node
    if isinstance(base, ast.Attribute):            # typing.Set, t.Dict
        name = base.attr
    elif isinstance(base, ast.Name):
        name = base.id
    elif isinstance(base, ast.Constant) and isinstance(base.value, str):
        try:                                       # string annotation
            return annotation_kind(ast.parse(base.value, mode="eval").body)
        except SyntaxError:
            return UNKNOWN
    else:
        return UNKNOWN
    if isinstance(node, ast.Subscript) and name == "Optional":
        if isinstance(node.slice, ast.expr):
            return annotation_kind(node.slice)
    return _ANNOTATION_KINDS.get(name, UNKNOWN)


class KindEnv:
    """Name -> kind map for one function scope (plus the class-attribute
    env for ``self.attr`` loads, shared across the class's methods)."""

    def __init__(self, attrs: Optional[Dict[str, str]] = None,
                 self_name: Optional[str] = None,
                 fallback_returns: Optional[Dict[str, str]] = None):
        self.names: Dict[str, str] = {}
        self.attrs = attrs or {}
        self.self_name = self_name
        # project-wide {function name -> annotated return kind} fallback so
        # `for u in engine.idle_units(t)` classifies across module boundaries
        self.fallback_returns = fallback_returns or {}

    def copy_names(self) -> Dict[str, str]:
        return dict(self.names)

    # -- classification ------------------------------------------------------

    def kind_of(self, node: ast.expr) -> str:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return SET
        if isinstance(node, (ast.Dict, ast.DictComp)):
            return DICT
        if isinstance(node, (ast.List, ast.Tuple, ast.JoinedStr, ast.Constant)):
            return ORDERED
        if isinstance(node, (ast.ListComp, ast.GeneratorExp)):
            # a list built by iterating a set inherits the hash order
            return self.kind_of(node.generators[0].iter)
        if isinstance(node, ast.Name):
            return self.names.get(node.id, UNKNOWN)
        if isinstance(node, ast.Attribute):
            if (self.self_name is not None
                    and isinstance(node.value, ast.Name)
                    and node.value.id == self.self_name):
                return self.attrs.get(node.attr, UNKNOWN)
            return UNKNOWN
        if isinstance(node, ast.BinOp):
            if isinstance(node.op, (ast.BitOr, ast.BitAnd, ast.BitXor, ast.Sub)):
                left, right = self.kind_of(node.left), self.kind_of(node.right)
                if SET in (left, right):
                    return SET
                if isinstance(node.op, ast.BitOr) and DICT in (left, right):
                    return DICT        # PEP 584 dict merge
            return UNKNOWN
        if isinstance(node, ast.IfExp):
            return join(self.kind_of(node.body), self.kind_of(node.orelse))
        if isinstance(node, ast.Call):
            return self._call_kind(node)
        if isinstance(node, ast.Starred):
            return self.kind_of(node.value)
        if isinstance(node, ast.Await):
            return self.kind_of(node.value)
        return UNKNOWN

    def _call_kind(self, node: ast.Call) -> str:
        func = node.func
        if isinstance(func, ast.Name):
            name = func.id
            if name in _CONSTRUCTOR_KINDS:
                # set(xs) is a set no matter what xs was; sorted(s) launders
                return _CONSTRUCTOR_KINDS[name]
            if name in _ORDER_PRESERVING:
                if not node.args:
                    return ORDERED
                return self.kind_of(node.args[0])
            if name in ("map", "filter", "zip"):
                kinds = [self.kind_of(a) for a in node.args]
                out = ORDERED
                for k in kinds:
                    out = join(out, k) if k in UNORDERED else out
                return out
            return self.fallback_returns.get(name, UNKNOWN)
        if isinstance(func, ast.Attribute):
            recv_kind = self.kind_of(func.value)
            if func.attr in _DICT_VIEW_METHODS:
                # contract: dict views are unordered unless the dict's
                # insertion order is itself proven — sorted() to be safe
                return DICT
            if func.attr in _SET_ALGEBRA_METHODS and recv_kind == SET:
                return SET
            if func.attr == "copy":
                return recv_kind
            if func.attr in ("most_common",):      # Counter.most_common sorts
                return ORDERED
            if func.attr == "chain":               # itertools.chain
                out = ORDERED
                for a in node.args:
                    k = self.kind_of(a)
                    out = join(out, k) if k in UNORDERED else out
                return out
            return self.fallback_returns.get(func.attr, UNKNOWN)
        return UNKNOWN

    # -- updates -------------------------------------------------------------

    def assign(self, target: ast.expr, kind: str) -> None:
        if isinstance(target, ast.Name):
            self.names[target.id] = kind
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self.assign(elt, UNKNOWN)
        # attribute / subscript stores don't update the flow-insensitive
        # class env (that comes from the class pre-pass)


class ClassAttrCollector(ast.NodeVisitor):
    """Pre-pass over a ClassDef: join every ``self.attr = <expr>`` (and
    class-level annotation) into an attr -> kind map for the methods."""

    def __init__(self) -> None:
        self.attrs: Dict[str, str] = {}
        self._env = KindEnv()   # empty name env: literals/constructors only

    def collect(self, node: ast.ClassDef) -> Dict[str, str]:
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
                self._note(stmt.target.id, annotation_kind(stmt.annotation))
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self_name = stmt.args.args[0].arg if stmt.args.args else None
                if self_name:
                    for sub in ast.walk(stmt):
                        self._visit_store(sub, self_name)
        return self.attrs

    def _visit_store(self, node: ast.AST, self_name: str) -> None:
        if isinstance(node, ast.Assign):
            kind = self._env.kind_of(node.value)
            for tgt in node.targets:
                self._note_self_attr(tgt, self_name, kind)
        elif isinstance(node, ast.AnnAssign):
            self._note_self_attr(node.target, self_name,
                                 annotation_kind(node.annotation))

    def _note_self_attr(self, tgt: ast.expr, self_name: str, kind: str) -> None:
        if (isinstance(tgt, ast.Attribute) and isinstance(tgt.value, ast.Name)
                and tgt.value.id == self_name):
            self._note(tgt.attr, kind)

    def _note(self, attr: str, kind: str) -> None:
        if attr in self.attrs:
            self.attrs[attr] = join(self.attrs[attr], kind)
        else:
            self.attrs[attr] = kind


# ---------------------------------------------------------------------------
# wall-clock taint

# time-module functions whose return value is wall/CPU clock state
WALL_CLOCK_TIME_FUNCS = {
    "time", "time_ns", "perf_counter", "perf_counter_ns", "monotonic",
    "monotonic_ns", "process_time", "process_time_ns", "thread_time",
    "thread_time_ns", "clock_gettime", "clock_gettime_ns",
}
# datetime constructors reading the clock
WALL_CLOCK_DT_FUNCS = {"now", "utcnow", "today"}


class TaintEnv:
    """Set of local names holding wall-clock-derived values."""

    def __init__(self, is_wall_call) -> None:
        self.tainted: set = set()
        self._is_wall_call = is_wall_call   # Call -> bool (import-aware)

    def is_tainted(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Call):
            if self._is_wall_call(node):
                return True
            # min(cap, elapsed) etc. propagate through builtins we can name
            if isinstance(node.func, ast.Name) and node.func.id in (
                    "min", "max", "abs", "round", "int", "float"):
                return any(self.is_tainted(a) for a in node.args)
            return False
        if isinstance(node, ast.BinOp):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, ast.Compare):
            return self.is_tainted(node.left) or any(
                self.is_tainted(c) for c in node.comparators)
        return False

    def assign(self, target: ast.expr, value: ast.expr) -> None:
        if isinstance(target, ast.Name):
            if self.is_tainted(value):
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            # t0, t1 = perf_counter(), perf_counter() — taint all elements
            tainted = self.is_tainted(value)
            for elt in target.elts:
                if isinstance(elt, ast.Name):
                    if tainted:
                        self.tainted.add(elt.id)
                    else:
                        self.tainted.discard(elt.id)
