"""The detlint rule checker: one ordered AST walk per module.

Rules (tools/detlint/findings.py has the registry; docs/determinism.md
the contract rationale):

* DET001 — order-sensitive accumulation (``+=``/``x = x + v`` on
  non-counter values, ``sum()``, ``math.fsum``) fed by iteration over a
  set or dict view without a ``sorted()`` wrapper.
* DET002 — wall-clock reads.  In the strict zone (``src/repro/core``,
  ``src/repro/serving``) every read is flagged — metrics-only use must
  carry the canonical suppression.  Everywhere, a wall-clock-derived
  value reaching a comparison, branch test, loop bound, or (strict zone)
  return is flagged at the sink.
* DET003 — module-level RNG state (``random.*``, ``np.random.*``).
* DET004 — hash-order tie-breaking: ``min``/``max`` over an unordered
  collection, or a stable ``sorted(..., key=...)``/``.sort(key=...)``
  whose equal-key runs preserve hash order.  ``sorted(u)`` with no key
  is the sanctioned fix and is never flagged.
* DET005 — iteration over a **set** (hash-ordered) that mutates shared
  state: outer-name rebinding, attribute/subscript stores, list appends,
  dict insertions, yields.  Dict iteration is exempt here by a
  compositional argument: dicts are insertion-ordered, and DET005 itself
  guarantees insertions never happen in hash order — so a clean tree
  keeps every dict deterministic by construction (see docs).

Set-content mutations (``seen.add(x)`` etc.) inside set loops are *not*
flagged: set content is order-free, only its iteration is hazardous, and
that iteration is checked where it happens.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from tools.detlint import dataflow as df
from tools.detlint.findings import Finding

# methods that imprint iteration order onto shared ordered state
_ORDER_SENSITIVE_METHODS = {
    "append", "appendleft", "extend", "insert", "setdefault", "update",
    "push", "put", "put_nowait", "heappush", "__setitem__",
}
# content mutations that are order-free when the receiver is a set
_SET_SAFE_METHODS = {
    "add", "discard", "remove", "clear", "update", "pop",
    "difference_update", "intersection_update",
    "symmetric_difference_update",
}
# numpy constructors that are fine (explicitly seeded / bit generators)
_NP_RANDOM_OK = {"Generator", "PCG64", "Philox", "SFC64", "MT19937"}
_NP_RANDOM_OK_WITH_ARGS = {"default_rng", "RandomState"}


class _Scope:
    """Per-function analysis state: collection kinds + wall-clock taint."""

    def __init__(self, kinds: df.KindEnv, taint: df.TaintEnv):
        self.kinds = kinds
        self.taint = taint


class ModuleChecker:
    """Runs every DET rule over one parsed module, in source order."""

    def __init__(self, path: str, tree: ast.Module, source_lines: List[str],
                 strict: bool,
                 return_kinds: Optional[Dict[str, str]] = None):
        self.path = path
        self.tree = tree
        self.lines = source_lines
        self.strict = strict
        # cross-module fallback: function/method name -> annotated return kind
        self.return_kinds = return_kinds or {}
        self.findings: List[Finding] = []
        # import-alias maps (module-wide; nested imports included)
        self.time_mods: Set[str] = set()
        self.wall_direct: Set[str] = set()
        self.dt_mods: Set[str] = set()
        self.dt_classes: Set[str] = set()
        self.random_mods: Set[str] = set()
        self.random_direct: Set[str] = set()
        self.numpy_mods: Set[str] = set()
        self.np_random_mods: Set[str] = set()
        self.fsum_direct: Set[str] = set()
        self.math_mods: Set[str] = set()

    # -- entry ---------------------------------------------------------------

    def run(self) -> List[Finding]:
        self._collect_imports()
        module_scope = _Scope(
            df.KindEnv(fallback_returns=self.return_kinds),
            df.TaintEnv(self._is_wall_call))
        self._exec_block(self.tree.body, module_scope)
        return self.findings

    # -- imports -------------------------------------------------------------

    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    bound = alias.asname or alias.name.split(".")[0]
                    if alias.name == "time":
                        self.time_mods.add(bound)
                    elif alias.name == "datetime":
                        self.dt_mods.add(bound)
                    elif alias.name == "random":
                        self.random_mods.add(bound)
                    elif alias.name == "numpy":
                        self.numpy_mods.add(bound)
                    elif alias.name == "numpy.random":
                        self.np_random_mods.add(alias.asname or "numpy")
                    elif alias.name == "math":
                        self.math_mods.add(bound)
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                for alias in node.names:
                    bound = alias.asname or alias.name
                    if mod == "time" and alias.name in df.WALL_CLOCK_TIME_FUNCS:
                        self.wall_direct.add(bound)
                    elif mod == "datetime" and alias.name == "datetime":
                        self.dt_classes.add(bound)
                    elif mod == "random" and alias.name not in (
                            "Random", "SystemRandom"):
                        self.random_direct.add(bound)
                    elif mod == "numpy" and alias.name == "random":
                        self.np_random_mods.add(bound)
                    elif mod == "math" and alias.name == "fsum":
                        self.fsum_direct.add(bound)

    # -- findings ------------------------------------------------------------

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        end = getattr(node, "end_lineno", line) or line
        snippet = self.lines[line - 1] if line - 1 < len(self.lines) else ""
        f = Finding(rule=rule, path=self.path, line=line, col=col,
                    message=message, snippet=snippet)
        # suppression comments may sit on any line of the flagged construct's
        # header (multi-line calls / for-headers); record the extent
        object.__setattr__(f, "_extent", (line, end))
        self.findings.append(f)

    # -- statement executor --------------------------------------------------

    def _exec_block(self, body: List[ast.stmt], scope: _Scope) -> None:
        for stmt in body:
            self._exec_stmt(stmt, scope)

    def _exec_stmt(self, stmt: ast.stmt, scope: _Scope) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._check_function(stmt, attrs=scope.kinds.attrs)
            return
        if isinstance(stmt, ast.ClassDef):
            attrs = df.ClassAttrCollector().collect(stmt)
            class_scope = _Scope(
                df.KindEnv(attrs=attrs, fallback_returns=self.return_kinds),
                df.TaintEnv(self._is_wall_call))
            self._exec_block(stmt.body, class_scope)
            return

        if isinstance(stmt, ast.Assign):
            self._scan_expr(stmt.value, scope)
            kind = scope.kinds.kind_of(stmt.value)
            for tgt in stmt.targets:
                self._scan_store_target(tgt, scope)
                scope.kinds.assign(tgt, kind)
                scope.taint.assign(tgt, stmt.value)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._scan_expr(stmt.value, scope)
                kind = scope.kinds.kind_of(stmt.value)
                if kind == df.UNKNOWN:
                    kind = df.annotation_kind(stmt.annotation)
                scope.kinds.assign(stmt.target, kind)
                scope.taint.assign(stmt.target, stmt.value)
            else:
                scope.kinds.assign(stmt.target,
                                   df.annotation_kind(stmt.annotation))
        elif isinstance(stmt, ast.AugAssign):
            self._scan_expr(stmt.value, scope)
            if (isinstance(stmt.target, ast.Name)
                    and scope.taint.is_tainted(stmt.value)):
                scope.taint.tainted.add(stmt.target.id)
            # attribute += wall-clock is the sanctioned metrics pattern:
            # no sink, no taint tracking through attributes
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self._scan_expr(stmt.value, scope)
                if self.strict and scope.taint.is_tainted(stmt.value):
                    self._emit("DET002", stmt,
                               "wall-clock-derived value returned from a "
                               "strict-core function; callers may branch on "
                               "it — return a deterministic quantity (node "
                               "budget, event count) instead")
        elif isinstance(stmt, ast.If):
            self._scan_expr(stmt.test, scope)
            self._check_truthiness_sink(stmt.test, scope, "branch test")
            self._exec_block(stmt.body, scope)
            self._exec_block(stmt.orelse, scope)
        elif isinstance(stmt, ast.While):
            self._scan_expr(stmt.test, scope)
            self._check_truthiness_sink(stmt.test, scope, "while condition")
            self._exec_block(stmt.body, scope)
            self._exec_block(stmt.orelse, scope)
        elif isinstance(stmt, ast.For):
            self._exec_for(stmt, scope)
        elif isinstance(stmt, ast.AsyncFor):
            self._scan_expr(stmt.iter, scope)
            self._exec_block(stmt.body, scope)
            self._exec_block(stmt.orelse, scope)
        elif isinstance(stmt, ast.With) or isinstance(stmt, ast.AsyncWith):
            for item in stmt.items:
                self._scan_expr(item.context_expr, scope)
            self._exec_block(stmt.body, scope)
        elif isinstance(stmt, ast.Try):
            self._exec_block(stmt.body, scope)
            for handler in stmt.handlers:
                self._exec_block(handler.body, scope)
            self._exec_block(stmt.orelse, scope)
            self._exec_block(stmt.finalbody, scope)
        elif isinstance(stmt, (ast.Expr, ast.Assert, ast.Raise, ast.Delete)):
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._scan_expr(child, scope)
        # Pass/Break/Continue/Import/Global/Nonlocal: nothing to do

    def _scan_store_target(self, tgt: ast.expr, scope: _Scope) -> None:
        """Subscript/attribute store targets contain load expressions too."""
        if isinstance(tgt, ast.Subscript):
            self._scan_expr(tgt.value, scope)
            self._scan_expr(tgt.slice, scope)
        elif isinstance(tgt, ast.Attribute):
            self._scan_expr(tgt.value, scope)

    # -- functions -----------------------------------------------------------

    def _check_function(self, node, attrs: Dict[str, str]) -> None:
        kinds = df.KindEnv(attrs=attrs,
                           self_name=(node.args.args[0].arg
                                      if node.args.args else None),
                           fallback_returns=self.return_kinds)
        all_args = (node.args.posonlyargs + node.args.args
                    + node.args.kwonlyargs)
        for arg in all_args:
            kinds.names[arg.arg] = df.annotation_kind(arg.annotation)
        scope = _Scope(kinds, df.TaintEnv(self._is_wall_call))
        self._exec_block(node.body, scope)

    # -- the For rules (DET001 / DET005) -------------------------------------

    def _exec_for(self, node: ast.For, scope: _Scope) -> None:
        self._scan_expr(node.iter, scope)
        self._check_range_bound_sink(node.iter, scope)
        iter_kind = scope.kinds.kind_of(node.iter)
        scope.kinds.assign(node.target, df.UNKNOWN)
        if iter_kind in df.UNORDERED:
            self._check_unordered_loop(node, scope, iter_kind)
        self._exec_block(node.body, scope)
        self._exec_block(node.orelse, scope)

    def _check_unordered_loop(self, node: ast.For, scope: _Scope,
                              iter_kind: str) -> None:
        what = ("a set" if iter_kind == df.SET else "a dict view")
        acc = self._find_accumulation(node, scope)
        if acc is not None:
            self._emit("DET001", node,
                       f"iteration over {what} feeds order-sensitive "
                       f"accumulation at line {acc.lineno}; wrap the "
                       f"iterable in sorted(...)")
            return
        # DET005 applies to hash-ordered sets only; dicts are
        # insertion-ordered, and a DET005-clean tree never inserts in hash
        # order, so dict iteration is deterministic by construction
        if iter_kind != df.SET:
            return
        mut = self._find_mutation(node, scope)
        if mut is not None:
            self._emit("DET005", node,
                       f"iteration over a set mutates shared state at line "
                       f"{mut.lineno} ({self._describe_mutation(mut)}); "
                       f"wrap the iterable in sorted(...)")

    def _loop_target_names(self, target: ast.expr) -> Set[str]:
        names: Set[str] = set()
        for sub in ast.walk(target):
            if isinstance(sub, ast.Name):
                names.add(sub.id)
        return names

    def _ordered_body_stmts(self, node: ast.For):
        """Loop-body statements in source order, skipping nested defs."""
        stack = list(reversed(node.body))
        while stack:
            stmt = stack.pop()
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            yield stmt
            children: List[ast.stmt] = []
            for field in ("body", "orelse", "finalbody"):
                children.extend(getattr(stmt, field, []) or [])
            for handler in getattr(stmt, "handlers", []) or []:
                children.extend(handler.body)
            stack.extend(reversed(children))

    @staticmethod
    def _is_counter_rhs(value: ast.expr) -> bool:
        """+= with an int-literal / len() RHS is exact (no rounding order)."""
        if isinstance(value, ast.Constant) and isinstance(value.value, int):
            return True
        if (isinstance(value, ast.Call) and isinstance(value.func, ast.Name)
                and value.func.id == "len"):
            return True
        return False

    def _unique_key_names(self, node: ast.For) -> Set[str]:
        """Loop-target names guaranteed unique per iteration: the single
        target when iterating a set / dict / .keys(), the first tuple
        element for .items().  (.values() guarantees nothing.)"""
        it = node.iter
        if (isinstance(it, ast.Call) and isinstance(it.func, ast.Attribute)
                and it.func.attr in ("values",)):
            return set()
        if (isinstance(it, ast.Call) and isinstance(it.func, ast.Attribute)
                and it.func.attr == "items"):
            if (isinstance(node.target, (ast.Tuple, ast.List))
                    and node.target.elts
                    and isinstance(node.target.elts[0], ast.Name)):
                return {node.target.elts[0].id}
            return set()
        if isinstance(node.target, ast.Name):
            return {node.target.id}
        return set()

    def _find_accumulation(self, node: ast.For,
                           scope: _Scope) -> Optional[ast.stmt]:
        """First order-sensitive accumulation statement in the loop body."""
        unique_keys = self._unique_key_names(node)
        reset_names: Set[str] = set()   # plain-assigned in body before use
        for stmt in self._ordered_body_stmts(node):
            if isinstance(stmt, ast.Assign):
                rhs_names = {n.id for n in ast.walk(stmt.value)
                             if isinstance(n, ast.Name)}
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name):
                        # x = x + v rebinding is accumulation, not a reset
                        if tgt.id in rhs_names and not self._is_counter_rhs(
                                stmt.value):
                            if tgt.id not in reset_names:
                                return stmt
                        else:
                            reset_names.add(tgt.id)
            elif isinstance(stmt, ast.AugAssign):
                if not isinstance(stmt.op, (ast.Add, ast.Sub, ast.Mult,
                                            ast.Div)):
                    continue
                if self._is_counter_rhs(stmt.value):
                    continue
                if (isinstance(stmt.target, ast.Name)
                        and stmt.target.id in reset_names):
                    continue   # re-initialized every iteration: order-free
                if (isinstance(stmt.target, ast.Subscript)
                        and isinstance(stmt.target.slice, ast.Name)
                        and stmt.target.slice.id in unique_keys):
                    # d[k] += v keyed by a per-iteration-unique loop var:
                    # every iteration touches its own slot, no cross-term
                    # float interaction — order-free
                    continue
                return stmt
        return None

    def _find_mutation(self, node: ast.For,
                       scope: _Scope) -> Optional[ast.stmt]:
        """First statement imprinting iteration order on shared state."""
        loop_locals = self._loop_target_names(node.target)
        outer_names = set(scope.kinds.names)
        for stmt in self._ordered_body_stmts(node):
            if isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    root = self._root_name(tgt)
                    if isinstance(tgt, (ast.Attribute, ast.Subscript)):
                        if root is not None and root in loop_locals:
                            continue      # per-element write via the loop var
                        return stmt
                    if isinstance(tgt, ast.Name):
                        if tgt.id in outer_names:
                            return stmt   # rebinding an outer name (argmax-by-hand)
                        loop_locals.add(tgt.id)
            elif isinstance(stmt, ast.AugAssign):
                root = self._root_name(stmt.target)
                if root is not None and root in loop_locals:
                    continue
                if self._is_counter_rhs(stmt.value):
                    continue
                tkind = (scope.kinds.kind_of(stmt.target)
                         if isinstance(stmt.target, ast.Name) else df.UNKNOWN)
                if (isinstance(stmt.op, (ast.BitOr, ast.BitAnd, ast.BitXor))
                        and tkind == df.SET):
                    continue              # set-content accumulation: order-free
                return stmt
            elif isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
                call = stmt.value
                func = call.func
                if isinstance(func, ast.Attribute):
                    method = func.attr
                    root = self._root_name(func.value)
                    if root is not None and root in loop_locals:
                        continue
                    recv_kind = scope.kinds.kind_of(func.value)
                    if recv_kind == df.SET and method in _SET_SAFE_METHODS:
                        continue
                    if method in _ORDER_SENSITIVE_METHODS:
                        return stmt
                elif isinstance(func, ast.Name):
                    if func.id == "heappush":
                        return stmt
            elif isinstance(stmt, ast.Expr) and isinstance(
                    stmt.value, (ast.Yield, ast.YieldFrom)):
                return stmt
        return None

    @staticmethod
    def _root_name(node: ast.expr) -> Optional[str]:
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            node = node.value
        if isinstance(node, ast.Name):
            return node.id
        return None

    @staticmethod
    def _describe_mutation(stmt: ast.stmt) -> str:
        if isinstance(stmt, ast.Assign):
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Subscript):
                return "subscript store"
            if isinstance(tgt, ast.Attribute):
                return "attribute store"
            return "outer-name rebinding"
        if isinstance(stmt, ast.AugAssign):
            return "augmented assignment"
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            func = stmt.value.func
            name = func.attr if isinstance(func, ast.Attribute) else (
                func.id if isinstance(func, ast.Name) else "call")
            return f".{name}() on shared state"
        return "yield"

    # -- expression scanning (DET001-sum / DET002 / DET003 / DET004) ---------

    def _scan_expr(self, expr: ast.expr, scope: _Scope) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                self._check_call(node, scope)
            elif isinstance(node, ast.Compare):
                if scope.taint.is_tainted(node):
                    self._emit("DET002", node,
                               "wall-clock-derived value in a comparison; "
                               "control flow must not depend on machine "
                               "load — use a node/event budget")

    def _check_truthiness_sink(self, test: ast.expr, scope: _Scope,
                               where: str) -> None:
        # bare-name truthiness (`if elapsed:`); Compare tests are flagged by
        # the Compare scan, don't double-report
        if not isinstance(test, ast.Compare) and scope.taint.is_tainted(test):
            self._emit("DET002", test,
                       f"wall-clock-derived value as a {where}")

    def _check_range_bound_sink(self, iter_expr: ast.expr,
                                scope: _Scope) -> None:
        if (isinstance(iter_expr, ast.Call)
                and isinstance(iter_expr.func, ast.Name)
                and iter_expr.func.id == "range"
                and any(scope.taint.is_tainted(a) for a in iter_expr.args)):
            self._emit("DET002", iter_expr,
                       "wall-clock-derived loop bound")

    def _check_call(self, node: ast.Call, scope: _Scope) -> None:
        # DET002: bare wall-clock reads in the strict zone
        if self.strict and self._is_wall_call(node):
            self._emit("DET002", node,
                       "wall-clock read in the deterministic core; even "
                       "metrics-only use needs an explicit "
                       "`# detlint: ignore[DET002] <reason>`")
        # DET003: module-level RNG state
        rng = self._global_rng_call(node)
        if rng is not None:
            self._emit("DET003", node,
                       f"global RNG state via {rng}; use an explicitly "
                       f"seeded random.Random / np.random.default_rng(seed) "
                       f"instance")
        # DET001: sum()/math.fsum over an unordered iterable
        if self._is_sum_call(node):
            arg_kind = (scope.kinds.kind_of(node.args[0])
                        if node.args else df.UNKNOWN)
            if arg_kind in df.UNORDERED:
                what = "a set" if arg_kind == df.SET else "a dict view"
                self._emit("DET001", node,
                           f"sum over {what}: float addition is not "
                           f"associative — sum(sorted(...)) or prove the "
                           f"operands exact")
        # DET004: hash-order tie-breaking in selection / key-stable sorts
        self._check_selection(node, scope)

    def _is_sum_call(self, node: ast.Call) -> bool:
        func = node.func
        if isinstance(func, ast.Name):
            return func.id == "sum" or func.id in self.fsum_direct
        if isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name):
            return func.value.id in self.math_mods and func.attr == "fsum"
        return False

    @staticmethod
    def _key_is_total(node: ast.Call) -> bool:
        """True when the ``key=lambda x: (...)`` tuple embeds the element
        identity (``x`` itself or ``x[0]``, the unique dict key for .items())
        — ties are then impossible, the order is total."""
        for kw in node.keywords:
            if kw.arg != "key" or not isinstance(kw.value, ast.Lambda):
                continue
            lam = kw.value
            if not lam.args.args:
                continue
            param = lam.args.args[0].arg
            parts = (lam.body.elts if isinstance(lam.body, ast.Tuple)
                     else [lam.body])
            for part in parts:
                if isinstance(part, ast.Name) and part.id == param:
                    return True
                if (isinstance(part, ast.Subscript)
                        and isinstance(part.value, ast.Name)
                        and part.value.id == param
                        and isinstance(part.slice, ast.Constant)
                        and part.slice.value == 0):
                    return True
        return False

    def _check_selection(self, node: ast.Call, scope: _Scope) -> None:
        func = node.func
        has_key = any(kw.arg == "key" for kw in node.keywords)
        if has_key and self._key_is_total(node):
            return
        if isinstance(func, ast.Name) and func.id in ("min", "max"):
            if len(node.args) != 1:      # min(a, b) scalar form
                return
            kind = scope.kinds.kind_of(node.args[0])
            if kind in df.UNORDERED:
                what = "a set" if kind == df.SET else "a dict view"
                detail = ("equal-key ties resolve by hash iteration order"
                          if has_key else
                          "ties between equal-comparing elements resolve by "
                          "hash iteration order")
                self._emit("DET004", node,
                           f"{func.id}() over {what}: {detail}; iterate "
                           f"sorted(...) or make the key total")
        elif isinstance(func, ast.Name) and func.id == "sorted":
            # sorted(u) with no key totally orders by value — sanctioned fix
            if has_key and node.args:
                kind = scope.kinds.kind_of(node.args[0])
                if kind in df.UNORDERED:
                    what = "a set" if kind == df.SET else "a dict view"
                    self._emit("DET004", node,
                               f"key-stable sorted() over {what}: equal-key "
                               f"runs preserve hash iteration order; extend "
                               f"the key to a total order")
        elif (isinstance(func, ast.Attribute) and func.attr == "sort"
              and has_key):
            kind = scope.kinds.kind_of(func.value)
            if kind in df.UNORDERED:
                self._emit("DET004", node,
                           "key-stable .sort() over an unordered-sourced "
                           "list: equal-key runs preserve hash iteration "
                           "order; extend the key to a total order")

    # -- wall-clock classification -------------------------------------------

    def _is_wall_call(self, node: ast.Call) -> bool:
        func = node.func
        if isinstance(func, ast.Name):
            return func.id in self.wall_direct
        if not isinstance(func, ast.Attribute):
            return False
        value = func.value
        if isinstance(value, ast.Name):
            if value.id in self.time_mods and func.attr in df.WALL_CLOCK_TIME_FUNCS:
                return True
            if value.id in self.dt_classes and func.attr in df.WALL_CLOCK_DT_FUNCS:
                return True
        if (isinstance(value, ast.Attribute) and value.attr == "datetime"
                and isinstance(value.value, ast.Name)
                and value.value.id in self.dt_mods
                and func.attr in df.WALL_CLOCK_DT_FUNCS):
            return True
        return False

    # -- RNG classification ---------------------------------------------------

    def _global_rng_call(self, node: ast.Call) -> Optional[str]:
        func = node.func
        if isinstance(func, ast.Name) and func.id in self.random_direct:
            return f"random.{func.id}"
        if not isinstance(func, ast.Attribute):
            return None
        value = func.value
        if (isinstance(value, ast.Name) and value.id in self.random_mods
                and func.attr not in ("Random", "SystemRandom")):
            return f"random.{func.attr}"
        is_np_random = (
            (isinstance(value, ast.Attribute) and value.attr == "random"
             and isinstance(value.value, ast.Name)
             and value.value.id in self.numpy_mods)
            or (isinstance(value, ast.Name)
                and value.id in self.np_random_mods))
        if is_np_random:
            fn = func.attr
            if fn in _NP_RANDOM_OK:
                return None
            if fn in _NP_RANDOM_OK_WITH_ARGS and node.args:
                return None
            return f"np.random.{fn}"
        return None


def collect_return_kinds(tree: ast.Module) -> Dict[str, str]:
    """Project-wide pre-pass: function name -> annotated return kind.

    Used as a cross-module fallback so ``for u in engine.idle_units(t):``
    classifies when ``idle_units`` is annotated ``-> Set[int]`` anywhere in
    the scanned tree.  Name collisions join conservatively.
    """
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            kind = df.annotation_kind(node.returns)
            if node.name in out:
                out[node.name] = df.join(out[node.name], kind)
            else:
                out[node.name] = kind
    return out
