import sys

from tools.detlint.cli import main

sys.exit(main())
