"""Workload traces + orchestrator alpha-mode ablation."""
import statistics

import pytest

import repro.configs as C
from repro.core.orchestrator import Orchestrator
from repro.core.profiler import Profiler
from repro.core import workloads


@pytest.fixture(scope="module")
def prof():
    return Profiler(C.get("flux"))


def test_steady_rates_and_mixes(prof):
    tr = workloads.steady_trace("flux", "heavy", 600.0, prof, seed=1)
    rate = len(tr) / 600.0
    assert abs(rate - workloads.RATES["flux"]) < 0.3
    # heavy mix skews to high resolutions (Table 5)
    share_hi = sum(r.resolution >= 3072 for r in tr) / len(tr)
    assert share_hi > 0.35
    # arrivals sorted, deadlines = 2.5x optimal (AlpaServe convention)
    for r in tr[:50]:
        assert abs((r.deadline - r.arrival) / prof.pipeline_time(r) - 2.5) < 1e-6


def test_dynamic_trace_shifts_mix(prof):
    tr = workloads.dynamic_trace("flux", 600.0, prof, seed=2)
    span = 600.0 / len(workloads.DYNAMIC_PATTERN)
    first = [r for r in tr if r.arrival < span]
    heavy_span_idx = 2  # pattern[2] is 70% heavy
    heavy = [r for r in tr if heavy_span_idx * span <= r.arrival
             < (heavy_span_idx + 1) * span]
    mean_res = lambda rs: statistics.mean(r.resolution for r in rs)
    assert mean_res(heavy) > mean_res(first)


def test_proprietary_trace_tidal(prof):
    tr = workloads.proprietary_trace("flux", 600.0, prof, seed=3)
    buckets = [0] * 10
    for r in tr:
        buckets[min(9, int(r.arrival / 60))] += 1
    assert max(buckets) > 2 * (min(buckets) + 1)   # pronounced tide


def test_alpha_mode_demand_vs_count(prof):
    """Demand weighting provisions more D-capacity for heavy-skewed mixes
    than count weighting (the beyond-paper orchestrator refinement)."""
    tr = workloads.steady_trace("flux", "heavy", 300.0, prof, seed=4)
    demand = Orchestrator(prof, 128, alpha_mode="demand").generate(tr)
    count = Orchestrator(prof, 128, alpha_mode="count").generate(tr)
    heavy_cap = lambda plan: sum(
        n for t, n in plan.type_histogram().items() if t in ("DC", "D"))
    assert heavy_cap(demand) >= heavy_cap(count)
    # both remain valid full-coverage plans
    for plan in (demand, count):
        for s in "EDC":
            assert plan.units_with(s)


# -- diurnal / phase-shift generators (predictive re-partitioning) -------------

def test_diurnal_phases_square_alternates_anti_phase():
    phases = workloads.diurnal_phases(n_periods=3, spans_per_period=2,
                                      amp=0.8)
    assert len(phases) == 6
    assert phases[-1][0] == pytest.approx(1.0)
    # end fractions strictly increase, equal spans
    fracs = [f for f, _ in phases]
    assert fracs == sorted(fracs)
    for i, (_, mults) in enumerate(phases):
        lead, anti = mults["sd3"], mults["cogvideox"]
        # anti-phase: multipliers mirror around 1.0
        assert lead + anti == pytest.approx(2.0)
        # square: periods start in the lead pipeline's high phase
        assert (lead > 1.0) == (i % 2 == 0)
        assert lead in (pytest.approx(1.8), pytest.approx(0.2))


def test_diurnal_phases_sine_is_smooth():
    phases = workloads.diurnal_phases(n_periods=1, spans_per_period=8,
                                      amp=0.5, shape="sine")
    mults = [m["sd3"] for _, m in phases]
    assert max(mults) <= 1.5 + 1e-9 and min(mults) >= 0.5 - 1e-9
    assert len(set(round(m, 6) for m in mults)) > 2   # actually varies


def test_phase_shift_phases_single_flip():
    phases = workloads.phase_shift_phases(flip_frac=0.4, tilt=2.0)
    assert len(phases) == 2
    assert phases[0][0] == pytest.approx(0.4)
    assert phases[0][1]["sd3"] == pytest.approx(2.0)
    assert phases[0][1]["cogvideox"] == pytest.approx(0.5)
    assert phases[1][1]["sd3"] == pytest.approx(0.5)


def test_randomized_fleet_scenario_periods_variant():
    """periods=1 keeps the historical single-flip output byte-identical;
    periods>1 produces the periodic variant with the same rate draws."""
    r1, p1 = workloads.randomized_fleet_scenario(7)
    r1b, p1b = workloads.randomized_fleet_scenario(7, periods=1)
    assert r1 == r1b and p1 == p1b
    assert len(p1) == 2
    r3, p3 = workloads.randomized_fleet_scenario(7, periods=3)
    assert r3 == r1                      # same rate draws
    assert len(p3) == 6
    assert p3[0][1] == p1[0][1]          # same hi tilt
    assert p3[1][1] == p1[1][1]          # same lo tilt
    assert p3[-1][0] == pytest.approx(1.0)


def test_diurnal_fleet_trace_has_periodic_mix():
    profs = {p: Profiler(C.get(p)) for p in ("sd3", "cogvideox")}
    phases = workloads.diurnal_phases(n_periods=2)
    tr = workloads.fleet_trace(("sd3", "cogvideox"), 400.0, profs, seed=0,
                               rates=workloads.PREDICTIVE_RATES,
                               phases=phases)
    # sd3 arrivals concentrate in its high phases ([0,100) and [200,300))
    sd3 = [r.arrival for r in tr if r.pipeline == "sd3"]
    hi = sum(1 for t in sd3 if (t % 200.0) < 100.0)
    assert hi / len(sd3) > 0.75
