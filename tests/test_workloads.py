"""Workload traces + orchestrator alpha-mode ablation."""
import statistics

import pytest

import repro.configs as C
from repro.core.orchestrator import Orchestrator
from repro.core.profiler import Profiler
from repro.core import workloads


@pytest.fixture(scope="module")
def prof():
    return Profiler(C.get("flux"))


def test_steady_rates_and_mixes(prof):
    tr = workloads.steady_trace("flux", "heavy", 600.0, prof, seed=1)
    rate = len(tr) / 600.0
    assert abs(rate - workloads.RATES["flux"]) < 0.3
    # heavy mix skews to high resolutions (Table 5)
    share_hi = sum(r.resolution >= 3072 for r in tr) / len(tr)
    assert share_hi > 0.35
    # arrivals sorted, deadlines = 2.5x optimal (AlpaServe convention)
    for r in tr[:50]:
        assert abs((r.deadline - r.arrival) / prof.pipeline_time(r) - 2.5) < 1e-6


def test_dynamic_trace_shifts_mix(prof):
    tr = workloads.dynamic_trace("flux", 600.0, prof, seed=2)
    span = 600.0 / len(workloads.DYNAMIC_PATTERN)
    first = [r for r in tr if r.arrival < span]
    heavy_span_idx = 2  # pattern[2] is 70% heavy
    heavy = [r for r in tr if heavy_span_idx * span <= r.arrival
             < (heavy_span_idx + 1) * span]
    mean_res = lambda rs: statistics.mean(r.resolution for r in rs)
    assert mean_res(heavy) > mean_res(first)


def test_proprietary_trace_tidal(prof):
    tr = workloads.proprietary_trace("flux", 600.0, prof, seed=3)
    buckets = [0] * 10
    for r in tr:
        buckets[min(9, int(r.arrival / 60))] += 1
    assert max(buckets) > 2 * (min(buckets) + 1)   # pronounced tide


def test_alpha_mode_demand_vs_count(prof):
    """Demand weighting provisions more D-capacity for heavy-skewed mixes
    than count weighting (the beyond-paper orchestrator refinement)."""
    tr = workloads.steady_trace("flux", "heavy", 300.0, prof, seed=4)
    demand = Orchestrator(prof, 128, alpha_mode="demand").generate(tr)
    count = Orchestrator(prof, 128, alpha_mode="count").generate(tr)
    heavy_cap = lambda plan: sum(
        n for t, n in plan.type_histogram().items() if t in ("DC", "D"))
    assert heavy_cap(demand) >= heavy_cap(count)
    # both remain valid full-coverage plans
    for plan in (demand, count):
        for s in "EDC":
            assert plan.units_with(s)
