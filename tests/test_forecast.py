"""Demand forecasting + predictive re-partitioning (core/forecast.py,
the ``predictive`` fleet scheduler in core/fleet.py).

Covers: randomized property tests for the forecaster (sinusoid,
square-wave, and trend+noise traces across seeds — predicted shift time
within tolerance; the confidence gate never fires on stationary traffic),
the FleetMonitor rate history, the pre-warm budget (mis-prediction cost
bound), pre-warm staging/consumption mechanics, the pre-warm × lending
interaction (no loan survives a cutover), and the system-level behavior —
predictive mode beats adaptive on a diurnal mix-flip trace and is inert on
stationary traffic.
"""
import math
import random

import pytest

from repro.core import workloads
from repro.core.fleet import (FLEET_SCHEDULERS, FleetConfig,
                              FleetOrchestrator, FleetSimulator,
                              PipelineRegistry, PredictiveFleetScheduler,
                              run_fleet)
from repro.core.forecast import (DemandForecaster, ShiftPrediction,
                                 fit_series, tv_distance)
from repro.core.monitor import FleetMonitor

BIN = 10.0
PERIOD = 300.0
SPAN = 600.0          # 2 periods of history — the minimum for detection


def _history(fn_a, fn_b, t_end, bin_s=BIN, span=SPAN, seed=0, noise=0.25):
    """Synthetic completed-bin history with multiplicative noise."""
    rng = random.Random(seed)
    out = []
    b = int(max(0.0, t_end - span) // bin_s)
    while (b + 1) * bin_s <= t_end:
        tc = (b + 0.5) * bin_s
        out.append((tc, {"a": max(0.0, fn_a(tc) * (1 + rng.gauss(0, noise))),
                         "b": max(0.0, fn_b(tc) * (1 + rng.gauss(0, noise)))}))
        b += 1
    return out


def _square(t, period=PERIOD, hi=3.0, lo=0.5):
    return hi if (t % period) < period / 2 else lo


# -- forecaster property tests -------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_square_wave_shift_predicted_within_tolerance(seed):
    """Anti-phase square waves: the predicted next flip must land within
    two bins of the true flip, pointing at the right settled mix."""
    fa = lambda t: _square(t)
    fb = lambda t: _square(t + PERIOD / 2)
    fc = DemandForecaster(bin_s=BIN, min_conf=0.35)
    for tau in (640.0, 810.0, 1000.0):
        fc.fit(_history(fa, fb, tau, seed=seed))
        pred = fc.predict_shift(tau, threshold=0.10, horizon=250.0)
        true_next = (int(tau // (PERIOD / 2)) + 1) * (PERIOD / 2)
        assert pred is not None, (seed, tau)
        assert abs(pred.t_shift - true_next) <= 2 * BIN, (seed, tau, pred)
        # the settled mix is the *new* phase's
        a_high_next = (true_next % PERIOD) < PERIOD / 2
        assert (pred.shares["a"] > 0.6) == a_high_next, (seed, tau, pred)


@pytest.mark.parametrize("seed", range(5))
def test_sinusoid_shift_predicted_within_tolerance(seed):
    """Smooth anti-phase tides: the predicted crossing must be within a
    quarter period of the true threshold crossing (the crossing time of a
    smooth waveform is noise-sensitive by nature; the phase may not be
    inverted)."""
    w = 2 * math.pi / PERIOD
    fa = lambda t: 2.0 + 1.5 * math.sin(w * t)
    fb = lambda t: 2.0 - 1.5 * math.sin(w * t)
    fc = DemandForecaster(bin_s=BIN, min_conf=0.35)
    tau = 600.0   # sin = 0 and rising: mix is even, about to tilt toward a
    fc.fit(_history(fa, fb, tau, seed=seed, noise=0.15))
    pred = fc.predict_shift(tau, threshold=0.10, horizon=250.0)
    assert pred is not None, seed
    # true crossing: TV = |1.5 sin(wt)| * 2 / 8 >= 0.10 -> t ~ tau + 13 s
    true_cross = tau + math.asin(8.0 * 0.10 / 3.0) / w
    assert abs(pred.t_shift - true_cross) <= PERIOD / 4, (seed, pred)
    assert pred.shares["a"] > 0.5, (seed, pred)   # tilting toward a


@pytest.mark.parametrize("seed", range(5))
def test_trend_with_noise_predicts_drift_crossing(seed):
    """Linear anti-phase trends + noise: the predicted crossing must be
    within tolerance of where the extrapolated shares cross the
    threshold."""
    fa = lambda t: 1.0 + 0.004 * t
    fb = lambda t: 5.8 - 0.004 * t
    fc = DemandForecaster(bin_s=BIN, min_conf=0.35)
    tau = 600.0
    fc.fit(_history(fa, fb, tau, seed=seed, noise=0.10))
    pred = fc.predict_shift(tau, threshold=0.10, horizon=400.0)
    assert pred is not None, seed
    # shares_a(t) = (1 + .004 t) / 6.8; TV(t) - TV(600) >= 0.10 at t = 770
    assert abs(pred.t_shift - 770.0) <= 80.0, (seed, pred)
    assert pred.shares["a"] > pred.shares["b"] or pred.t_shift < 900.0


@pytest.mark.parametrize("seed", range(8))
def test_confidence_gate_never_fires_on_stationary_traffic(seed):
    """Stationary noisy traffic: the gate must hold — no prediction, ever,
    at any noise seed."""
    fc = DemandForecaster(bin_s=BIN, min_conf=0.35)
    fc.fit(_history(lambda t: 2.0, lambda t: 2.0, 800.0, seed=seed,
                    noise=0.3))
    assert fc.confidence() < 0.35, seed
    assert fc.predict_shift(800.0, threshold=0.10, horizon=250.0) is None


def test_fit_series_rejects_short_lag_plateau_correlation():
    """A slowly-varying (but aperiodic) series correlates at every small
    lag; the dip-gated autocorrelation must not call it periodic."""
    ts = [(i + 0.5) * BIN for i in range(60)]
    rng = random.Random(7)
    level = 2.0
    ys = []
    for _ in ts:
        level += rng.gauss(0, 0.05)       # a slow random walk
        ys.append(max(0.0, level))
    fit = fit_series(ts, ys)
    assert fit.period == 0.0


def test_tv_distance_basics():
    assert tv_distance({"a": 1.0}, {"a": 1.0}) == 0.0
    assert tv_distance({"a": 1.0}, {"b": 1.0}) == 1.0
    assert abs(tv_distance({"a": 0.75, "b": 0.25},
                           {"a": 0.25, "b": 0.75}) - 0.5) < 1e-12


# -- FleetMonitor rate history -------------------------------------------------

def test_rate_history_bins_zero_fill_and_trim():
    mon = FleetMonitor(t_win=100.0)
    assert mon.rate_history(50.0, ("a",)) == []     # disabled by default
    mon.enable_rate_history(10.0, 50.0)
    mon.record_arrival(3.0, "a", 20.0)
    mon.record_arrival(7.0, "a", 10.0)
    mon.record_arrival(25.0, "b", 40.0)
    hist = mon.rate_history(31.0, ("a", "b"))
    assert [t for t, _ in hist] == [5.0, 15.0, 25.0]
    assert hist[0][1] == {"a": 3.0, "b": 0.0}       # 30 cost / 10 s bin
    assert hist[1][1] == {"a": 0.0, "b": 0.0}       # zero-filled gap
    assert hist[2][1] == {"a": 0.0, "b": 4.0}
    # the current (still-filling) bin is excluded
    mon.record_arrival(33.0, "a", 10.0)
    assert [t for t, _ in mon.rate_history(35.0, ("a",))] == [5.0, 15.0, 25.0]
    # old bins slide out of the retained span (5 completed bins kept)
    mon.record_arrival(90.0, "a", 10.0)
    hist = mon.rate_history(90.0, ("a",))
    assert hist[0][0] == 45.0 and len(hist) == 5
    # ``last`` restricts to the newest completed bins
    assert [t for t, _ in mon.rate_history(90.0, ("a",), last=2)] \
        == [75.0, 85.0]


def test_rate_history_oldest_returned_bin_is_backed():
    """Trim regression: the oldest bin the query window returns must still
    hold its recorded demand — trimming it early would show the forecaster
    a spurious zero valley at the left edge of every full window."""
    mon = FleetMonitor(t_win=100.0)
    mon.enable_rate_history(10.0, 50.0)
    for b in range(10):
        mon.record_arrival(b * 10.0 + 1.0, "a", 10.0)
    hist = mon.rate_history(95.0, ("a",))
    assert [t for t, _ in hist] == [45.0, 55.0, 65.0, 75.0, 85.0]
    assert all(d["a"] == 1.0 for _, d in hist), hist


# -- pre-warm staging mechanics ------------------------------------------------

def _bootstrap_fleet(monkeypatch, lending=False, mode="adaptive",
                     pipelines=("sd3", "cogvideox"), num_chips=128,
                     **cfg_kw):
    """A fully initialised FleetSimulator whose clock never ran: plan,
    lanes and engines exist, so staging/repartition mechanics can be
    driven by hand."""
    from repro.core.clock import EventClock
    cfg = FleetConfig(num_chips=num_chips, lending=lending, **cfg_kw)
    registry = PipelineRegistry(pipelines)
    profs = {p: registry.profiler(p) for p in pipelines}
    trace = workloads.fleet_trace(pipelines, 60.0, profs, seed=0,
                                  rates={"sd3": 10.0, "cogvideox": 0.5})
    orch = FleetOrchestrator(registry, num_chips=num_chips, chips_per_node=8)
    sched = FLEET_SCHEDULERS[mode](orch, cfg)
    sim = FleetSimulator(registry, sched, trace, cfg)
    monkeypatch.setattr(EventClock, "run", lambda self, driver: None)
    sim.run()
    assert sim.plan is not None
    return sim


def _flipped_budgets(sim):
    """Budgets that reverse the current partition (every unit flips)."""
    hist = sim.plan.budget_histogram()
    pids = list(sim.reg.pipelines)
    assert len(pids) == 2
    return {pids[0]: hist[pids[1]], pids[1]: hist[pids[0]]}


def test_stage_prewarm_respects_budget_and_is_idempotent(monkeypatch):
    """Mis-prediction cost bound: one staging call never stages more than
    the pre-warm budget, its cost is bounded by budget x full reload, and
    re-staging the same target is free."""
    sim = _bootstrap_fleet(monkeypatch, prewarm_budget=6)
    budgets = _flipped_budgets(sim)
    staged = sim.stage_prewarm(budgets, tau=0.0)
    assert 0 < staged <= 6
    assert sim.prewarm_units == staged
    max_reload = max(
        sum(sim.reg.profiler(p).stage_load_time(s, via_host=True)
            for s in "EDC") for p in sim.reg.pipelines)
    assert sim.prewarm_cost_s <= staged * max_reload * 2 + 1e-9
    # staged chips are remembered: a second identical call stages 0 more
    cost = sim.prewarm_cost_s
    assert sim.stage_prewarm(budgets, tau=0.0) == 0 or \
        sim.prewarm_units <= 6 * 2
    assert sim.prewarm_cost_s <= cost + max_reload * 6 * 2


def test_prewarm_averts_cutover_reload(monkeypatch):
    """The point of the tentpole at mechanism scale: staging the flipped
    partition's weights, then re-partitioning to it, must charge less
    swap reload than the same re-partition without staging."""
    cold = _bootstrap_fleet(monkeypatch)
    budgets = _flipped_budgets(cold)
    cold._repartition(budgets, tau=10.0)
    assert cold.swap_cost_s > 0.0
    warm = _bootstrap_fleet(monkeypatch, prewarm_budget=10 ** 6)
    staged = warm.stage_prewarm(dict(budgets), tau=0.0)
    assert staged > 0
    warm._repartition(dict(budgets), tau=10.0)
    assert warm.prewarm_hits > 0
    assert warm.swap_cost_s < cold.swap_cost_s
    assert not warm.prewarmed          # marks are spent at the cutover


def test_prewarm_ttl_expires_staged_weights(monkeypatch):
    """Staged weights are evicted after prewarm_ttl: a cutover long after
    the staging pays the full reload again."""
    sim = _bootstrap_fleet(monkeypatch, prewarm_budget=10 ** 6,
                           prewarm_ttl=30.0)
    budgets = _flipped_budgets(sim)
    sim.stage_prewarm(dict(budgets), tau=0.0)
    ref = _bootstrap_fleet(monkeypatch)
    ref._repartition(dict(budgets), tau=100.0)
    sim._repartition(dict(budgets), tau=100.0)   # 100 > ttl: all stale
    assert sim.prewarm_hits == 0
    assert sim.swap_cost_s == pytest.approx(ref.swap_cost_s)


def test_idle_only_staging_defers_busy_units(monkeypatch):
    """With idle_only, a unit mid-work is skipped (deferred), not stalled."""
    sim = _bootstrap_fleet(monkeypatch, prewarm_budget=10 ** 6)
    # make every unit of every lane busy
    for lane in sim.lanes.values():
        lane.engine.seed_unit_state(
            {u.uid: 50.0 for u in lane.engine.units})
    budgets = _flipped_budgets(sim)
    assert sim.stage_prewarm(budgets, tau=0.0, idle_only=True) == 0
    assert sim.prewarm_cost_s == 0.0
    # without idle_only the same call stages (queued behind the busy work)
    assert sim.stage_prewarm(budgets, tau=0.0) > 0


# -- pre-warm x lending (no loan survives a cutover) ---------------------------

def test_prewarm_forces_loan_return_before_staging(monkeypatch):
    """A lent-out unit scheduled for pre-warm must return its loan before
    anything is staged on its chips, and no loan ever survives the
    cutover."""
    sim = _bootstrap_fleet(monkeypatch, lending=True,
                           prewarm_budget=10 ** 6)
    broker = sim.broker
    assert broker is not None
    # hand-grant a loan on every lendable sd3 unit so staging must hit one
    lend_map = sim.plan.lending_map(sim.reg)
    grants = 0
    for units in lend_map.values():
        for lu in units:
            if lu.pipeline == "sd3" and ("cogvideox", "C") in lu.borrow_cost:
                broker._grant(sim, 0.0, "cogvideox", lu, "C")
                grants += 1
    assert grants > 0 and broker.active
    # shrink sd3 to its floor: cogvideox target units land on lent sd3
    # chips, so those units ARE scheduled for pre-warm
    budgets = {"sd3": 8, "cogvideox": sim.cfg.num_chips - 8}
    sim.stage_prewarm(budgets, tau=1.0)
    assert sim.prewarm_loan_returns > 0
    assert broker.forced_returns >= sim.prewarm_loan_returns
    # the remaining loans (if any) are force-closed by the cutover itself
    sim._repartition(budgets, tau=5.0)
    assert not broker.active, "a loan survived the cutover"
    for lane in sim.lanes.values():
        assert lane.borrowed_units == {}


def test_prewarm_loan_return_charges_the_lender_reload(monkeypatch):
    """The forced return pays the lender's reload through the same
    seed_unit_state path as every other loan close."""
    sim = _bootstrap_fleet(monkeypatch, lending=True)
    broker = sim.broker
    lend_map = sim.plan.lending_map(sim.reg)
    lu = next(lu for units in lend_map.values() for lu in units
              if lu.pipeline == "sd3" and ("cogvideox", "C") in lu.borrow_cost)
    broker._grant(sim, 0.0, "cogvideox", lu, "C")
    swap_before = broker.swap_cost_s
    assert broker.force_return_unit(sim, "sd3", lu.unit, tau=1.0)
    assert broker.swap_cost_s > swap_before       # return reload charged
    assert not broker.force_return_unit(sim, "sd3", lu.unit, tau=1.0)
    lender_unit = sim.lanes["sd3"].engine.units[lu.unit]
    assert lender_unit.free_at > 1.0              # busy reloading


# -- system-level predictive behavior -----------------------------------------

def _diurnal_cfg(**kw):
    base = dict(num_chips=128, t_win=90.0, cooldown=70.0,
                forecast_bin=5.0, forecast_history=480.0,
                forecast_horizon=200.0, prewarm_lead=40.0,
                prewarm_cooldown=60.0, prewarm_ttl=200.0,
                forecast_grace=50.0)
    base.update(kw)
    return FleetConfig(**base)


DIURNAL_RATES = {"sd3": 14.0, "cogvideox": 0.42}


@pytest.fixture(scope="module")
def diurnal_results():
    phases = workloads.diurnal_phases(n_periods=4)
    out = {}
    for mode in ("adaptive", "predictive"):
        out[mode] = run_fleet(["sd3", "cogvideox"], mode=mode,
                              duration=960.0, cfg=_diurnal_cfg(),
                              rates=DIURNAL_RATES, phases=phases)
    return out


def test_predictive_beats_adaptive_on_diurnal_trace(diurnal_results):
    """The tentpole claim at test scale: on a diurnal mix-flip trace the
    predictive scheduler pre-warms, fires predicted shifts, and the worst
    pipeline's tail never degrades vs adaptive."""
    ad, pr = diurnal_results["adaptive"], diurnal_results["predictive"]
    assert not ad.oom and not pr.oom
    assert ad.n_requests == pr.n_requests
    assert pr.predictive_repartitions > 0
    assert pr.prewarm_units > 0 and pr.prewarm_hits > 0
    worst_ad = max(m["p95_s"] for m in ad.per_pipeline.values())
    worst_pr = max(m["p95_s"] for m in pr.per_pipeline.values())
    assert worst_pr <= worst_ad
    assert pr.slo_attainment >= ad.slo_attainment


def test_predictive_prewarm_cost_is_bounded(diurnal_results):
    """Mis-prediction cost bound at system scale: total staging cost can
    never exceed (stagings allowed by the cooldown) x budget x reload."""
    pr = diurnal_results["predictive"]
    cfg = _diurnal_cfg()
    from repro.core.profiler import Profiler
    import repro.configs as C
    max_reload = max(
        sum(Profiler(C.get(p)).stage_load_time(s, via_host=True)
            for s in "EDC") for p in ("sd3", "cogvideox"))
    campaigns = 960.0 / cfg.prewarm_cooldown + 1
    assert pr.prewarm_cost_s <= campaigns * cfg.prewarm_budget * max_reload
    assert pr.prewarm_units <= campaigns * cfg.prewarm_budget


def test_predictive_is_inert_on_stationary_traffic():
    """The confidence gate end-to-end: stationary traffic must produce no
    predictions, no pre-warms, and no predictive re-partitions."""
    res = run_fleet(["sd3", "cogvideox"], mode="predictive", duration=300.0,
                    cfg=_diurnal_cfg(), rates=DIURNAL_RATES, phases=None)
    assert res.predictive_repartitions == 0
    assert res.prewarm_units == 0
    assert res.prewarm_cost_s == 0.0


def test_predictive_defaults_off_and_knobs_inert_elsewhere():
    """mode="adaptive" with arbitrary predictive knobs must be bit-identical
    to plain adaptive — the knobs are read only by the predictive
    scheduler (the off path must reproduce the committed baselines)."""
    phases = ((0.5, {"sd3": 1.5, "flux": 0.3}),
              (1.0, {"sd3": 0.3, "flux": 2.0}))
    rates = {"sd3": 10.0, "flux": 1.0}
    a = run_fleet(["sd3", "flux"], mode="adaptive", duration=120.0,
                  cfg=FleetConfig(num_chips=128, t_win=60.0, cooldown=40.0),
                  rates=rates, phases=phases)
    b = run_fleet(["sd3", "flux"], mode="adaptive", duration=120.0,
                  cfg=FleetConfig(num_chips=128, t_win=60.0, cooldown=40.0,
                                  forecast_bin=1.0, forecast_history=30.0,
                                  forecast_min_conf=0.0, prewarm_lead=5.0,
                                  prewarm_budget=999, prewarm_cooldown=1.0),
                  rates=rates, phases=phases)
    assert a.slo_attainment == b.slo_attainment
    assert a.mean_latency == b.mean_latency
    assert a.p95_latency == b.p95_latency
    assert a.sched_wakeups == b.sched_wakeups
    assert a.repartitions == b.repartitions
    assert b.prewarm_units == 0 and b.predictive_repartitions == 0


def test_predictive_scheduler_registered():
    assert "predictive" in FLEET_SCHEDULERS
    assert FLEET_SCHEDULERS["predictive"] is PredictiveFleetScheduler
    assert PredictiveFleetScheduler.uses_forecast
    # the forecast wake source contract: next bin boundary, plus the armed
    # shift time
    orch = FleetOrchestrator(PipelineRegistry(("sd3",)), num_chips=64)
    sched = PredictiveFleetScheduler(orch, FleetConfig(forecast_bin=10.0))
    assert sched.forecast_wake(12.0) == 20.0
    sched._pred = ShiftPrediction(t_shift=15.0, confidence=1.0,
                                  shares={"sd3": 1.0}, demand={"sd3": 1.0})
    assert sched.forecast_wake(12.0) == 15.0
