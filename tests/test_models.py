"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes + finiteness, plus prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.data import pipeline as dp
from repro.models import audio, transformer as tf, vlm
from repro.training import loop as train_loop

ARCHS = list(C.ARCH_IDS)


def _batch_for(cfg, b=2, l=16, seed=0):
    dcfg = dp.DataConfig(batch=b, seq_len=l, seed=seed)
    return {k: jnp.asarray(v) for k, v in dp.synthetic_batch(cfg, dcfg, 0).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = C.get_smoke(arch)
    assert cfg.d_model <= 512 and cfg.num_experts <= 4
    params = tf.init(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = tf.forward(cfg, params, batch["tokens"],
                             prefix_embeds=batch.get("patch_embeds"))
    b = batch["tokens"].shape[0]
    if cfg.modality == "audio_codec":
        assert logits.shape == (b, 16, cfg.num_codebooks, cfg.vocab_size)
    elif cfg.modality == "vision":
        assert logits.shape == (b, 16 + cfg.vision_tokens, cfg.vocab_size)
    else:
        assert logits.shape == (b, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = C.get_smoke(arch)
    state = train_loop.init_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(train_loop.make_train_step(cfg))
    batch = _batch_for(cfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state.opt.step) == 1
    # params actually moved
    p0 = jax.tree_util.tree_leaves(state.params)[1]
    assert np.isfinite(np.asarray(p0, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """serve_step against a prefilled cache == full forward's last logits."""
    cfg = C.get_smoke(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    params = tf.init(cfg, jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)
    b, l = 2, 12
    if cfg.modality == "audio_codec":
        toks = jax.random.randint(key, (b, cfg.num_codebooks, l), 0, cfg.vocab_size)
        last, rest = toks[:, :, -1:], toks[:, :, :-1]
    else:
        toks = jax.random.randint(key, (b, l), 0, cfg.vocab_size)
        last, rest = toks[:, -1:], toks[:, :-1]
    logits, _ = tf.forward(cfg, params, toks)
    _, cache, off = tf.prefill(cfg, params, rest, max_len=32)
    dec, _ = tf.decode_step(cfg, params, last, cache, off)
    want = np.asarray(logits[:, -1])
    got = np.asarray(dec[:, 0])
    rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert rel < 2e-2, f"{arch}: decode/forward rel err {rel}"


def test_vlm_prefix_embeddings_change_logits():
    cfg = C.get_smoke("internvl2-2b")
    params = tf.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    pe0 = vlm.vision_stub_embeds(cfg, 2)
    pe1 = vlm.vision_stub_embeds(cfg, 2, jax.random.PRNGKey(3)) * 10
    l0, _ = vlm.vlm_forward(cfg, params, toks, pe0)
    l1, _ = vlm.vlm_forward(cfg, params, toks, pe1)
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


def test_audio_delay_pattern_roundtrip():
    cfg = C.get_smoke("musicgen-medium")
    toks = audio.codec_stub_tokens(cfg, 2, 10, jax.random.PRNGKey(0))
    delayed = audio.apply_delay_pattern(toks)
    # codebook k is shifted right by k
    np.testing.assert_array_equal(np.asarray(delayed[:, 0]), np.asarray(toks[:, 0]))
    np.testing.assert_array_equal(np.asarray(delayed[:, 2, 2:]),
                                  np.asarray(toks[:, 2, :-2]))
    undone = audio.undo_delay_pattern(delayed)
    np.testing.assert_array_equal(np.asarray(undone[:, :, :6]),
                                  np.asarray(toks[:, :, :6]))


def test_sliding_window_restricts_context():
    """A token beyond the window must not influence local attention."""
    cfg = C.get_smoke("starcoder2-15b")
    cfg = dataclasses.replace(cfg, window_size=4, num_layers=1,
                              layer_pattern=("attn_local:dense",))
    params = tf.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0, cfg.vocab_size)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 7) % cfg.vocab_size)
    l1, _ = tf.forward(cfg, params, toks)
    l2, _ = tf.forward(cfg, params, toks2)
    # position 9 attends to positions 6..9 only -> unaffected by pos-0 change
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               atol=1e-6)
    assert not np.allclose(np.asarray(l1[0, 1]), np.asarray(l2[0, 1]))


def test_gemma2_softcap_bounds_logits():
    cfg = C.get_smoke("gemma2-9b")
    params = tf.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    logits, _ = tf.forward(cfg, params, toks)
    assert np.abs(np.asarray(logits)).max() <= cfg.logit_softcap + 1e-4


def test_moe_aux_loss_nonzero_and_capacity_drops():
    cfg = C.get_smoke("deepseek-moe-16b")
    params = tf.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    _, aux = tf.forward(cfg, params, toks)
    assert float(aux) > 0.0
    # tiny capacity must still produce finite outputs (drops, not NaNs)
    tight = dataclasses.replace(cfg, capacity_factor=0.25)
    logits, _ = tf.forward(tight, params, toks)
    assert np.isfinite(np.asarray(logits)).all()
