"""ILP solver: exactness vs brute force (hypothesis property tests)."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import ilp


@st.composite
def instances(draw):
    n = draw(st.integers(1, 5))
    dims = draw(st.integers(1, 3))
    budgets = [draw(st.integers(0, 8)) for _ in range(dims)]
    options = []
    for _ in range(n):
        m = draw(st.integers(0, 4))
        opts = [ilp.Option(dim=draw(st.integers(0, dims - 1)),
                           usage=draw(st.sampled_from([1, 2, 4, 8])),
                           reward=draw(st.floats(-5, 20, allow_nan=False,
                                                 width=32)))
                for _ in range(m)]
        options.append(opts)
    return options, budgets


@given(instances())
@settings(max_examples=150, deadline=None)
def test_solver_matches_brute_force(inst):
    options, budgets = inst
    sol = ilp.solve(options, budgets)
    assert sol.optimal
    assert abs(sol.reward - ilp.brute_force(options, budgets)) < 1e-6


@given(instances())
@settings(max_examples=100, deadline=None)
def test_solution_is_feasible(inst):
    options, budgets = inst
    sol = ilp.solve(options, budgets)
    used = [0] * len(budgets)
    for r, o in sol.choices.items():
        assert o in options[r]
        assert o.reward > 0
        used[o.dim] += o.usage
    for u, b in zip(used, budgets):
        assert u <= b
    # reward accounting
    assert abs(sum(o.reward for o in sol.choices.values()) - sol.reward) < 1e-6


def test_anytime_cap_returns_feasible():
    import random
    rng = random.Random(0)
    options = [[ilp.Option(rng.randrange(4), rng.choice([1, 2, 4, 8]),
                           rng.uniform(10, 1000)) for _ in range(8)]
               for _ in range(300)]
    budgets = [64, 32, 16, 16]
    sol = ilp.solve(options, budgets, node_cap=5000, time_cap=0.05)
    used = [0] * 4
    for r, o in sol.choices.items():
        used[o.dim] += o.usage
    assert all(u <= b for u, b in zip(used, budgets))
    assert sol.reward > 0
