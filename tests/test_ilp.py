"""ILP solver: exactness vs brute force.

Property-style tests over a seeded random-case generator, so the suite
needs no optional ``hypothesis`` dependency; when hypothesis is installed
the same properties also run fuzzed (see the bottom of the file).
"""
import random

import pytest

from repro.core import ilp


def make_instance(seed: int):
    """Random small instance: mirrors the old hypothesis strategy."""
    rng = random.Random(seed)
    n = rng.randint(1, 5)
    dims = rng.randint(1, 3)
    budgets = [rng.randint(0, 8) for _ in range(dims)]
    options = []
    for _ in range(n):
        m = rng.randint(0, 4)
        options.append([ilp.Option(dim=rng.randrange(dims),
                                   usage=rng.choice([1, 2, 4, 8]),
                                   reward=rng.uniform(-5, 20))
                        for _ in range(m)])
    # duplicate option lists exercise the solver's symmetry breaking
    if n > 2 and rng.random() < 0.4:
        options[1] = list(options[0])
    return options, budgets


@pytest.mark.parametrize("block", range(5))
def test_solver_matches_brute_force(block):
    for seed in range(block * 50, block * 50 + 50):
        options, budgets = make_instance(seed)
        sol = ilp.solve(options, budgets)
        assert sol.optimal
        assert abs(sol.reward - ilp.brute_force(options, budgets)) < 1e-6, seed


@pytest.mark.parametrize("block", range(3))
def test_solution_is_feasible(block):
    for seed in range(1000 + block * 50, 1000 + block * 50 + 50):
        options, budgets = make_instance(seed)
        sol = ilp.solve(options, budgets)
        used = [0] * len(budgets)
        for r, o in sol.choices.items():
            assert o in options[r]
            assert o.reward > 0
            used[o.dim] += o.usage
        for u, b in zip(used, budgets):
            assert u <= b
        # reward accounting
        assert abs(sum(o.reward for o in sol.choices.values()) - sol.reward) < 1e-6


def test_warm_start_preserves_optimality():
    """A warm hint — even an adversarially bad or stale one — only seeds the
    incumbent and must not change the optimum."""
    for seed in range(200):
        options, budgets = make_instance(seed)
        ref = ilp.solve(options, budgets)
        rng = random.Random(seed + 999)
        warm = {}
        for r, opts in enumerate(options):
            if opts and rng.random() < 0.7:
                o = rng.choice(opts)
                warm[r] = (o.dim, o.usage)
        warm[len(options) + 3] = (0, 1)   # stale index must be ignored
        sol = ilp.solve(options, budgets, warm=warm)
        assert sol.optimal
        assert abs(sol.reward - ref.reward) < 1e-6, seed


def test_warm_start_speeds_reconvergence():
    """Re-solving an instance from last round's optimal choices must not
    explore more nodes than solving cold: the warm incumbent starts at the
    optimum, so the branch-and-bound prunes a subset of the cold tree.
    (Both solves must reach proven optimality — a capped solve's node count
    is wall-clock dependent — so the instance is kept small.)"""
    rng = random.Random(42)
    options = [[ilp.Option(rng.randrange(2), rng.choice([1, 2, 4]),
                           rng.uniform(100, 1000)) for _ in range(3)]
               for _ in range(14)]
    budgets = [8, 8]
    cold = ilp.solve(options, budgets, time_cap=60.0)
    assert cold.optimal, "instance must be provably solvable for this test"
    warm = {r: (o.dim, o.usage) for r, o in cold.choices.items()}
    resolved = ilp.solve(options, budgets, warm=warm, time_cap=60.0)
    assert resolved.optimal
    assert abs(resolved.reward - cold.reward) < 1e-6
    assert resolved.nodes <= cold.nodes


def test_anytime_cap_returns_feasible():
    rng = random.Random(0)
    options = [[ilp.Option(rng.randrange(4), rng.choice([1, 2, 4, 8]),
                           rng.uniform(10, 1000)) for _ in range(8)]
               for _ in range(300)]
    budgets = [64, 32, 16, 16]
    sol = ilp.solve(options, budgets, node_cap=5000, time_cap=0.05)
    used = [0] * 4
    for r, o in sol.choices.items():
        used[o.dim] += o.usage
    assert all(u <= b for u, b in zip(used, budgets))
    assert sol.reward > 0


# -- optional hypothesis fuzzing (runs only when the dep is installed) --------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @st.composite
    def instances(draw):
        n = draw(st.integers(1, 5))
        dims = draw(st.integers(1, 3))
        budgets = [draw(st.integers(0, 8)) for _ in range(dims)]
        options = []
        for _ in range(n):
            m = draw(st.integers(0, 4))
            opts = [ilp.Option(dim=draw(st.integers(0, dims - 1)),
                               usage=draw(st.sampled_from([1, 2, 4, 8])),
                               reward=draw(st.floats(-5, 20, allow_nan=False,
                                                     width=32)))
                    for _ in range(m)]
            options.append(opts)
        return options, budgets

    @given(instances())
    @settings(max_examples=150, deadline=None)
    def test_solver_matches_brute_force_fuzzed(inst):
        options, budgets = inst
        sol = ilp.solve(options, budgets)
        assert sol.optimal
        assert abs(sol.reward - ilp.brute_force(options, budgets)) < 1e-6
except ImportError:
    pass


# -- multiplicity-aware grouped solve (dense same-class floods) ---------------

def make_grouped_instance(seed: int):
    """Small grouped instance with modest counts (brute-forceable after
    expansion)."""
    rng = random.Random(seed)
    n_groups = rng.randint(1, 3)
    dims = rng.randint(1, 2)
    budgets = [rng.randint(1, 6) for _ in range(dims)]
    options, counts = [], []
    for _ in range(n_groups):
        m = rng.randint(1, 3)
        options.append([ilp.Option(dim=rng.randrange(dims),
                                   usage=rng.choice([1, 2, 4]),
                                   reward=rng.uniform(-2, 20))
                        for _ in range(m)])
        counts.append(rng.randint(1, 4))
    return options, budgets, counts


@pytest.mark.parametrize("block", range(3))
def test_grouped_matches_expanded_brute_force(block):
    for seed in range(2000 + block * 40, 2000 + block * 40 + 40):
        options, budgets, counts = make_grouped_instance(seed)
        gsol = ilp.solve_grouped(options, budgets, counts)
        assert gsol.optimal
        expanded = [opts for opts, m in zip(options, counts)
                    for _ in range(m)]
        assert abs(gsol.reward - ilp.brute_force(expanded, budgets)) < 1e-6, seed
        # per-group grants never exceed the multiplicity, and usage fits
        used = [0] * len(budgets)
        for g, granted in gsol.alloc.items():
            assert len(granted) <= counts[g]
            for o in granted:
                assert o in options[g]
                used[o.dim] += o.usage
        for u, b in zip(used, budgets):
            assert u <= b


def test_grouped_flood_is_capacity_capped():
    """5000 identical requests against a budget of 8 must build an 8-slot
    instance, not a 5000-row one — the whole point of the aggregation."""
    opts = [[ilp.Option(dim=0, usage=1, reward=10.0)]]
    gsol = ilp.solve_grouped(opts, budgets=[8], counts=[5000])
    assert gsol.n_slots == 8
    assert gsol.optimal
    assert len(gsol.alloc[0]) == 8
    assert abs(gsol.reward - 80.0) < 1e-9


def test_grouped_warm_start_preserves_optimality():
    options, budgets, counts = make_grouped_instance(2500)
    base = ilp.solve_grouped(options, budgets, counts)
    warm = {0: [(options[0][0].dim, options[0][0].usage)] * counts[0]}
    warmed = ilp.solve_grouped(options, budgets, counts, warm=warm)
    assert abs(base.reward - warmed.reward) < 1e-9


def test_grouped_zero_remaining_capacity():
    """All-zero budgets: the capacity bound caps every group's expansion at
    0 slots — nothing is solved, nothing is granted, and the solve is
    trivially optimal rather than an error."""
    opts = [[ilp.Option(dim=0, usage=1, reward=10.0)],
            [ilp.Option(dim=1, usage=2, reward=5.0)]]
    gsol = ilp.solve_grouped(opts, budgets=[0, 0], counts=[7, 3])
    assert gsol.n_slots == 0
    assert gsol.alloc == {}
    assert gsol.reward == 0.0
    assert gsol.optimal


def test_grouped_single_member_groups_equal_ungrouped_solve():
    """counts == all-ones must reduce exactly to the plain solver: same
    reward, same per-dimension usage."""
    for seed in range(40):
        options, budgets = make_instance(seed)
        plain = ilp.solve(options, budgets)
        gsol = ilp.solve_grouped(options, budgets, [1] * len(options))
        assert abs(gsol.reward - plain.reward) < 1e-6, seed
        used_plain = [0] * len(budgets)
        for o in plain.choices.values():
            used_plain[o.dim] += o.usage
        used_grouped = [0] * len(budgets)
        for granted in gsol.alloc.values():
            assert len(granted) <= 1
            for o in granted:
                used_grouped[o.dim] += o.usage
        for u, b in zip(used_grouped, budgets):
            assert u <= b


def test_grouped_expansion_cap_binds_on_flood():
    """A flood of counts far beyond capacity must expand each group only to
    its capacity bound (total_budget // min_usage), never to the raw count
    — and the truncation must not cost any reward."""
    opts = [[ilp.Option(dim=0, usage=2, reward=10.0)],      # cap: 12//2 = 6
            [ilp.Option(dim=1, usage=1, reward=4.0),
             ilp.Option(dim=0, usage=4, reward=9.0)]]       # cap: 12//1 = 12
    budgets = [8, 4]
    gsol = ilp.solve_grouped(opts, budgets, counts=[10_000, 50_000])
    assert gsol.n_slots == 6 + 12        # capacity-capped, not 60k rows
    assert gsol.optimal
    # optimum: 4x usage-2 on dim0 (40) + 4x usage-1 on dim1 (16)
    assert abs(gsol.reward - 56.0) < 1e-9
    used = [0, 0]
    for g, granted in gsol.alloc.items():
        for o in granted:
            used[o.dim] += o.usage
    assert used[0] <= budgets[0] and used[1] <= budgets[1]


def test_aggregate_dispatch_parity_on_randomized_trace():
    """Dispatcher(aggregate=True) must reach the same solver optimum and
    grant the same number of requests as the expanded per-request solve on
    a randomized same-class-heavy trace (the regime aggregation targets)."""
    import repro.configs as configs
    from repro.core import workloads
    from repro.core.dispatcher import Dispatcher
    from repro.core.orchestrator import Orchestrator
    from repro.core.profiler import Profiler

    prof = Profiler(configs.get("sd3"))
    rng = random.Random(7)
    for seed in range(4):
        trace = workloads.make_trace("sd3", "medium", 20.0, prof,
                                     seed=seed, rate=8.0)
        plan = Orchestrator(prof, num_chips=64).generate(trace[:32])
        assert plan is not None
        tau = rng.uniform(5.0, 15.0)
        pending = [r for r in trace if r.arrival <= tau][-48:]
        idle = set(range(plan.num_units))
        free_at = {g: 0.0 for g in idle}
        grants = {}
        rewards = {}
        for aggregate in (False, True):
            disp = Dispatcher(prof, aggregate=aggregate)
            decs = disp.dispatch(list(pending), plan, set(idle),
                                 dict(free_at), tau)
            grants[aggregate] = len(decs)
            rewards[aggregate] = disp.last_solve_stats["reward"]
            assert disp.last_solve_stats["optimal"]
        assert abs(rewards[True] - rewards[False]) < 1e-6, seed
        assert grants[True] == grants[False], seed
