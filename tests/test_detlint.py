"""detlint: golden-findings fixtures, suppressions, baseline, determinism.

The fixture corpus (tests/detlint_fixtures/) carries one positive and one
negative module per rule; the positives for DET001 and DET002 are verbatim
reductions of the two determinism bugs this repo actually shipped and fixed
(PR 4: string-set float accumulation; PR 5: wall-clock ILP anytime cap), so
re-introducing either class is caught here *and* by the CI gate.
"""
import os
import subprocess
import sys

import pytest

from tools.detlint.engine import (apply_baseline, lint_paths, lint_source,
                                  load_baseline, write_baseline)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join("tests", "detlint_fixtures")

# (path, line, rule) for every finding in the fixture corpus — frozen with
# the fixtures themselves
GOLDEN = [
    ("tests/detlint_fixtures/det001_pos.py", 14, "DET001"),
    ("tests/detlint_fixtures/det001_pos.py", 22, "DET001"),
    ("tests/detlint_fixtures/det002_pos.py", 16, "DET002"),
    ("tests/detlint_fixtures/det003_pos.py", 11, "DET003"),
    ("tests/detlint_fixtures/det003_pos.py", 12, "DET003"),
    ("tests/detlint_fixtures/det004_pos.py", 11, "DET004"),
    ("tests/detlint_fixtures/det005_pos.py", 10, "DET005"),
]


def _lint_fixture(name):
    path = os.path.join(REPO, FIXTURES, name)
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    findings, suppressed, err = lint_source(f"{FIXTURES}/{name}", source)
    assert err is None
    return findings, suppressed


def test_golden_findings_over_fixture_corpus(monkeypatch):
    monkeypatch.chdir(REPO)
    result = lint_paths([FIXTURES])
    assert result.errors == []
    got = [(f.path, f.line, f.rule) for f in result.findings]
    assert got == GOLDEN


@pytest.mark.parametrize("rule", ["DET001", "DET002", "DET003", "DET004",
                                  "DET005"])
def test_each_positive_fires_only_its_rule(rule):
    findings, _ = _lint_fixture(f"det{rule[-3:]}_pos.py")
    assert findings, f"{rule} positive fixture produced no findings"
    assert {f.rule for f in findings} == {rule}


@pytest.mark.parametrize("rule", ["DET001", "DET002", "DET003", "DET004",
                                  "DET005"])
def test_each_negative_is_silent(rule):
    findings, _ = _lint_fixture(f"det{rule[-3:]}_neg.py")
    assert findings == []


def test_pr4_reintroduction_is_flagged():
    """The exact PR 4 bug shape (set walk feeding float accumulation) must
    keep firing DET001 — both the += loop and the sum() variant."""
    findings, _ = _lint_fixture("det001_pos.py")
    assert len(findings) == 2 and all(f.rule == "DET001" for f in findings)


def test_pr5_reintroduction_is_flagged():
    """The exact PR 5 bug shape (wall-clock anytime cap in a solver loop)
    must keep firing DET002 even outside the strict zone — the taint
    reaches a comparison that controls a break."""
    findings, _ = _lint_fixture("det002_pos.py")
    assert [(f.rule, f.line) for f in findings] == [("DET002", 16)]


# ---------------------------------------------------------------------------
# strict zone

BARE_CLOCK = "import time\n\ndef stamp():\n    t = time.time()\n    log(t)\n"


def test_strict_zone_flags_bare_wall_clock_reads():
    findings, _, err = lint_source("src/repro/core/x.py", BARE_CLOCK,
                                   strict=True)
    assert err is None
    assert [f.rule for f in findings] == ["DET002"]


def test_non_strict_allows_bare_wall_clock_reads():
    findings, _, err = lint_source("benchmarks/x.py", BARE_CLOCK,
                                   strict=False)
    assert err is None
    assert findings == []


# ---------------------------------------------------------------------------
# suppressions

SUPPRESSED = (
    "def f(s: set):\n"
    "    tot = 0.0\n"
    "    for x in s:  # detlint: ignore[DET001] proven exact: int-valued\n"
    "        tot += x\n"
    "    return tot\n"
)


def test_inline_suppression_with_reason_suppresses():
    findings, suppressed, err = lint_source("x.py", SUPPRESSED)
    assert err is None
    assert findings == [] and suppressed == 1


def test_bare_suppression_without_reason_is_malformed():
    src = SUPPRESSED.replace(" proven exact: int-valued", "")
    findings, suppressed, _ = lint_source("x.py", src)
    # the ignore is rejected (DET000) and does NOT silence the finding
    assert {f.rule for f in findings} == {"DET000", "DET001"}
    assert suppressed == 0


def test_wrong_rule_suppression_does_not_silence():
    src = SUPPRESSED.replace("DET001", "DET004")
    findings, suppressed, _ = lint_source("x.py", src)
    assert [f.rule for f in findings] == ["DET001"] and suppressed == 0


# ---------------------------------------------------------------------------
# baseline

def test_baseline_roundtrip_grandfathers_findings(tmp_path, monkeypatch):
    monkeypatch.chdir(REPO)
    result = lint_paths([FIXTURES])
    assert len(result.findings) == len(GOLDEN)
    bl_path = str(tmp_path / "baseline.json")
    write_baseline(bl_path, result.findings)

    again = lint_paths([FIXTURES])
    apply_baseline(again, load_baseline(bl_path))
    assert again.findings == [] and again.baselined == len(GOLDEN)


def test_repo_gate_is_clean(monkeypatch):
    """The CI gate invariant: zero unsuppressed findings over the tree."""
    monkeypatch.chdir(REPO)
    result = lint_paths(["src/repro/core", "src/repro/serving",
                         "benchmarks"])
    assert result.errors == []
    assert [(f.path, f.line, f.rule) for f in result.findings] == []


# ---------------------------------------------------------------------------
# self-determinism: the linter's own output must not depend on the hash seed

def _run_detlint(hashseed):
    env = dict(os.environ, PYTHONHASHSEED=str(hashseed))
    proc = subprocess.run(
        [sys.executable, "-m", "tools.detlint", FIXTURES, "--no-baseline"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    return proc.returncode, proc.stdout, proc.stderr


def test_output_identical_under_arbitrary_hash_seeds():
    rc_a, out_a, err_a = _run_detlint(0)
    rc_b, out_b, err_b = _run_detlint(4242)
    assert rc_a == rc_b == 1          # fixtures carry findings by design
    assert out_a == out_b
    assert err_a == err_b
    assert out_a.count("\n") == len(GOLDEN)
