"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import adaln_rmsnorm as ar
from repro.kernels import flash_attention as fa
from repro.kernels import ops, ref
from repro.kernels import ssm_scan


@pytest.mark.parametrize("b,lq,lkv,h,d", [
    (2, 64, 64, 2, 32), (1, 100, 100, 3, 64), (2, 1, 128, 2, 32),
    (1, 128, 128, 1, 128), (1, 17, 17, 2, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, lq, lkv, h, d, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, lq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, lkv, h, d), dtype)
    v = jax.random.normal(ks[2], (b, lkv, h, d), dtype)
    out = fa.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                             interpret=True)
    want = ops.flash_attention(q, k, v, causal=True, use_kernel=False)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window,softcap,causal", [
    (48, 0.0, True), (0, 50.0, True), (16, 30.0, True), (0, 0.0, False),
])
def test_flash_attention_variants(window, softcap, causal):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    b, l, h, d = 2, 96, 2, 32
    q = jax.random.normal(ks[0], (b, l, h, d))
    k = jax.random.normal(ks[1], (b, l, h, d))
    v = jax.random.normal(ks[2], (b, l, h, d))
    out = fa.flash_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, block_q=32, block_k=32,
                             interpret=True)
    want = ops.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("b,h,l,dk,dv,bonus", [
    (2, 2, 100, 16, 32, False), (1, 3, 64, 32, 32, True),
    (2, 1, 33, 8, 8, True), (1, 2, 16, 64, 64, False),
    (1, 1, 7, 4, 4, True),
])
def test_ssm_scan_vs_sequential(b, h, l, dk, dv, bonus):
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (b, h, l, dk))
    k = jax.random.normal(ks[1], (b, h, l, dk))
    v = jax.random.normal(ks[2], (b, h, l, dv))
    decay = jnp.maximum(jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, h, l, dk)))),
                        np.exp(-ssm_scan.MAX_NEG_LOGW))
    s0 = jax.random.normal(ks[4], (b, h, dk, dv))
    u = jax.random.normal(ks[5], (h, dk)) if bonus else None
    o1, s1 = ssm_scan.ssm_scan(q, k, v, decay, bonus=u, initial_state=s0,
                               interpret=True)
    o2, s2 = ref.linear_scan_ref(q, k, v, decay, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-3, rtol=3e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=3e-3, rtol=3e-3)


def test_chunked_ref_matches_sequential():
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 6)
    b, h, l, dk, dv = 2, 3, 130, 8, 16
    q = jax.random.normal(ks[0], (b, h, l, dk))
    k = jax.random.normal(ks[1], (b, h, l, dk))
    v = jax.random.normal(ks[2], (b, h, l, dv))
    decay = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, l, dk))) * 0.3 + 0.7
    s0 = jax.random.normal(ks[4], (b, h, dk, dv))
    bonus = jax.random.normal(ks[5], (h, dk))
    for bn in (None, bonus):
        o1, s1 = ref.linear_scan_ref(q, k, v, decay, bn, s0)
        o2, s2 = ref.chunked_linear_scan_ref(q, k, v, decay, bn, s0, chunk=32)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("b,l,d,rows", [(2, 100, 64, 32), (1, 7, 128, 256),
                                        (4, 256, 32, 64)])
def test_adaln_rmsnorm(b, l, d, rows):
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (b, l, d), jnp.float32)
    s = jax.random.normal(ks[1], (b, d)) * 0.1
    t = jax.random.normal(ks[2], (b, d)) * 0.1
    out = ar.adaln_rmsnorm(x, s, t, block_rows=rows, interpret=True)
    want = ref.adaln_rmsnorm_ref(x, s, t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_decode_step_matches_scan():
    """Recurrent single-step == one-step full scan (both oracle paths)."""
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 6)
    b, h, dk, dv = 2, 4, 8, 16
    q = jax.random.normal(ks[0], (b, h, dk))
    k = jax.random.normal(ks[1], (b, h, dk))
    v = jax.random.normal(ks[2], (b, h, dv))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, dk)))
    s0 = jax.random.normal(ks[4], (b, h, dk, dv))
    u = jax.random.normal(ks[5], (h, dk))
    o1, s1 = ref.linear_scan_decode_ref(q, k, v, w, s0, u)
    o2, s2 = ref.linear_scan_ref(q[:, :, None], k[:, :, None], v[:, :, None],
                                 w[:, :, None], u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2[:, :, 0]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)
