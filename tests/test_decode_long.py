"""Long-horizon decode properties: ring caches must stay exact past the
window/chunk capacity, and SSM state must carry arbitrarily far."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as tf

# long-horizon stress sweeps (~2 min total): excluded from the tier-1 fast
# subset; `pytest -m slow` / `-m ""` runs them
pytestmark = pytest.mark.slow


def _roll(cfg, params, toks, steps, max_len):
    """Greedy-free teacher-forced decode: feed toks one by one, collect
    logits, compare to the full forward at each horizon."""
    b = toks.shape[0]
    _, cache, off = tf.prefill(cfg, params, toks[:, :1], max_len=max_len)
    outs = []
    for i in range(1, steps):
        lg, cache = tf.decode_step(cfg, params, toks[:, i:i + 1], cache, off)
        off = off + 1
        outs.append(lg[:, 0])
    return jnp.stack(outs, axis=1)  # (B, steps-1, V)


@pytest.mark.parametrize("arch,window", [
    ("starcoder2-15b", 6),    # sliding window smaller than the horizon
    ("gemma2-9b", 6),         # local+global alternation
    ("llama4-maverick-400b-a17b", 8),   # chunked-local + global
])
def test_ring_cache_exact_past_capacity(arch, window):
    cfg = dataclasses.replace(C.get_smoke(arch), window_size=window,
                              chunk_size=window, capacity_factor=8.0)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    b, steps = 2, 3 * window  # decode far beyond the ring capacity
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, steps), 0,
                              cfg.vocab_size)
    got = _roll(cfg, params, toks, steps, max_len=steps + 4)
    want, _ = tf.forward(cfg, params, toks)
    want = want[:, 1:]  # decode after feeding token i == forward position i
    rel = (np.abs(np.asarray(got) - np.asarray(want)).max()
           / (np.abs(np.asarray(want)).max() + 1e-9))
    assert rel < 3e-2, f"{arch}: ring-cache divergence {rel}"


@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-1.2b"])
def test_ssm_state_carries_far(arch):
    cfg = C.get_smoke(arch)
    params = tf.init(cfg, jax.random.PRNGKey(0))
    b, steps = 1, 40
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, steps), 0,
                              cfg.vocab_size)
    got = _roll(cfg, params, toks, steps, max_len=steps + 4)
    want, _ = tf.forward(cfg, params, toks)
    want = want[:, 1:]
    rel = (np.abs(np.asarray(got) - np.asarray(want)).max()
           / (np.abs(np.asarray(want)).max() + 1e-9))
    assert rel < 3e-2, f"{arch}: state-carry divergence {rel}"


def test_decode_state_is_o1_for_ssm():
    """rwkv6 decode cache size is independent of history length."""
    cfg = C.get_smoke("rwkv6-3b")
    c1 = jax.eval_shape(lambda: tf.init_cache(cfg, 2, 64))
    c2 = jax.eval_shape(lambda: tf.init_cache(cfg, 2, 65536))
    n1 = sum(x.size for x in jax.tree_util.tree_leaves(c1))
    n2 = sum(x.size for x in jax.tree_util.tree_leaves(c2))
    assert n1 == n2


def test_window_cache_is_bounded():
    cfg = C.get_smoke("starcoder2-15b")  # window 16 in smoke
    small = jax.eval_shape(lambda: tf.init_cache(cfg, 2, 32))
    big = jax.eval_shape(lambda: tf.init_cache(cfg, 2, 1 << 16))
    nb = sum(x.size for x in jax.tree_util.tree_leaves(big))
    ns = sum(x.size for x in jax.tree_util.tree_leaves(small))
    assert nb <= ns * (cfg.window_size / 16 + 1)
