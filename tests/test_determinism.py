"""Byte-exactness parity under arbitrary PYTHONHASHSEED.

PR 4 made the committed BENCH baselines hash-seed deterministic by sorting
every float-accumulating str-set iteration; the predictive re-partitioning
subsystem adds a new wake source (forecast bins), a forecaster, and a
pre-warm staging path — all of which must preserve both properties:

* **off path**: with predictive off, re-running the ``--mixed --shared``
  and ``--lending`` scenarios reproduces the committed
  ``BENCH_shared_cluster.json`` / ``BENCH_unit_lending.json`` byte-for-byte
  (slow tests, run nightly), under an arbitrary hash seed;
* **on path**: the predictive scheduler itself is hash-seed deterministic —
  two subprocesses with different ``PYTHONHASHSEED`` values produce
  identical trajectories (fast tests).
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# two arbitrary, different hash seeds; str hashing (set iteration order)
# differs between them, which is exactly what must not leak into results
HASH_SEEDS = ("1", "31337")

_SCENARIO_DRIVER = r"""
import json, sys
from repro.core import workloads
from repro.core.fleet import FleetConfig, run_fleet
p = json.load(sys.stdin)
phases = [tuple(x) for x in p["phases"]] if p["phases"] else None
res = run_fleet(p["pipelines"], mode=p["mode"], duration=p["duration"],
                cfg=FleetConfig(**p["cfg"]), rates=p["rates"],
                phases=phases, seed=p["seed"])
out = {
    "slo": res.slo_attainment, "mean": res.mean_latency,
    "p95": res.p95_latency, "fin": res.n_finished,
    "wakeups": res.sched_wakeups, "swap_cost": res.swap_cost_s,
    "repartitions": res.repartitions, "per_pipeline": res.per_pipeline,
    "loans": res.loans, "borrowed_s": res.borrowed_unit_seconds,
    "prewarm": [res.prewarm_units, res.prewarm_cost_s, res.prewarm_hits,
                res.prewarm_loan_returns, res.predictive_repartitions],
}
print(json.dumps(out, sort_keys=True))
"""


def _run_scenario(payload, hash_seed):
    env = dict(os.environ, PYTHONHASHSEED=hash_seed,
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", _SCENARIO_DRIVER],
                         input=json.dumps(payload), capture_output=True,
                         text=True, cwd=REPO, timeout=1200, check=True,
                         env=env)
    return out.stdout.strip().splitlines()[-1]


def _payload(mode, **kw):
    base = dict(pipelines=["sd3", "cogvideox"], mode=mode, duration=240.0,
                seed=0, rates={"sd3": 10.0, "cogvideox": 0.4}, phases=None,
                cfg=dict(num_chips=64, t_win=60.0, cooldown=40.0))
    base.update(kw)
    return base


def test_predictive_run_is_hash_seed_deterministic():
    """The new wake source + forecaster + pre-warm path: identical results
    under different PYTHONHASHSEED values (every iteration that feeds a
    float accumulation or a threshold comparison must be sorted)."""
    from repro.core import workloads
    payload = _payload(
        "predictive",
        phases=[list(x) for x in workloads.diurnal_phases(n_periods=3)],
        cfg=dict(num_chips=64, t_win=60.0, cooldown=40.0,
                 forecast_bin=5.0, forecast_history=160.0,
                 forecast_horizon=80.0, prewarm_lead=16.0,
                 prewarm_cooldown=20.0, prewarm_ttl=60.0,
                 forecast_grace=20.0))
    a = _run_scenario(payload, HASH_SEEDS[0])
    b = _run_scenario(payload, HASH_SEEDS[1])
    assert a == b


def test_lending_run_is_hash_seed_deterministic():
    """The lending path (force-returns now also reachable from pre-warm)
    stays hash-seed deterministic."""
    from repro.core import workloads
    payload = _payload(
        "adaptive",
        phases=[list(x) for x in workloads.bursty_ec_phases(240.0)],
        rates=dict(workloads.LENDING_RATES),
        cfg=dict(num_chips=64, t_win=60.0, cooldown=40.0, lending=True))
    a = _run_scenario(payload, HASH_SEEDS[0])
    b = _run_scenario(payload, HASH_SEEDS[1])
    assert a == b


# -- committed-baseline byte reproduction (nightly: the full scenarios) --------

_BENCH_DRIVER = r"""
import json, sys
from benchmarks import e2e
p = json.load(sys.stdin)
if p["kind"] == "shared":
    e2e.run_mixed_shared(quick=True, bench_path=p["out"])
elif p["kind"] == "lending":
    e2e.run_lending(quick=True, bench_path=p["out"])
else:
    raise SystemExit(2)
print("done")
"""


def _rerun_bench(kind, out_path, hash_seed):
    env = dict(os.environ, PYTHONHASHSEED=hash_seed,
               PYTHONPATH=os.path.join(REPO, "src"))
    subprocess.run([sys.executable, "-c", _BENCH_DRIVER],
                   input=json.dumps({"kind": kind, "out": str(out_path)}),
                   capture_output=True, text=True, cwd=REPO, timeout=3600,
                   check=True, env=env)


@pytest.mark.slow
@pytest.mark.parametrize("kind,baseline", [
    ("shared", "BENCH_shared_cluster.json"),
    ("lending", "BENCH_unit_lending.json"),
])
def test_committed_bench_reproduces_byte_for_byte(tmp_path, kind, baseline):
    """With predictive off (it is not part of these scenarios), re-running
    the committed shared-cluster / unit-lending benches reproduces the
    committed JSON *byte-for-byte* — under an arbitrary PYTHONHASHSEED.
    This is the off-path contract the new wake source must not disturb."""
    out = tmp_path / baseline
    _rerun_bench(kind, out, HASH_SEEDS[1])
    with open(os.path.join(REPO, baseline), "rb") as f:
        committed = f.read()
    assert out.read_bytes() == committed
