"""Shared-cluster co-serving (core/fleet.py): one placement plan for
heterogeneous pipelines over one chip pool.

Covers: registry/plan/budget invariants, the 1-pipeline special case
(bit-identical to Simulator + TridentScheduler), mix-shift detection with
hysteresis, re-partition weight-swap accounting, and the headline behavior
— the adaptive fleet beats static sub-clusters under a traffic-mix flip.
"""
import pytest

import repro.configs as C
from repro.core import workloads
from repro.core.monitor import FleetMonitor
from repro.core.profiler import Profiler
from repro.core.simulator import SimConfig, Simulator
from repro.core.trident import TridentScheduler
from repro.core.fleet import (FleetConfig, FleetOrchestrator, PipelineRegistry,
                              run_fleet)

FLIP = ((0.5, {"sd3": 1.5, "flux": 0.3}),
        (1.0, {"sd3": 0.3, "flux": 2.0}))
RATES = {"sd3": 10.0, "flux": 1.0}


@pytest.fixture(scope="module")
def registry():
    return PipelineRegistry(("sd3", "flux"))


def small_cfg(**kw):
    base = dict(num_chips=128, t_win=60.0, cooldown=40.0)
    base.update(kw)
    return FleetConfig(**base)


# -- registry / plan / budgets -----------------------------------------------

def test_registry_holds_one_profiler_per_pipeline(registry):
    assert registry.pipelines == ("sd3", "flux")
    assert len(registry) == 2
    assert "sd3" in registry and "hunyuanvideo" not in registry
    assert registry.profiler("flux").cfg.name == "flux"


def test_budgets_node_quantized_floored_and_exact(registry):
    orch = FleetOrchestrator(registry, num_chips=128, chips_per_node=8)
    for weights in ({"sd3": 3.0, "flux": 1.0},
                    {"sd3": 1.0, "flux": 0.0},      # zero-demand pipeline
                    {"sd3": 0.0, "flux": 0.0}):     # no demand at all
        budgets = orch.budgets(weights)
        assert sum(budgets.values()) == 128
        for pid, chips in budgets.items():
            assert chips % 8 == 0
            assert chips >= 8, f"{pid} lost its floor node: {budgets}"


def test_fleet_plan_units_are_pipeline_tagged(registry):
    orch = FleetOrchestrator(registry, num_chips=128)
    budgets = orch.budgets({"sd3": 2.0, "flux": 1.0})
    plan = orch.generate({}, budgets)
    assert plan is not None
    assert plan.budget_histogram() == budgets
    # contiguous, disjoint, exhaustive chip ranges
    spans = sorted(plan.chip_ranges.values())
    assert spans[0][0] == 0 and spans[-1][1] == 128
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c
    for pid, sub in plan.subplans.items():
        assert sub.pipeline == pid
        prof = registry.profiler(pid)
        assert sub.num_units * sub.unit_size == plan.budget_histogram()[pid]
        assert sub.unit_size == prof.k_min
        for s in "EDC":
            assert sub.units_with(s), f"{pid}: no unit hosts stage {s}"
        assert sub.tagged(0) == (pid, sub.placements[0])
    tags = plan.tagged_units()
    assert {t[0] for t in tags} == {"sd3", "flux"}


# -- 1-pipeline special case --------------------------------------------------

def test_single_pipeline_fleet_matches_simulator():
    """A fleet with one registered pipeline must reproduce the plain
    Simulator + TridentScheduler results exactly — the single-pipeline
    system is the fleet's 1-pipeline special case."""
    prof = Profiler(C.get("sd3"))
    t1 = workloads.make_trace("sd3", "medium", 45.0, prof, seed=3)
    t2 = workloads.make_trace("sd3", "medium", 45.0, prof, seed=3)
    cfg = SimConfig(num_chips=128)
    base = Simulator("sd3", TridentScheduler(prof, cfg, t1), t1, cfg).run()
    fleet = run_fleet(["sd3"], mode="static",
                      cfg=FleetConfig(num_chips=128, adaptive_idle_gap=False,
                                      aggregate_ilp=False),
                      trace=t2)
    assert fleet.slo_attainment == base.slo_attainment
    assert fleet.mean_latency == base.mean_latency
    assert fleet.p95_latency == base.p95_latency
    assert fleet.n_finished == base.n_finished
    assert fleet.sched_wakeups == base.sched_wakeups
    assert fleet.repartitions[1:] == []          # static never moves


# -- multi-lane event/tick parity on the unified kernel ------------------------

@pytest.mark.parametrize("seed", (0, 3))
@pytest.mark.parametrize("mode", ("static", "proportional", "adaptive",
                                  "predictive"))
def test_fleet_event_clock_matches_tick_clock(mode, seed):
    """The multi-lane extension of the 1-pipeline bit-identical check:
    with both simulators driving the one event-clock kernel
    (repro.core.clock), the fleet inherits the tick reference loop for
    free — on randomized mix-tilt traces, every fleet scheduler must
    reproduce the tick clock's results exactly while waking far less.
    ``scheduler_wake_hooks`` registers the re-partition trigger crossings
    (window cadence / cooldown expiry) as wake sources, so the event clock
    sees them at the same grid point the tick clock does.  The
    ``predictive`` scheduler runs the periodic scenario variant on a
    longer trace, with the forecast bins grid-aligned — its fits and
    staging move only at bin boundaries, which both clocks visit exactly
    (the forecast wake source), so its whole forecast → pre-warm →
    predictive-fire trajectory must be identical too."""
    predictive = mode == "predictive"
    rates, phases = workloads.randomized_fleet_scenario(
        seed, periods=3 if predictive else 1)
    duration = 240.0 if predictive else 90.0
    extra = (dict(forecast_bin=2.0, forecast_history=160.0,
                  forecast_horizon=80.0, prewarm_lead=16.0,
                  prewarm_cooldown=20.0, prewarm_ttl=60.0,
                  forecast_grace=20.0) if predictive else {})
    results = {}
    for clock_mode in ("event", "tick"):
        # heartbeat pinned to the tick grid: while work is pending the two
        # clocks visit identical grid points, so the only skipped wake-ups
        # are provably no-ops (nothing pending, nothing completing) — the
        # regime where parity is exact by construction, for ANY seed
        cfg = small_cfg(mode=clock_mode, adaptive_idle_gap=False,
                        max_idle_gap=0.25, scheduler_wake_hooks=True,
                        **extra)
        results[clock_mode] = run_fleet(["sd3", "flux"], mode=mode,
                                        duration=duration, cfg=cfg,
                                        seed=seed, rates=rates,
                                        phases=phases)
    ev, tk = results["event"], results["tick"]
    assert ev.slo_attainment == tk.slo_attainment
    assert ev.n_finished == tk.n_finished and ev.n_requests == tk.n_requests
    for a, b in ((tk.mean_latency, ev.mean_latency),
                 (tk.p95_latency, ev.p95_latency)):
        assert abs(a - b) <= 1e-6 * max(1.0, abs(a)), (a, b)
    assert ev.repartitions == tk.repartitions
    assert ev.per_pipeline == tk.per_pipeline
    if predictive:
        assert ev.prewarm_units == tk.prewarm_units
        assert ev.prewarm_cost_s == tk.prewarm_cost_s
        assert ev.prewarm_hits == tk.prewarm_hits
        assert ev.predictive_repartitions == tk.predictive_repartitions
    # hot randomized traces keep most grid points busy, so the saving is
    # scenario-dependent — strictly fewer is the invariant worth pinning
    assert ev.sched_wakeups < tk.sched_wakeups


# -- SLO-weighted budget objective ---------------------------------------------

def test_slo_weighted_budgets_skew_toward_the_missing_pipeline(registry):
    """``FleetConfig.budget_objective="slo"``: equal demand, skewed SLO
    attainment — the missing pipeline must get more chips than under the
    pure-demand objective; the default objective is inert (same object,
    bit-identical off)."""
    orch = FleetOrchestrator(registry, num_chips=128, chips_per_node=8)
    weights = {"sd3": 2.0, "flux": 2.0}
    even = orch.budgets(weights)
    skewed = orch.objective_weights(weights, {"sd3": 1.0, "flux": 0.5},
                                    objective="slo")
    budgets = orch.budgets(skewed)
    assert sum(budgets.values()) == 128
    assert budgets["flux"] > even["flux"]
    # inert paths: default objective, no evidence, perfect attainment
    assert orch.objective_weights(weights, {"flux": 0.0}) is weights
    assert orch.objective_weights(weights, {}, objective="slo") is weights
    assert orch.objective_weights(weights, {"sd3": 1.0, "flux": 1.0},
                                  objective="slo") == weights


def test_slo_objective_fleet_run_still_converges():
    """End-to-end sanity on the two-pipeline skew case: the slo objective
    re-partitions on the flip like the demand objective does, and never
    hands the flipped-to (SLO-missing) pipeline fewer chips."""
    demand = run_fleet(["sd3", "flux"], mode="adaptive", duration=120.0,
                       cfg=small_cfg(), rates=RATES, phases=FLIP)
    slo = run_fleet(["sd3", "flux"], mode="adaptive", duration=120.0,
                    cfg=small_cfg(budget_objective="slo"),
                    rates=RATES, phases=FLIP)
    assert not slo.oom and slo.n_requests == demand.n_requests
    assert len(slo.repartitions) > 1
    assert (slo.repartitions[-1][1]["flux"]
            >= demand.repartitions[-1][1]["flux"])


# -- mix-shift monitor ---------------------------------------------------------

def test_fleet_monitor_mix_shift_hysteresis_and_cooldown():
    mon = FleetMonitor(t_win=100.0)
    mon.last_repartition = 0.0
    for i in range(40):
        mon.record_arrival(10.0 + i, "sd3", 3.0)
        mon.record_arrival(10.0 + i, "flux", 1.0)
    shares = mon.demand_shares(50.0)
    assert abs(shares["sd3"] - 0.75) < 1e-9
    basis = dict(shares)
    # same mix: below the hysteresis threshold -> no trigger
    assert not mon.mix_shift(200.0, basis, threshold=0.1, cooldown=60.0)
    # mix flips hard
    for i in range(60):
        mon.record_arrival(150.0 + i, "flux", 10.0)
    assert mon.mix_shift(210.0, basis, threshold=0.1, cooldown=60.0)
    # ...but not inside the cooldown window
    mon.last_repartition = 205.0
    assert not mon.mix_shift(210.0, basis, threshold=0.1, cooldown=60.0)
    # nor against an already-updated basis
    mon.last_repartition = 0.0
    new_basis = mon.demand_shares(210.0)
    assert not mon.mix_shift(210.0, new_basis, threshold=0.1, cooldown=60.0)


def test_fleet_monitor_windows_slide():
    mon = FleetMonitor(t_win=50.0)
    mon.record_arrival(0.0, "sd3", 5.0)
    mon.record_finish(1.0, "sd3", True)
    mon.record_finish(2.0, "sd3", False)
    assert mon.slo_attainment(10.0)["sd3"] == 0.5
    assert mon.next_window_boundary() == 50.0
    mon.record_arrival(100.0, "flux", 2.0)   # slides the old samples out
    assert "sd3" not in mon.demand_shares(100.0)
    assert mon.slo_attainment(100.0) == {}


# -- co-serving behavior -------------------------------------------------------

@pytest.fixture(scope="module")
def flip_results():
    out = {}
    for mode in ("static", "adaptive"):
        out[mode] = run_fleet(["sd3", "flux"], mode=mode, duration=120.0,
                              cfg=small_cfg(), rates=RATES, phases=FLIP)
    return out


def test_adaptive_beats_static_on_mix_flip(flip_results):
    """The tentpole claim at test scale: when the traffic mix flips
    mid-trace, re-partitioning the shared pool beats static sub-clusters
    on tail latency and SLO attainment."""
    st, ad = flip_results["static"], flip_results["adaptive"]
    assert not st.oom and not ad.oom
    assert st.n_requests == ad.n_requests   # identical arrivals (same seed)
    assert len(ad.repartitions) > 1         # it actually moved chips
    assert len(st.repartitions) == 1        # static never did
    assert ad.p95_latency < st.p95_latency
    assert ad.slo_attainment >= st.slo_attainment
    # the flipped-to pipeline is where the win comes from
    assert (ad.per_pipeline["flux"]["p95_s"]
            < st.per_pipeline["flux"]["p95_s"])


def test_repartition_charges_weight_swap_cost(flip_results):
    ad = flip_results["adaptive"]
    assert ad.units_reloaded > 0
    assert ad.swap_cost_s > 0.0
    # engine counters survive the engine swaps: the adaptive run's banked
    # totals must cover the whole trace, not just the post-swap stretch —
    # the static run (one engine, never retired) is the reference
    st = flip_results["static"]
    ad_disp = sum(s["dispatches"] for s in ad.engine_stats.values())
    st_disp = sum(s["dispatches"] for s in st.engine_stats.values())
    assert ad_disp > 0.7 * st_disp


def test_aborted_repartition_keeps_trigger_armed(monkeypatch):
    """If the re-partition's plan generation fails, the mix-shift trigger
    must stay armed (the demand basis only moves when a swap succeeds) —
    the fleet retries and eventually moves the chips."""
    from repro.core import fleet as fleet_mod
    calls = {"n": 0}
    orig = fleet_mod.FleetOrchestrator.generate

    def flaky(self, recent, budgets, measured=None):
        calls["n"] += 1
        if 2 <= calls["n"] <= 3:   # abort the first re-partition attempts
            return None
        return orig(self, recent, budgets, measured)

    monkeypatch.setattr(fleet_mod.FleetOrchestrator, "generate", flaky)
    res = run_fleet(["sd3", "flux"], mode="adaptive", duration=120.0,
                    cfg=small_cfg(), rates=RATES, phases=FLIP)
    assert calls["n"] > 3              # kept retrying past the aborts
    assert len(res.repartitions) > 1   # and the swap eventually landed


def test_adaptive_holds_still_on_steady_mix():
    """Hysteresis: steady traffic (no flip) must not trigger re-partitions
    — the weight-swap cost is never paid on noise."""
    res = run_fleet(["sd3", "flux"], mode="adaptive", duration=120.0,
                    cfg=small_cfg(), rates=RATES, phases=None)
    assert len(res.repartitions) == 1
    assert res.swap_cost_s == 0.0


def test_adaptive_reacts_faster_than_window_cadence():
    """The proportional baseline only re-partitions on window boundaries;
    the adaptive fleet fires as soon as the monitored shares cross the
    hysteresis threshold — so after a mid-trace flip it moves chips no
    later, and both converge toward the flipped demand."""
    prop = run_fleet(["sd3", "flux"], mode="proportional", duration=120.0,
                     cfg=small_cfg(), rates=RATES, phases=FLIP)
    ad = run_fleet(["sd3", "flux"], mode="adaptive", duration=120.0,
                   cfg=small_cfg(), rates=RATES, phases=FLIP)
    assert len(prop.repartitions) > 1 and len(ad.repartitions) > 1
    first_prop = prop.repartitions[1][0]
    first_ad = ad.repartitions[1][0]
    assert first_ad <= first_prop
    # both end with the majority of chips on the flipped-to pipeline
    assert prop.repartitions[-1][1]["flux"] > prop.repartitions[0][1]["flux"]
    assert ad.repartitions[-1][1]["flux"] > ad.repartitions[0][1]["flux"]


def test_lending_off_path_is_bit_identical():
    """PR-2 parity: with lending disabled (the default), every lending knob
    must be inert — results are bit-identical no matter how the lending
    fields are set, and no lending state is created.  (The committed
    ``BENCH_shared_cluster.json`` pins the same property at bench scale:
    re-running ``--mixed --shared`` on this tree reproduces it byte-for-
    byte.)"""
    a = run_fleet(["sd3", "flux"], mode="adaptive", duration=120.0,
                  cfg=small_cfg(), rates=RATES, phases=FLIP)
    b = run_fleet(["sd3", "flux"], mode="adaptive", duration=120.0,
                  cfg=small_cfg(lending=False, lend_max_loans=1,
                                lend_min_hold=1.0, lend_win=5.0,
                                lend_util_target=0.9,
                                idle_window_wakeups=False),
                  rates=RATES, phases=FLIP)
    assert a.slo_attainment == b.slo_attainment
    assert a.mean_latency == b.mean_latency
    assert a.p95_latency == b.p95_latency
    assert a.sched_wakeups == b.sched_wakeups
    assert a.repartitions == b.repartitions
    assert a.per_pipeline == b.per_pipeline
    assert b.loans == 0 and b.borrowed_unit_seconds == 0.0


def test_fleet_trace_is_deterministic_and_tagged():
    profs = {p: Profiler(C.get(p)) for p in ("sd3", "flux")}
    a = workloads.fleet_trace(["sd3", "flux"], 60.0, profs, seed=5,
                              rates=RATES, phases=FLIP)
    b = workloads.fleet_trace(["sd3", "flux"], 60.0, profs, seed=5,
                              rates=RATES, phases=FLIP)
    assert [(r.pipeline, r.resolution, r.arrival) for r in a] \
        == [(r.pipeline, r.resolution, r.arrival) for r in b]
    assert {r.pipeline for r in a} == {"sd3", "flux"}
    assert all(a[i].arrival <= a[i + 1].arrival for i in range(len(a) - 1))
    # adding a pipeline never perturbs the existing streams
    profs3 = dict(profs, cogvideox=Profiler(C.get("cogvideox")))
    c = workloads.fleet_trace(["sd3", "flux", "cogvideox"], 60.0, profs3,
                              seed=5, rates=dict(RATES, cogvideox=0.5),
                              phases=FLIP)
    sd3_a = [(r.resolution, r.arrival) for r in a if r.pipeline == "sd3"]
    sd3_c = [(r.resolution, r.arrival) for r in c if r.pipeline == "sd3"]
    assert sd3_a == sd3_c
