"""Scale-out fast-path parity (ISSUE 9): the flag-gated hot paths behind
``benchmarks/e2e.py --scale`` must not change what the simulator computes.

Three flags, three contracts:

* ``array_state`` — array-backed PendingSet/Monitor columns are
  **bit-identical by construction** (stable argsort, same-order
  incremental sums): full-result equality on single-lane and fleet runs.
* ``incremental_ilp`` — signature reuse is exact whenever the previous
  solve proved optimality; the dense-DP fast path returns a true optimum
  where a node-capped DFS may return an improvable incumbent, so whole-run
  equality is *modulo equal-reward tie reordering*: every deterministic
  headline metric must match exactly, per-pipeline latency percentiles
  within a small tolerance, and the run must actually reuse solves.
* ``step_changed_lanes_only`` — documented trajectory-changing (idle lanes
  skip backlog samples): the contract is determinism plus conservation
  (same requests, all finished both ways) and headline-metric sanity.

Plus the solver-level pin: the DP fast path's reward equals the
branch-and-bound's proven optimum on randomized single-dimension
instances.
"""
import dataclasses
import json
import os
import random
import subprocess
import sys

import pytest

from repro.core import ilp, workloads
from repro.core.fleet import FleetConfig, run_fleet
from repro.core.simulator import SimConfig, run_sim
from repro.core.trident import TridentScheduler


def _fleet(seed, **kw):
    rates, phases = workloads.randomized_fleet_scenario(seed)
    cfg = FleetConfig(num_chips=128, t_win=60.0, cooldown=40.0, **kw)
    return run_fleet(["sd3", "flux"], mode="adaptive", duration=90.0,
                     cfg=cfg, seed=seed, rates=rates, phases=phases)


def _strip_reuses(d):
    out = dict(d)
    out["engine_stats"] = {k: {kk: vv for kk, vv in v.items()
                               if kk != "ilp_reuses"}
                           for k, v in d["engine_stats"].items()}
    return out


# -- array_state: bit-exact ---------------------------------------------------

@pytest.mark.parametrize("seed", (0, 3, 7))
def test_array_state_fleet_bit_exact(seed):
    a = dataclasses.asdict(_fleet(seed))
    b = dataclasses.asdict(_fleet(seed, array_state=True))
    assert a == b


@pytest.mark.parametrize("workload", ("light", "medium"))
def test_array_state_single_lane_bit_exact(workload):
    a = run_sim("sd3", TridentScheduler, workload, 45.0,
                sim_cfg=SimConfig(num_chips=128), seed=2)
    b = run_sim("sd3", TridentScheduler, workload, 45.0,
                sim_cfg=SimConfig(num_chips=128, array_state=True), seed=2)
    assert dataclasses.asdict(a) == dataclasses.asdict(b)


# the strong form of the array_state contract: with the flag forced ON,
# re-running the committed shared-cluster scenario reproduces
# BENCH_shared_cluster.json *byte-for-byte* (the file has no wall-clock
# fields).  Nightly, like the other committed-baseline reproductions.

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SHARED_DRIVER = r"""
import json, sys
from benchmarks import e2e
p = json.load(sys.stdin)
e2e.run_mixed_shared(quick=True, bench_path=p["out"],
                     fleet_cfg_kw={"array_state": True})
print("done")
"""


@pytest.mark.slow
def test_array_state_reproduces_committed_shared_bench(tmp_path):
    out = tmp_path / "BENCH_shared_cluster.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(_REPO, "src"))
    subprocess.run([sys.executable, "-c", _SHARED_DRIVER],
                   input=json.dumps({"out": str(out)}), capture_output=True,
                   text=True, cwd=_REPO, timeout=3600, check=True, env=env)
    with open(os.path.join(_REPO, "BENCH_shared_cluster.json"), "rb") as f:
        committed = f.read()
    assert out.read_bytes() == committed


# -- incremental_ilp: exact modulo equal-reward ties --------------------------

@pytest.mark.parametrize("seed", (0, 3, 7))
def test_incremental_ilp_headline_parity(seed):
    a = _fleet(seed)
    c = _fleet(seed, incremental_ilp=True)
    # deterministic headline metrics must be unaffected by solve reuse and
    # the DP fast path (equal-reward solutions grant the same totals)
    assert c.n_requests == a.n_requests
    assert c.n_finished == a.n_finished
    assert c.slo_attainment == a.slo_attainment
    assert c.goodput == a.goodput
    assert c.sched_wakeups == a.sched_wakeups
    assert c.repartitions == a.repartitions
    for pid, pa in a.per_pipeline.items():
        pc = c.per_pipeline[pid]
        for k in ("requests", "finished", "on_time", "slo", "chips"):
            assert pc[k] == pa[k], (pid, k)
        # equal-reward tie reordering can shuffle which request lands in
        # which batch; latency summaries stay within a small band
        for k in ("mean_s", "p95_s"):
            assert pc[k] == pytest.approx(pa[k], rel=0.05), (pid, k)
    # the flag must actually have reused solves on a steady trace
    reuses = sum(v.get("ilp_reuses", 0) for v in c.engine_stats.values())
    assert reuses > 0
    base_reuses = sum(v.get("ilp_reuses", 0)
                      for v in a.engine_stats.values())
    assert base_reuses == 0


def test_incremental_ilp_run_is_deterministic():
    a = dataclasses.asdict(_fleet(0, incremental_ilp=True))
    b = dataclasses.asdict(_fleet(0, incremental_ilp=True))
    assert a == b


# -- step_changed_lanes_only: determinism + conservation ----------------------

@pytest.mark.parametrize("seed", (0, 3, 7))
def test_lane_gating_conserves_requests(seed):
    a = _fleet(seed)
    d = _fleet(seed, step_changed_lanes_only=True)
    assert d.n_requests == a.n_requests
    assert d.n_finished == a.n_finished
    assert d.slo_attainment == pytest.approx(a.slo_attainment, abs=0.02)
    # gating skips only no-op lane steps, never scheduler wake-ups
    assert d.sched_wakeups == a.sched_wakeups


def test_lane_gating_run_is_deterministic():
    a = dataclasses.asdict(_fleet(3, step_changed_lanes_only=True))
    b = dataclasses.asdict(_fleet(3, step_changed_lanes_only=True))
    assert a == b


def test_all_fast_paths_together_conserve_requests():
    a = _fleet(3)
    f = _fleet(3, array_state=True, incremental_ilp=True,
               step_changed_lanes_only=True)
    assert f.n_requests == a.n_requests
    assert f.n_finished == a.n_finished
    assert f.slo_attainment == pytest.approx(a.slo_attainment, abs=0.02)


# -- DP fast path == proven DFS optimum on single-dim instances ---------------

def _random_single_dim_instance(rng):
    n = rng.randint(1, 10)
    dim = rng.randint(0, 2)
    budgets = [0, 0, 0]
    budgets[dim] = rng.randint(1, 60)
    options = []
    for _ in range(n):
        opts = [ilp.Option(dim=dim, usage=rng.randint(1, 12),
                           reward=rng.uniform(0.1, 10.0))
                for _ in range(rng.randint(0, 3))]
        options.append(opts)
    return options, budgets


@pytest.mark.parametrize("seed", range(8))
def test_dp_reward_matches_dfs_optimum(seed):
    rng = random.Random(seed)
    for _ in range(40):
        options, budgets = _random_single_dim_instance(rng)
        dfs = ilp.solve(options, budgets, time_cap=1.0)
        dp = ilp.solve(options, budgets, time_cap=1.0, dp=True)
        assert dfs.optimal, "instance too big for a proven optimum"
        assert dp.reward == pytest.approx(dfs.reward, rel=1e-12)
        surviving = any(o.usage <= budgets[o.dim]
                        for opts in options for o in opts)
        if surviving:
            assert dp.optimal and dp.nodes == 0, "DP path not taken"
        # DP choices must themselves be a feasible solution
        used = sum(o.usage for o in dp.choices.values())
        assert used <= max(budgets)


def test_unconstrained_shortcut_picks_per_request_argmax():
    # every dim slack -> each request takes its first-listed best option
    options = [[ilp.Option(dim=0, usage=2, reward=1.0),
                ilp.Option(dim=0, usage=1, reward=1.0)],   # tie: first wins
               [ilp.Option(dim=1, usage=2, reward=3.0)]]
    sol = ilp.solve(options, [4, 4, 0], time_cap=1.0, dp=True)
    assert sol.optimal and sol.nodes == 0
    assert sol.reward == pytest.approx(4.0)
    assert sol.choices[0].usage == 2       # first-listed tie-break


def test_dp_decomposes_per_dim_and_declines_coupled_instances():
    # constrained but per-request single-dim: decomposes into independent
    # knapsacks (dim 0 must drop the 0.9 request; dim 1 keeps its one)
    options = [[ilp.Option(dim=0, usage=2, reward=1.0)],
               [ilp.Option(dim=0, usage=2, reward=0.9)],
               [ilp.Option(dim=1, usage=2, reward=1.0)]]
    sol = ilp.solve(options, [2, 2, 0], time_cap=1.0, dp=True)
    assert sol.optimal and sol.nodes == 0  # DP decomposition path
    assert sol.reward == pytest.approx(2.0)
    assert set(sol.choices) == {0, 2}
    # a request whose options straddle dims couples the instance -> DFS
    coupled = [[ilp.Option(dim=0, usage=2, reward=1.0),
                ilp.Option(dim=1, usage=2, reward=0.8)],
               [ilp.Option(dim=0, usage=2, reward=0.9)]]
    sol = ilp.solve(coupled, [2, 2, 0], time_cap=1.0, dp=True)
    assert sol.optimal and sol.nodes > 0   # fell through to the DFS
    assert sol.reward == pytest.approx(1.7)  # r0 -> dim1, r1 -> dim0


# -- scale trace: deterministic and correctly aliased -------------------------

def test_scale_trace_is_deterministic_and_aliased():
    from repro.core.fleet import PipelineRegistry
    reg = PipelineRegistry()
    for pid in workloads.SCALE_PIPELINES:
        if pid not in workloads.SCALE_ALIASES:
            reg.register(pid)
    for alias, base in workloads.SCALE_ALIASES.items():
        reg.register(alias, profiler=reg.profiler(base))
    profs = {pid: reg.profiler(pid) for pid in workloads.SCALE_PIPELINES}
    dur = workloads.scale_duration(2000, num_chips=512)
    t1 = workloads.scale_trace(dur, profs, seed=0, num_chips=512)
    t2 = workloads.scale_trace(dur, profs, seed=0, num_chips=512)
    assert len(t1) == len(t2) > 0
    assert ([(r.pipeline, r.arrival, r.resolution, r.seconds) for r in t1]
            == [(r.pipeline, r.arrival, r.resolution, r.seconds)
                for r in t2])
    pids = {r.pipeline for r in t1}
    assert pids == set(workloads.SCALE_PIPELINES)
    # arrivals are sorted — the fleet clock requires a time-ordered trace
    arr = [r.arrival for r in t1]
    assert arr == sorted(arr)


# -- elastic capacity events under the fast paths (ISSUE 10 satellites) -------

def _elastic_fleet(seed, **kw):
    sched = workloads.preemption_storm_schedule(240.0, 64, seed=0,
                                                n_storms=1)
    cfg = FleetConfig(num_chips=64, t_win=80.0, cooldown=60.0, elastic=True,
                      elastic_schedule=sched, **kw)
    return run_fleet(["sd3", "flux"], mode="adaptive", duration=240.0,
                     cfg=cfg, seed=seed, rates={"sd3": 5.0, "flux": 1.0})


def test_lane_gating_sees_capacity_events():
    """Satellite fix: capacity and lending events mutate a lane with no
    completion to show for it — ``step_changed_lanes_only`` must treat
    them as dirty (``mark_lane_dirty``) or the gated run diverges on
    exactly the storm wake-ups this fleet exists to handle."""
    a = _elastic_fleet(0)
    d = _elastic_fleet(0, step_changed_lanes_only=True)
    assert d.n_requests == a.n_requests
    assert d.n_finished == a.n_finished
    assert d.nodes_lost == a.nodes_lost > 0
    assert d.nodes_joined == a.nodes_joined > 0
    assert d.requeued_requests == a.requeued_requests
    assert d.drained_units == a.drained_units
    assert d.final_chips == a.final_chips
    assert d.slo_attainment == pytest.approx(a.slo_attainment, abs=0.02)


def test_array_state_elastic_bit_exact():
    a = dataclasses.asdict(_elastic_fleet(0))
    b = dataclasses.asdict(_elastic_fleet(0, array_state=True))
    assert a == b


# -- PendingSet: randomized plain-vs-array parity -----------------------------

def test_pending_set_array_parity_randomized():
    """Drive both PendingSet representations through the same random op
    stream — adds (with deadline ties), removes, re-adds of live members
    (must keep their slot), discards of absent requests — and demand the
    deadline-sorted views, iteration order, and membership answers stay
    identical.  Removal-heavy stretches force the array path's tombstone
    compaction."""
    from types import SimpleNamespace

    from repro.core.clock import PendingSet

    rng = random.Random(0xE1A5)
    for _ in range(12):
        plain, arr = PendingSet(), PendingSet(array_state=True)
        live, rid = [], 0
        for _ in range(rng.randint(40, 140)):
            op = rng.random()
            if op < 0.5 or not live:
                r = SimpleNamespace(rid=rid,
                                    deadline=float(rng.randint(0, 9)))
                rid += 1
                plain.add(r)
                arr.add(r)
                live.append(r)
            elif op < 0.8:
                r = live.pop(rng.randrange(len(live)))
                plain.remove(r)
                arr.remove(r)
            elif op < 0.9:
                r = rng.choice(live)     # re-add keeps the slot
                plain.add(r)
                arr.add(r)
            else:
                ghost = SimpleNamespace(rid=10 ** 6 + rid, deadline=0.0)
                plain.discard(ghost)
                arr.discard(ghost)
            assert len(plain) == len(arr) == len(live)
            assert bool(plain) == bool(arr)
            cap = rng.choice((None, 1, 3, 8))
            assert [r.rid for r in plain.by_deadline(cap)] \
                == [r.rid for r in arr.by_deadline(cap)]
            assert [r.rid for r in plain] == [r.rid for r in arr]
            probe = rng.choice(live) if live else \
                SimpleNamespace(rid=-1, deadline=0.0)
            assert (probe in plain) == (probe in arr)
            assert plain.has_rid(probe.rid) == arr.has_rid(probe.rid)
