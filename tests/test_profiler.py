"""Profiler calibration invariants (the paper's §2.1/§3 observations)."""
import pytest

import repro.configs as C
from repro.core.profiler import HBM_BYTES, Profiler
from repro.core.request import Request

PIPES = list(C.PIPELINE_IDS)


@pytest.fixture(scope="module")
def profs():
    return {p: Profiler(C.get(p)) for p in PIPES}


def _mid_req(pid):
    return Request(pid, 720, 4.0) if C.get(pid).is_video else Request(pid, 1024)


@pytest.mark.parametrize("pid", PIPES)
def test_diffuse_dominates(profs, pid):
    """§2.1: Diffuse is > 70% of end-to-end time."""
    p = profs[pid]
    r = _mid_req(pid)
    t_d = p.stage_time(r, "D", p.optimal_degree(r, "D") * p.k_min)
    assert t_d / p.pipeline_time(r) > 0.5


@pytest.mark.parametrize("pid", PIPES)
def test_encode_is_parallelism_averse(profs, pid):
    p = profs[pid]
    r = _mid_req(pid)
    assert p.optimal_degree(r, "E") == 1
    assert p.speedup(r, "E", 8 * p.k_min) < 2.0


@pytest.mark.parametrize("pid", ["sd3", "flux"])
def test_fig3_optimal_degree_grows_with_resolution(profs, pid):
    p = profs[pid]
    degs = [p.optimal_degree(Request(pid, res), "D")
            for res in (128, 512, 1024, 2048, 4096)]
    assert degs == sorted(degs)
    assert degs[0] == 1 and degs[-1] >= 4


@pytest.mark.parametrize("pid", PIPES)
def test_decode_scales_worse_than_diffuse(profs, pid):
    p = profs[pid]
    r = _mid_req(pid)
    k = 8 * p.k_min
    assert p.efficiency(r, "C", k) < p.efficiency(r, "D", k)


def test_mp_fold_matches_memory_pressure(profs):
    """Flux/HYV need k_min>1 (their Diffuse > 1 chip); sd3/cog do not."""
    assert profs["sd3"].k_min == 1
    assert profs["cogvideox"].k_min == 1
    assert profs["flux"].k_min >= 2
    assert profs["hunyuanvideo"].k_min >= 2


def test_colocated_infeasibility_drives_disaggregation(profs):
    """HYV cannot host ⟨EDC⟩ even with the MP fold -> always disaggregated;
    B1-B4 (no fold) cannot host flux at all (the paper's OOM rows)."""
    hyv = profs["hunyuanvideo"]
    assert hyv.unit_param_bytes("EDC") > HBM_BYTES
    flux_nofold = Profiler(C.get("flux"), force_k_min=1)
    assert flux_nofold.unit_param_bytes("EDC") > HBM_BYTES


@pytest.mark.parametrize("pid", PIPES)
def test_memory_model_monotonicity(profs, pid):
    p = profs[pid]
    r = _mid_req(pid)
    assert p.peak_mem(r, "D", 1) >= p.peak_mem(r, "D", 2)
    assert p.peak_mem(r, "EDC", 1) >= p.peak_mem(r, "D", 1)
    # the paper's Q_DC > Q_ED (since l_C >> l_E) holds for heavy requests;
    # tiny latents under a 4096-dim T5-XXL condition can invert it
    heavy = (Request(pid, 720, 8.0) if C.get(pid).is_video
             else Request(pid, 4096))
    assert p.comm_bytes(heavy, "DC") > p.comm_bytes(heavy, "ED")


@pytest.mark.parametrize("pid", PIPES)
def test_stage_times_positive_and_finite(profs, pid):
    p = profs[pid]
    from repro.core.workloads import MIXES
    for mix in MIXES[pid].values():
        for (res, sec), _ in mix:
            r = Request(pid, res, float(sec))
            for s in "EDC":
                for k in (1, 2, 4, 8):
                    t = p.stage_time(r, s, k * p.k_min)
                    assert 0 < t < 3600
