"""The event-clock kernel (repro.core.clock) in isolation: loop mechanics,
tick-grid quantization, heartbeat/adaptive gap, wake-source plug-ins, and
the completion-heap contract every driver shares."""
import math

import pytest

import repro.configs as C
from repro.core.clock import (ClockConfig, ClockDriver, EventClock, Lane,
                              PendingSet, Scheduler, monitor_boundary_source,
                              replace_capable)
from repro.core.monitor import Monitor
from repro.core.profiler import Profiler
from repro.core.request import Request
from repro.core.trident import TridentScheduler


class _Recorder(ClockDriver):
    """Minimal driver: records wake-up times, never pends, finishes when
    told."""

    def __init__(self, done_at=math.inf, pending_until=-1.0):
        self.taus = []
        self.done_at = done_at
        self.pending_until = pending_until

    def advance(self, tau):
        self.taus.append(tau)

    def done(self):
        return self.taus and self.taus[-1] >= self.done_at

    def heartbeat_pending(self):
        return self.taus and self.taus[-1] <= self.pending_until

    def still_pending(self, lane, rid):
        return False


def test_tick_mode_visits_every_grid_point():
    clock = EventClock(ClockConfig(tick=0.5, horizon=2.0, mode="tick"))
    drv = _Recorder()
    clock.run(drv)
    assert drv.taus == [0.0, 0.5, 1.0, 1.5, 2.0]
    assert clock.wakeups == 5


def test_tick_mode_stops_when_driver_is_done():
    clock = EventClock(ClockConfig(tick=0.5, horizon=100.0, mode="tick"))
    drv = _Recorder(done_at=1.0)
    clock.run(drv)
    assert drv.taus[-1] == 1.0 and len(drv.taus) == 3


def test_event_mode_quantizes_wake_sources_up_to_the_grid():
    clock = EventClock(ClockConfig(tick=0.25, horizon=10.0))
    wakes = iter([0.6, 2.26, None])
    clock.add_source(lambda tau: next(wakes))
    drv = _Recorder()
    clock.run(drv)
    # 0.6 -> 0.75, 2.26 -> 2.5, then no source answers -> loop ends
    assert drv.taus == [0.0, 0.75, 2.5]


def test_event_mode_always_advances_at_least_one_tick():
    clock = EventClock(ClockConfig(tick=0.25, horizon=1.0))
    clock.add_source(lambda tau: tau)   # pathological: "wake now"
    drv = _Recorder()
    clock.run(drv)
    assert drv.taus == [0.0, 0.25, 0.5, 0.75, 1.0]


def test_completion_heap_orders_by_finish_then_push_order():
    clock = EventClock(ClockConfig())
    r = Request("sd3", 512)
    clock.push_completion(2.0, "a", "D", "D", 1.0, (r,))
    clock.push_completion(1.0, "a", "E", "E", 0.5, (r,))
    clock.push_completion(1.0, "b", "C", "C", 0.1, (r,))
    due = list(clock.pop_due(1.5))
    assert [(e[0], e[2]) for e in due] == [(1.0, "a"), (1.0, "b")]
    assert clock.completions[0][0] == 2.0    # not yet due
    assert list(clock.pop_due(0.5)) == []


def test_heartbeat_fires_only_while_driver_pends():
    clock = EventClock(ClockConfig(tick=0.25, horizon=50.0, max_idle_gap=1.0))
    drv = _Recorder(pending_until=2.0)
    clock.run(drv)
    # heartbeats every gap while pending, then nothing can change state
    assert drv.taus == [0.0, 1.0, 2.0, 3.0]


def test_adaptive_gap_doubles_without_flips_and_resets_on_one():
    cfg = ClockConfig(tick=0.25, horizon=200.0, max_idle_gap=1.0,
                      adaptive_idle_gap=True, idle_gap_max=8.0)
    clock = EventClock(cfg)
    clock.track_deadline(20.0, "p", 1)

    class _Pending(_Recorder):
        def heartbeat_pending(self):
            return self.taus[-1] < 40.0

        def still_pending(self, lane, rid):
            return True

    drv = _Pending()
    clock.run(drv)
    gaps = [b - a for a, b in zip(drv.taus, drv.taus[1:])]
    assert max(gaps) == 8.0                      # doubled up to the ceiling
    reset = drv.taus.index(next(t for t in drv.taus if t >= 20.0))
    assert gaps[reset] == 1.0                    # the flip reset the gap


def test_monitor_boundary_source_respects_arming():
    mon = Monitor(t_win=10.0)
    mon.record_stage(5.0, "D", "D", 1.0)
    armed = {"on": True}
    src = monitor_boundary_source(mon, lambda: armed["on"])
    assert src(6.0) == 15.0
    assert src(20.0) is None          # boundary not in the future
    armed["on"] = False
    assert src(6.0) is None           # disarmed
    assert monitor_boundary_source(Monitor(), lambda: True)(0.0) is None


def test_replace_capable_detects_overrides():
    prof = Profiler(C.get("sd3"))
    from repro.core.simulator import SimConfig
    assert replace_capable(TridentScheduler(prof, SimConfig(), []))
    assert not replace_capable(Scheduler(prof, SimConfig(), []))
    assert Scheduler(prof, SimConfig(), []).next_wake(None, 0.0) is None


def test_lane_admit_and_record_feed_the_kernel():
    prof = Profiler(C.get("sd3"))
    from repro.core.simulator import SimConfig
    lane = Lane("sd3", prof, Scheduler(prof, SimConfig(), []))
    clock = EventClock(ClockConfig())
    r = Request("sd3", 512, arrival=1.0)
    r.deadline = 9.0
    lane.admit(r, clock)
    assert r in lane.pending and lane.new_arrivals == [r]
    assert clock._deadlines == [(9.0, "sd3", r.rid)]
    assert isinstance(lane.pending, PendingSet)


def test_lane_borrowed_stage_accounting_rejects_diffuse():
    """The lending invariant is enforced in the shared Lane bookkeeping:
    counting a D run on a loan slot (uid >= base_units) must assert."""
    prof = Profiler(C.get("sd3"))
    from repro.core.dispatcher import DispatchDecision
    from repro.core.orchestrator import Orchestrator
    from repro.core.simulator import SimConfig
    lane = Lane("sd3", prof, Scheduler(prof, SimConfig(), []))
    lane.engine = type("_E", (), {})()
    lane.engine.plan = Orchestrator(prof, num_chips=8).generate(
        [Request("sd3", 512)])
    lane.track_borrowed = True
    lane.base_units = 99                    # nothing is borrowed
    clock = EventClock(ClockConfig())
    r = Request("sd3", 512)
    dec = DispatchDecision(request=r, vr_type=0, degree=1,
                           d_units=(0,), e_units=(0,), c_units=(0,))
    lane.record(dec, {"E": (0.0, 1.0)}, clock)
    assert lane.borrowed_stage_runs == {}
    lane.base_units = 0                     # every unit counts as borrowed
    with pytest.raises(AssertionError):
        lane.record(dec, {"D": (1.0, 2.0)}, clock)
