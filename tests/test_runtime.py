"""Runtime Engine semantics: merging, Adjust-on-Dispatch, handoff buffers."""
import pytest

import repro.configs as C
from repro.core.dispatcher import DispatchDecision
from repro.core.placement import PlacementPlan
from repro.core.profiler import Profiler
from repro.core.request import Request
from repro.core.runtime import CAP_HB, RuntimeEngine


@pytest.fixture(scope="module")
def prof():
    return Profiler(C.get("sd3"))


def _req(prof, res=1024):
    r = Request("sd3", res)
    r.deadline = 1e9
    return r


def _plan(types):
    return PlacementPlan(list(types), unit_size=1, units_per_node=8)


def test_merging_execute_saves_overhead(prof):
    """E+D+C colocated on one unit runs as merged atomic executions."""
    plan = _plan(["EDC"] * 8)
    eng = RuntimeEngine(prof, plan)
    r = _req(prof)
    dec = DispatchDecision(r, 0, 1, (0,), (0,), (0,))
    times = eng.execute(dec, tau=0.0)
    assert eng.stats.merged_runs == 2  # E+D and D+C both merged
    assert times["E"][1] <= times["D"][0] + 1e-9
    assert times["D"][1] <= times["C"][0] + 1e-9
    # separate units: same stages, no merge, transfers appear
    eng2 = RuntimeEngine(prof, _plan(["ED"] * 4 + ["C"] * 4))
    dec2 = DispatchDecision(r, 2, 1, (0,), (0,), (4,))
    eng2.execute(dec2, tau=0.0)
    assert eng2.stats.merged_runs == 1   # only E+D merged
    assert eng2.stats.device_pushes == 1  # D->C push


def test_adjust_on_dispatch_defers_loads(prof):
    """Placement switch updates metadata instantly; replica loads happen on
    the first dispatch that needs them, and only there."""
    plan = _plan(["EDC"] * 8)
    eng = RuntimeEngine(prof, plan)
    new = _plan(["DC"] * 4 + ["E"] * 4)
    eng.apply_placement(new, tau=0.0)
    assert eng.stats.placement_switches == 1
    assert eng.stats.adjust_loads == 0          # nothing moved yet
    assert eng.plan.placements[0] == "DC"
    assert "E" in eng.units[4].resident or eng.units[4].resident == {"E", "D", "C"}
    r = _req(prof)
    dec = DispatchDecision(r, 1, 1, (0,), (4,), (0,))
    eng.execute(dec, tau=0.0)
    # E was already resident (old EDC) -> no load; nothing new needed
    assert eng.stats.adjust_loads == 0
    # now force a unit that never had C: switch an E unit to C
    eng.apply_placement(_plan(["DC"] * 4 + ["E"] * 3 + ["C"]), tau=0.0)
    dec2 = DispatchDecision(r, 1, 1, (1,), (4,), (7,))
    pre = eng.stats.adjust_loads
    eng.execute(dec2, tau=0.0)
    assert eng.stats.adjust_loads == pre  # C resident from initial EDC too

    # fresh engine where residency genuinely lacks the stage
    eng3 = RuntimeEngine(prof, _plan(["E"] * 8))
    eng3.apply_placement(_plan(["EDC"] * 8), tau=0.0)
    dec3 = DispatchDecision(r, 0, 1, (0,), (0,), (0,))
    eng3.execute(dec3, tau=0.0)
    assert eng3.stats.adjust_loads == 2          # D and C loaded on dispatch
    assert eng3.stats.adjust_load_time > 0


def test_downtime_adjust_blocks_cluster(prof):
    eng = RuntimeEngine(prof, _plan(["E"] * 8), adjust_on_dispatch=False)
    cost = eng.apply_placement(_plan(["EDC"] * 8), tau=0.0,
                               downtime_adjust=True)
    assert cost > 0
    assert eng.stats.downtime > 0
    assert all(u.free_at >= cost for u in eng.units)


def test_handoff_buffer_overflow_host_path(prof):
    eng = RuntimeEngine(prof, _plan(["ED"] * 4 + ["C"] * 4))
    eng.units[4].hb_staged = CAP_HB  # destination HB full
    r = _req(prof, res=1536)
    dec = DispatchDecision(r, 2, 1, (0,), (0,), (4,))
    eng.execute(dec, tau=0.0)
    assert eng.stats.host_path_pushes == 1
    assert eng.stats.device_pushes == 0


def test_reinstance_hot_set_is_free(prof):
    eng = RuntimeEngine(prof, _plan(["EDC"] * 16))
    r = _req(prof)
    # contiguous intra-node set of 4 -> hot
    eng.execute(DispatchDecision(r, 0, 4, (0, 1, 2, 3), (0, 1, 2, 3),
                                 (0,)), tau=0.0)
    assert eng.stats.lazy_group_inits == 0
    # non-contiguous set -> lazy init once, then cached
    eng.execute(DispatchDecision(r, 0, 2, (8, 10), (8, 10), (8,)), tau=100.0)
    assert eng.stats.lazy_group_inits == 1
    eng.execute(DispatchDecision(r, 0, 2, (8, 10), (8, 10), (8,)), tau=200.0)
    assert eng.stats.lazy_group_inits == 1


def test_fifo_reservation(prof):
    """Plans on busy units start after the units free up."""
    eng = RuntimeEngine(prof, _plan(["EDC"] * 8))
    r1, r2 = _req(prof), _req(prof)
    t1 = eng.execute(DispatchDecision(r1, 0, 1, (0,), (0,), (0,)), tau=0.0)
    t2 = eng.execute(DispatchDecision(r2, 0, 1, (0,), (0,), (0,)), tau=0.0)
    assert t2["E"][0] >= t1["C"][1] - 1e-9
