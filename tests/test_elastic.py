"""Elastic capacity + fault injection (repro.core.elastic).

Covers the FaultInjector wake source end to end on small fleets: the
off-by-default contract (elastic off is bit-identical to a plain run),
join/preempt/degrade mechanics, the stage-aware drain's advantage over
a drain-unaware arm, Monitor-side quarantine of a slow-failing node,
and determinism of both the schedule generators and full trajectories.
"""
from __future__ import annotations

import dataclasses

import pytest

from repro.core import workloads
from repro.core.elastic import CapacityEvent
from repro.core.fleet import FleetConfig, run_fleet


def _run(duration, rates, sched, *, drain=True, prewarm=True, seed=0,
         num_chips=64, pipelines=("sd3",), **cfg_kw):
    cfg = FleetConfig(num_chips=num_chips, t_win=500.0, cooldown=500.0,
                      elastic=True, elastic_schedule=sched,
                      elastic_drain=drain, elastic_prewarm=prewarm, **cfg_kw)
    return run_fleet(list(pipelines), mode="adaptive", duration=duration,
                     cfg=cfg, seed=seed, rates=dict(rates))


# ---------------------------------------------------------------- events


def test_capacity_event_validation():
    with pytest.raises(AssertionError):
        CapacityEvent(t=1.0, kind="explode")
    with pytest.raises(AssertionError):
        CapacityEvent(t=1.0, kind="join", n_nodes=2, lead=-1.0)
    ev = CapacityEvent(t=5.0, kind="preempt", nodes=(3,), lead=2.0)
    assert ev.nodes == (3,) and ev.factor == 1.0


def _walk_live(events, live):
    """Replay a schedule checking node ids stay valid; returns final size."""
    last_t = -1.0
    for ev in events:
        assert ev.t >= last_t
        last_t = ev.t
        if ev.kind == "join":
            assert ev.n_nodes > 0
            live += ev.n_nodes
        else:
            assert ev.nodes, ev
            assert all(0 <= n < live for n in ev.nodes), (ev, live)
            if ev.kind == "preempt":
                live -= len(ev.nodes)
        assert live >= 1
    return live


@pytest.mark.parametrize("seed", [0, 1, 7])
def test_storm_schedule_deterministic_and_valid(seed):
    mk = lambda: workloads.preemption_storm_schedule(  # noqa: E731
        900.0, 256, seed=seed)
    a, b = mk(), mk()
    assert a == b                      # deterministic per seed
    _walk_live(a, 256 // 8)
    # the degraded node recovers before the first preemption notice so
    # the slow node never confounds the measured recovery windows
    first_notice = min(e.t - e.lead for e in a if e.kind == "preempt")
    recover_t = max(e.t for e in a if e.kind == "recover")
    assert recover_t < first_notice
    # every storm is eventually backfilled by a join
    assert sum(e.n_nodes for e in a if e.kind == "join") == \
        sum(len(e.nodes) for e in a if e.kind == "preempt")


def test_evacuation_schedule_deterministic_and_valid():
    a = workloads.region_evacuation_schedule(600.0, 128, seed=3)
    assert a == workloads.region_evacuation_schedule(600.0, 128, seed=3)
    final = _walk_live(a, 128 // 8)
    assert final == 128 // 8           # quarter in, old quarter out


def test_storm_div_scales_storm_size():
    big = workloads.preemption_storm_schedule(900.0, 256, seed=0,
                                              storm_div=4)
    small = workloads.preemption_storm_schedule(900.0, 256, seed=0)
    k = lambda ev: len(ev.nodes)       # noqa: E731
    assert max(map(k, (e for e in big if e.kind == "preempt"))) > \
        max(map(k, (e for e in small if e.kind == "preempt")))


# ------------------------------------------------------------ off path


def test_elastic_off_is_bit_identical():
    """elastic=False and elastic=True+empty schedule must not differ."""
    kw = dict(duration=90.0, seed=0, rates={"sd3": 5.0})
    plain = run_fleet(["sd3"], mode="adaptive",
                      cfg=FleetConfig(num_chips=64), **kw)
    armed = run_fleet(["sd3"], mode="adaptive",
                      cfg=FleetConfig(num_chips=64, elastic=True,
                                      elastic_schedule=()), **kw)
    assert dataclasses.asdict(plain) == dataclasses.asdict(armed)
    assert plain.final_chips == 64


# ------------------------------------------------------------ mechanics


def test_join_grows_pool_and_prewarms():
    sched = (CapacityEvent(t=60.0, kind="join", n_nodes=2, lead=20.0),)
    r = _run(150.0, {"sd3": 6.0}, sched)
    assert r.nodes_joined == 2
    assert r.final_chips == 64 + 2 * 8
    # the announce window staged the post-join partition onto the
    # incoming chips: every new chip pre-warmed
    assert r.elastic_prewarm_chips == 16
    assert len(r.repartitions) >= 1


def test_preempt_drain_aware_requeues_nothing():
    """lead > max stage runtime: the stage-aware drain lands everything
    in flight before the loss, while the drain-unaware arm keeps
    launching onto doomed units and pays revocations at the land."""
    sched = (CapacityEvent(t=120.0, kind="preempt", nodes=(6, 7),
                           lead=30.0),)
    aware = _run(200.0, {"sd3": 14.0}, sched)
    unaware = _run(200.0, {"sd3": 14.0}, sched, drain=False, prewarm=False)
    for r in (aware, unaware):
        assert r.nodes_lost == 2
        assert r.final_chips == 64 - 2 * 8
        assert r.n_finished == r.n_requests      # nothing stranded
    assert aware.drained_units > 0
    assert aware.requeued_requests == 0
    assert unaware.requeued_requests > 0
    assert unaware.drained_units == 0


def test_degrade_detector_quarantines_slow_node():
    """A 3x-slow node on a quiet single-lane fleet clears the evidence
    bar and all of its units end up decommissioned."""
    sched = (CapacityEvent(t=20.0, kind="degrade", nodes=(0,), factor=3.0),)
    r = _run(240.0, {"sd3": 6.0}, sched)
    assert r.quarantined_units == 3
    assert r.slo_attainment > 0.9      # routing around it keeps SLOs


def test_elastic_trajectory_deterministic():
    sched = workloads.preemption_storm_schedule(300.0, 64, seed=0,
                                                n_storms=1)
    mk = lambda: _run(300.0, {"sd3": 8.0}, sched, seed=2)  # noqa: E731
    a, b = mk(), mk()
    assert dataclasses.asdict(a) == dataclasses.asdict(b)
    assert a.nodes_lost > 0 and a.nodes_joined > 0


def test_evict_prewarm_unit_drops_only_that_units_chips():
    """Satellite fix: a unit mutated under staged pre-warm marks (lent
    out, drained, decommissioned) must lose exactly its chips' marks —
    a stale mark would count as a hit and avert a reload the chips owe."""
    from types import SimpleNamespace

    from repro.core.fleet import FleetSimulator

    marks = {c: ("sd3", frozenset({"unet"}), 1.0) for c in range(16)}
    stub = SimpleNamespace(
        prewarmed=dict(marks),
        plan=SimpleNamespace(unit_chips=lambda pid, g: (8, 12)))
    FleetSimulator._evict_prewarm_unit(stub, "sd3", 1)
    assert sorted(stub.prewarmed) == [c for c in range(16)
                                      if not 8 <= c < 12]
    # empty mark table: early-out leaves it empty (no KeyErrors)
    stub.prewarmed = {}
    FleetSimulator._evict_prewarm_unit(stub, "sd3", 1)
    assert stub.prewarmed == {}
