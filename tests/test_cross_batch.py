"""Fleet-level cross-lane dynamic batching (core/dispatcher.CrossLaneBatcher).

Covers: the shape-key contract (same placement type but different stage
never merges), borrow-ledger accounting for fused launches spanning a
borrowed unit, the multi-dimensional grouped-ILP column against an
exhaustive reference, the E-hold execute/skip contract, the burst-storm
trace generator, off-path bit-identity (knobs present but batching off),
and the headline behavior at smoke scale — correlated long-prompt bursts
overload each lane's single auxiliary encode unit and cross-lane fusion
recovers the tail.
"""
import random
from types import SimpleNamespace

import pytest

from repro.core import ilp, workloads
from repro.core.clock import Lane
from repro.core.dispatcher import CrossLaneBatcher
from repro.core.fleet import FleetConfig, PipelineRegistry, run_fleet

PIPES = workloads.CROSS_BATCH_PIPELINES

# CI-sized burst storm, one tuned definition shared with
# ``benchmarks/e2e.py --cross-batch`` (its smoke variant)
SMOKE = dict(duration=600.0, head=160.0,
             base_rates={"flux": 1.45, "hunyuanvideo": 0.35},
             wave_rates={"flux": 4.6, "hunyuanvideo": 0.2},
             cfg=dict(num_chips=64, t_win=120.0, cooldown=100.0))


@pytest.fixture(scope="module")
def registry():
    return PipelineRegistry(PIPES)


@pytest.fixture(scope="module")
def profs(registry):
    return {p: registry.profiler(p) for p in PIPES}


def _storm(profs, on, seed=0):
    cfg = FleetConfig(cross_lane_batching=on,
                      cross_lane_max_batch=(8 if on else 0), **SMOKE["cfg"])
    trace = workloads.cross_batch_trace(SMOKE["duration"], profs, seed=seed,
                                        base_rates=SMOKE["base_rates"],
                                        wave_rates=SMOKE["wave_rates"],
                                        head=SMOKE["head"])
    return run_fleet(PIPES, mode="predictive", duration=SMOKE["duration"],
                     cfg=cfg, registry=PipelineRegistry(PIPES), trace=trace)


@pytest.fixture(scope="module")
def storm_runs(profs):
    return _storm(profs, on=False), _storm(profs, on=True)


# -- shape-key contract --------------------------------------------------------

def _stub_lane(pid, placements, unit_size=2):
    plan = SimpleNamespace(placements=placements, unit_size=unit_size)
    return SimpleNamespace(pipeline=pid, engine=SimpleNamespace(plan=plan))


def test_same_ptype_different_stage_never_merges():
    """A ⟨C⟩-typed unit hosting a warm E replica must not merge with a C
    run on the same placement type: the shape key includes the *stage*,
    so the two candidates land in distinct groups, each spanning one
    lane, and no fusion happens."""
    lane_a = _stub_lane("flux", {0: "C"})
    lane_b = _stub_lane("hunyuanvideo", {0: "C"})
    dec_e = SimpleNamespace(xl_candidate=("E",), e_units=(0,), c_units=())
    dec_c = SimpleNamespace(xl_candidate=("C",), e_units=(), c_units=(0,))
    batcher = CrossLaneBatcher()
    groups = batcher._collect([(lane_a, [dec_e]), (lane_b, [dec_c])])
    assert set(groups) == {("E", "C", 2), ("C", "C", 2)}
    assert all(len(g) == 1 for g in groups.values())
    # end-to-end: plan() fuses nothing (clock untouched, so None is safe)
    cgroups = batcher.plan([(lane_a, [dec_e]), (lane_b, [dec_c])], 0.0, None)
    assert cgroups == [] and batcher.merges == 0
    assert not hasattr(dec_e, "xl_efused") and not hasattr(dec_c, "xl_cdefer")


def test_same_shape_same_stage_groups_together():
    lane_a = _stub_lane("flux", {0: "EC"})
    lane_b = _stub_lane("hunyuanvideo", {0: "EC"})
    dec_a = SimpleNamespace(xl_candidate=("E",), e_units=(0,), c_units=())
    dec_b = SimpleNamespace(xl_candidate=("E",), e_units=(0,), c_units=())
    groups = CrossLaneBatcher()._collect([(lane_a, [dec_a]),
                                          (lane_b, [dec_b])])
    assert set(groups) == {("E", "EC", 2)}
    assert len(groups[("E", "EC", 2)]) == 2


# -- borrow-ledger accounting --------------------------------------------------

def test_fused_launch_on_borrowed_unit_charges_host_ledger():
    """A fused launch whose host units span a borrowed (lending) slot
    counts ONE stage run on the host lane's borrow ledger; launches on
    native units charge nothing, and lanes without lending tracking are
    untouched (the owning lane's BORROW_PENALTY accounting lives in its
    own dispatcher, not here)."""
    batcher = CrossLaneBatcher()
    host = SimpleNamespace(track_borrowed=True, base_units=4,
                           borrowed_stage_runs={})
    batcher._charge_borrowed(host, (5,), "E")        # unit 5 is borrowed
    assert host.borrowed_stage_runs == {"E": 1}
    batcher._charge_borrowed(host, (5, 1), "C")      # spans a borrowed slot
    assert host.borrowed_stage_runs == {"E": 1, "C": 1}
    batcher._charge_borrowed(host, (1, 2), "E")      # native units only
    assert host.borrowed_stage_runs == {"E": 1, "C": 1}
    plain = SimpleNamespace(track_borrowed=False, base_units=4,
                            borrowed_stage_runs={})
    batcher._charge_borrowed(plain, (9,), "E")
    assert plain.borrowed_stage_runs == {}


# -- multi-dimensional grouped ILP columns -------------------------------------

def test_multidim_grouped_solve_matches_brute_force():
    """Cross-lane columns charge two budget dimensions at once (the shared
    fleet batch budget and the member lane's own cap); the grouped solve
    must still find the exhaustive optimum."""
    rng = random.Random(11)
    for _ in range(30):
        dims = rng.randrange(2, 4)
        budgets = [rng.randrange(2, 6) for _ in range(dims)]
        options, counts = [], []
        for _g in range(rng.randrange(1, 4)):
            b = rng.randrange(1, 3)
            lane_dim = rng.randrange(1, dims)
            options.append([ilp.Option(dim=(0, lane_dim), usage=(b, b),
                                       reward=float(rng.randrange(1, 10)))])
            counts.append(rng.randrange(1, 3))
        sol = ilp.solve_grouped(options, budgets, counts)
        expanded = [opts for opts, m in zip(options, counts)
                    for _ in range(m)]
        assert abs(sol.reward - ilp.brute_force(expanded, budgets)) < 1e-9
        # feasibility across every charged dimension
        rem = list(budgets)
        for g, granted in sol.alloc.items():
            assert len(granted) <= counts[g]
            for o in granted:
                for d, u in zip(o.dim, o.usage):
                    rem[d] -= u
        assert all(r >= 0 for r in rem)


# -- E-hold execute/skip contract ----------------------------------------------

def _exec_lane(pending):
    lane = SimpleNamespace(pending=list(pending), executed=[], recorded=[])
    lane.engine = SimpleNamespace(
        execute=lambda dec, tau: lane.executed.append(dec) or {})
    lane.record = lambda dec, times, clock: lane.recorded.append(dec)
    return lane


def test_e_hold_skips_unfused_and_executes_fused():
    """An ``xl_hold`` decision executes only when the fleet batcher fused
    it this tick; otherwise nothing is reserved and the request stays in
    the pending pool for a later tick."""
    req_h = SimpleNamespace(rid=1)
    req_f = SimpleNamespace(rid=2)
    req_n = SimpleNamespace(rid=3)
    held = SimpleNamespace(request=req_h, corequests=(), xl_hold=True)
    fused = SimpleNamespace(request=req_f, corequests=(), xl_hold=True,
                            xl_efused=(0.0, 1.0, True, (0,)))
    native = SimpleNamespace(request=req_n, corequests=())
    lane = _exec_lane([req_h, req_f, req_n])
    Lane.execute_decisions(lane, [held, fused, native], 0.0, None)
    assert lane.executed == [fused, native]
    assert lane.recorded == [fused, native]
    assert lane.pending == [req_h]         # held request stays pending


# -- burst-storm trace generator -----------------------------------------------

def test_cross_batch_trace_deterministic_and_stamped(profs):
    t1 = workloads.cross_batch_trace(300.0, profs, seed=3)
    t2 = workloads.cross_batch_trace(300.0, profs, seed=3)
    # rids are a process-global counter; determinism is everything else
    assert [(r.pipeline, r.arrival, r.cond_len, r.deadline, r.resolution,
             r.seconds) for r in t1] == \
           [(r.pipeline, r.arrival, r.cond_len, r.deadline, r.resolution,
             r.seconds) for r in t2]
    assert t1 == sorted(t1, key=lambda r: (r.arrival, r.pipeline, r.rid))
    wave = [r for r in t1 if r.cond_len != 77]
    base = [r for r in t1 if r.cond_len == 77]
    assert wave and base
    for r in wave:
        assert r.cond_len == workloads.CROSS_BATCH_COND[r.pipeline]
        expect = r.arrival + workloads.SLO_SCALE * \
            profs[r.pipeline].pipeline_time(r)
        assert abs(r.deadline - expect) < 1e-9
        # wave classes are the long-prompt scenario classes
        assert ((r.resolution, r.seconds)
                in [cls for cls, _ in
                    workloads.CROSS_BATCH_MIXES[r.pipeline]])


def test_cross_batch_phases_gate_and_short_fallback():
    ph = workloads.cross_batch_phases(900.0)
    assert ph[0][1] == {p: 0.0 for p in PIPES}     # closed head
    assert ph[-1][0] == 1.0
    mults = [m[PIPES[0]] for _, m in ph]
    assert 1.0 in mults and 0.0 in mults           # gate actually opens
    assert all(a < b for a, b in zip([f for f, _ in ph],
                                     [f for f, _ in ph][1:]))
    # a trace too short for one absolute cycle still bursts (scaled shape)
    short = workloads.cross_batch_phases(90.0)
    assert any(m[PIPES[0]] == 1.0 for _, m in short)
    assert short[-1][0] == 1.0


# -- off-path bit-identity -----------------------------------------------------

def test_knobs_default_off():
    cfg = FleetConfig()
    assert cfg.cross_lane_batching is False
    assert cfg.cross_lane_max_batch == 0


def test_off_path_bit_identical_with_knobs_present(profs):
    """``cross_lane_max_batch`` with batching off must be bit-identical to
    the plain config — the knob is read only by the CrossLaneBatcher,
    which the off path never constructs (the committed BENCH trajectories
    must stay byte-stable)."""
    def run(**kw):
        cfg = FleetConfig(num_chips=64, t_win=60.0, cooldown=40.0, **kw)
        trace = workloads.cross_batch_trace(180.0, profs, seed=1,
                                            head=60.0)
        return run_fleet(PIPES, mode="predictive", duration=180.0, cfg=cfg,
                         registry=PipelineRegistry(PIPES), trace=trace)
    a = run()
    b = run(cross_lane_max_batch=8)
    assert a.p95_latency == b.p95_latency
    assert a.mean_latency == b.mean_latency
    assert a.slo_attainment == b.slo_attainment
    assert a.sched_wakeups == b.sched_wakeups
    assert a.repartitions == b.repartitions
    assert b.cross_lane_merges == 0 and b.cross_lane_merged_requests == 0


# -- headline behavior at smoke scale ------------------------------------------

def test_cross_lane_batching_improves_burst_storm_tail(storm_runs):
    off, on = storm_runs
    assert not off.oom and not on.oom
    assert off.n_requests == on.n_requests
    assert off.cross_lane_merges == 0
    assert on.cross_lane_merges > 0
    # every fusion spans >= 2 lanes, so >= 2 batch items per merge
    assert on.cross_lane_merged_requests >= 2 * on.cross_lane_merges
    assert on.p95_latency <= off.p95_latency
    # E-hold never starves: everything admitted finishes under overload
    assert on.n_finished == on.n_requests


def test_burst_storm_helps_the_overloaded_lane(storm_runs):
    """The lane whose single auxiliary encode unit overloads is the one
    the fusion rescues — the partner lane may trade some of its own tail
    into the pool, but the *worst* pipeline's tail must improve (which
    lane is worst depends on scale; the contract doesn't)."""
    off, on = storm_runs
    worst_off = max(m["p95_s"] for m in off.per_pipeline.values())
    worst_on = max(m["p95_s"] for m in on.per_pipeline.values())
    assert worst_on < worst_off


# -- force-return vs fused launch (lending interaction) ------------------------

def test_force_return_deferred_past_fused_launch(monkeypatch):
    """A force-return arriving while the borrowed slot hosts an un-drained
    MERGED_LANE launch must defer (``force_return_pending``), not yank the
    unit mid-merge; ``step`` closes it at the drain.  ``hard=True`` (the
    re-partition path) skips the guard."""
    from repro.core.lending import Loan, LendingBroker

    cfg = SimpleNamespace(lend_min_hold=45.0, lend_min_pressure=0.5,
                          lend_low_pressure=0.05)
    broker = LendingBroker(cfg, registry=None)
    loan = Loan(lender="sd3", lender_uid=5, borrower="flux", slot=9,
                ptype="E", start=0.0, borrow_cost=0.4)
    broker.active.append(loan)
    closed = []
    monkeypatch.setattr(
        broker, "_close",
        lambda fleet, ln, tau: (closed.append(ln), broker.active.remove(ln)))
    monkeypatch.setattr(broker, "_lend_budgets", lambda fleet, tau: {})
    busy = {"on": True}
    fleet = SimpleNamespace(
        _xl=SimpleNamespace(
            fused_busy=lambda pid, unit, tau:
                busy["on"] and (pid, unit) == ("flux", 9)),
        fleet_monitor=SimpleNamespace(backlog_pressure=lambda tau: {}),
        lanes={"flux": SimpleNamespace(engine=SimpleNamespace(
            units={9: SimpleNamespace(free_at=float("inf"))}))})

    # fused launch in flight: the close is deferred, nothing changes hands
    assert broker.force_return_unit(fleet, "sd3", 5, tau=10.0) is False
    assert loan.force_return_pending
    assert loan in broker.active and not closed
    assert broker.forced_returns == 0

    # still busy at the next wake-up: step keeps deferring
    broker.step(fleet, tau=20.0)
    assert loan in broker.active and not closed

    # merge drained: the very next step closes the pending loan
    busy["on"] = False
    broker.step(fleet, tau=30.0)
    assert closed == [loan] and not broker.active
    assert broker.forced_returns == 1

    # hard=True (re-partition: engines are rebuilt anyway) skips the guard
    busy["on"] = True
    loan2 = Loan(lender="sd3", lender_uid=5, borrower="flux", slot=9,
                 ptype="E", start=0.0, borrow_cost=0.4)
    broker.active.append(loan2)
    assert broker.force_return_unit(fleet, "sd3", 5, tau=40.0, hard=True)
    assert closed == [loan, loan2] and broker.forced_returns == 2
