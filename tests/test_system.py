"""End-to-end behaviour tests for the paper's system.

Wall-clock path: real (tiny) JAX diffusion pipeline served through the real
planners — the same Orchestrator/Dispatcher decisions as the simulator, but
stage execution is actual model computation on CPU.
"""
import jax
import numpy as np
import pytest

import repro.configs as C
from repro.core.dispatcher import Dispatcher
from repro.core.orchestrator import Orchestrator
from repro.core.profiler import Profiler
from repro.core.request import Request
from repro.models import pipeline as pl


@pytest.fixture(scope="module")
def served_pipeline():
    cfg = C.get_smoke("sd3")
    params = pl.init(cfg, jax.random.PRNGKey(0))
    prof = Profiler(C.get("sd3"))     # planning uses the full-size profile
    return cfg, params, prof


def test_wallclock_stage_level_serving(served_pipeline):
    """Plan with the real dispatcher, execute stages with the real model."""
    cfg, params, prof = served_pipeline
    orch = Orchestrator(prof, num_chips=32)
    reqs = []
    for i, res in enumerate((512, 1024, 512)):
        r = Request("sd3", res, arrival=0.0)
        r.deadline = 2.5 * prof.pipeline_time(r)
        reqs.append(r)
    plan = orch.generate(reqs)
    disp = Dispatcher(prof)
    idle = set(range(plan.num_units))
    decisions = disp.dispatch(reqs, plan, idle, {g: 0.0 for g in idle}, 0.0)
    assert decisions

    # execute each decision's stages with the actual JAX pipeline
    key = jax.random.PRNGKey(1)
    for dec in decisions:
        toks = jax.random.randint(key, (1, 8), 0, cfg.encoder.vocab_size)
        cond = pl.encode(cfg, params, toks)                      # Γ^E
        grid = cfg.latent_grid(64, 0.0)
        lat = pl.diffuse(cfg, params, cond,                      # Γ^D
                         (1, cfg.latent_tokens(64, 0.0), cfg.dit.latent_dim),
                         key)
        out = pl.decode(cfg, params, lat, grid)                  # Γ^C
        assert np.isfinite(np.asarray(out)).all()
        dec.request.stage_done["C"] = 0.0
    assert all(r.finished for r in (d.request for d in decisions))


def test_placement_plan_serves_every_stage(served_pipeline):
    _, _, prof = served_pipeline
    orch = Orchestrator(prof, num_chips=64)
    plan = orch.generate([Request("sd3", 1024) for _ in range(10)])
    for s in "EDC":
        assert plan.units_with(s)


def test_paper_claim_lossless():
    """Stage-level dispatch is a *lossless* systems acceleration: outputs are
    bit-identical to monolithic execution (§9)."""
    cfg = C.get_smoke("flux")
    params = pl.init(cfg, jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0,
                              cfg.encoder.vocab_size)
    key = jax.random.PRNGKey(5)
    a = pl.generate(cfg, params, toks, 64, 0.0, key)
    cond = pl.encode(cfg, params, toks)
    lat = pl.diffuse(cfg, params, cond,
                     (1, cfg.latent_tokens(64, 0.0), cfg.dit.latent_dim), key)
    b = pl.decode(cfg, params, lat, cfg.latent_grid(64, 0.0))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
