"""Training substrate: convergence, optimizer math, checkpoint, data."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.data import pipeline as dp
from repro.training import checkpoint, loop, optimizer as opt


def test_loss_decreases_dense():
    cfg = C.get_smoke("yi-9b")
    dcfg = dp.DataConfig(batch=4, seq_len=32)
    _, hist = loop.train(cfg, dp.iterator(cfg, dcfg), num_steps=25, log_every=5)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_loss_decreases_ssm():
    cfg = C.get_smoke("rwkv6-3b")
    dcfg = dp.DataConfig(batch=4, seq_len=32)
    _, hist = loop.train(cfg, dp.iterator(cfg, dcfg), num_steps=25, log_every=5)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_adamw_schedule():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(opt.schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(opt.schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(opt.schedule(cfg, jnp.int32(100))) - 0.1) < 1e-6


def test_adamw_descends_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    cfg = opt.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                          weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_grad_clip_limits_update():
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    cfg = opt.AdamWConfig(lr=1e-3, warmup_steps=0, grad_clip=1.0,
                          weight_decay=0.0)
    big = {"w": jnp.full((3,), 1e9)}
    new, _ = opt.update(cfg, big, state, params)
    assert np.isfinite(np.asarray(new["w"])).all()


def test_checkpoint_roundtrip(tmp_path):
    cfg = C.get_smoke("gemma2-9b")
    params = jax.eval_shape(lambda: None) if False else None
    from repro.models import transformer as tf
    params = tf.init(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, params)
    back = checkpoint.restore(path, params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_data_determinism_and_sharding_shapes():
    cfg = C.get_smoke("deepseek-moe-16b")
    dcfg = dp.DataConfig(batch=8, seq_len=16, seed=3)
    a = dp.synthetic_batch(cfg, dcfg, 5)
    b = dp.synthetic_batch(cfg, dcfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = dp.synthetic_batch(cfg, dcfg, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    spec = dp.batch_spec(cfg, dcfg)
    assert spec["tokens"].shape == a["tokens"].shape


def test_vlm_train_step_masks_vision_positions():
    cfg = C.get_smoke("internvl2-2b")
    state = loop.init_state(cfg, jax.random.PRNGKey(0))
    dcfg = dp.DataConfig(batch=2, seq_len=16)
    batch = {k: jnp.asarray(v) for k, v in dp.synthetic_batch(cfg, dcfg, 0).items()}
    step = jax.jit(loop.make_train_step(cfg))
    _, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
